"""End-to-end distributed mining: count distribution over a device mesh.

Spawns an 8-device host mesh (the CPU stand-in for a pod) as a 2-D
``(block=4, cls=2)`` mining mesh: TID bitmap blocks are sharded across
the ``block`` axis while each ``cls`` shard evaluates its own slice of
every candidate-pair chunk, and mines a dataset with the unified
engine: one fused gather→screen→intersect→scatter dispatch per pair
chunk against the shared block-sharded DeviceRowStore, with the
two-level distributed Early-Stopping screen (psum of per-shard
one-block bounds over ``block`` only).  Results are verified against
the single-host oracle.

    python examples/distributed_mining.py        # re-execs with 8 devices
"""

import sys

sys.path.insert(0, "src")

from repro.launch.forcedevices import force_host_device_count  # noqa: E402

import os                                                     # noqa: E402

if "XLA_FLAGS" not in os.environ:
    force_host_device_count(8)

import time                                                   # noqa: E402

import jax                                                    # noqa: E402

from repro.core.oracle import mine                            # noqa: E402
from repro.core.distributed import DistributedMiner           # noqa: E402
from repro.data import make_dataset                           # noqa: E402
from repro.launch.mesh import make_mining_mesh                # noqa: E402


def main() -> None:
    mesh = make_mining_mesh(block=4, cls=2)
    print(f"mesh: {dict(mesh.shape)} over {jax.device_count()} devices")

    db, minsups = make_dataset("kosarak-like")
    ms = minsups[3]
    print(f"dataset: kosarak-like |DB|={len(db)} minSup={ms}")

    t0 = time.time()
    ref, ref_stats = mine(db, ms, "eclat", early_stop=True)
    t_oracle = time.time() - t0
    print(f"oracle:      F={len(ref):5d}  {t_oracle:.2f}s")

    miner = DistributedMiner(mesh, early_stop=True, capacity=8192,
                             block_words=8)
    t0 = time.time()
    out, stats = miner.mine(db, ms)
    t_dist = time.time() - t0
    assert out == ref, "distributed result differs from oracle!"
    print(f"distributed: F={len(out):5d}  {t_dist:.2f}s  "
          f"dispatches={stats.device_calls} "
          f"screened={stats.screened_out}/{stats.candidates}")
    print("count-distribution result == oracle: OK")


if __name__ == "__main__":
    main()
