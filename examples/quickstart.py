"""Quickstart: mine frequent itemsets with Early-Stopping intersections.

Runs the paper's running example (Table I) and a synthetic retail-like
dataset through all three schemes (Eclat / dEclat / PrePost+), with and
without Early Stopping, and prints the comparison/work savings — the
paper's headline result.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
sys.path.insert(0, "src")

from repro.core.oracle import mine, mine_bruteforce          # noqa: E402
from repro.core.eclat import mine_bitmap                     # noqa: E402
from repro.data import make_dataset                          # noqa: E402


def main() -> None:
    # --- the paper's Table I example ------------------------------------
    db = [list(t) for t in ["ade", "bcd", "ace", "acde", "ae", "acd",
                            "bc", "acde", "bce", "ade"]]
    print("== paper running example (minSup=3) ==")
    expected = mine_bruteforce(db, 3)
    print(f"frequent itemsets: {len(expected)} (paper says 15)")
    for scheme in ("eclat", "declat", "prepost"):
        out_s, st_s = mine(db, 3, scheme, early_stop=False)
        out_e, st_e = mine(db, 3, scheme, early_stop=True)
        assert out_s == out_e == expected
        print(f"  {scheme:8s}: comparisons {st_s.comparisons:4d} -> "
              f"{st_e.comparisons:4d} "
              f"({1 - st_e.comparisons / st_s.comparisons:.0%} saved, "
              f"{st_e.es_aborts} early aborts)")

    # --- a sparse synthetic dataset (the regime where ES shines) --------
    print("\n== retail-like replica, minSup level 3 ==")
    db2, minsups = make_dataset("retail-like")
    ms = minsups[2]
    out_s, st_s = mine(db2, ms, "eclat", early_stop=False)
    out_e, st_e = mine(db2, ms, "eclat", early_stop=True)
    assert out_s == out_e
    print(f"|DB|={len(db2)}, minSup={ms}, frequent={len(out_s)}, "
          f"cands/nodes={st_s.ratio:.2f}")
    print(f"  eclat oracle:  comparisons {st_s.comparisons:,} -> "
          f"{st_e.comparisons:,} "
          f"({1 - st_e.comparisons / st_s.comparisons:.1%} saved)")

    # --- the TPU-shaped bitmap engine ------------------------------------
    out_b, st_b = mine_bitmap(db2, ms, "eclat", early_stop=True,
                              block_words=8)
    assert out_b == out_s
    print(f"  bitmap engine: word-ops {st_b.word_ops_full:,} -> "
          f"{st_b.word_ops:,} ({st_b.word_ops_saved_frac:.1%} saved; "
          f"{st_b.screened_out} screened + {st_b.kernel_aborts} "
          f"in-kernel aborts, {st_b.device_calls} device calls)")


if __name__ == "__main__":
    main()
