"""End-to-end LM training driver: a scaled-down qwen-family model trained
for a few hundred steps on the synthetic bigram stream, with checkpoints
and restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume

The model is the qwen1.5 architecture (QKV bias, tied embeddings) at
~14M params so a few hundred steps are CPU-feasible; the same driver
runs the full configs on real hardware.  Loss must drop well below
ln(vocab) (the stream has 0.7-bigram structure => achievable CE ~2.2).
"""

import sys
sys.path.insert(0, "src")

import argparse                                               # noqa: E402
import dataclasses                                            # noqa: E402

from repro.configs import get_arch                            # noqa: E402
from repro.launch.train import train_lm                       # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    spec = get_arch("qwen1.5-0.5b")
    cfg = dataclasses.replace(
        spec.config_fn(None),
        name="qwen1.5-mini",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, d_head=32,
        d_ff=704, vocab_size=8192,
        dtype="float32", remat="none", attn_chunk=128)

    out = train_lm(cfg, steps=args.steps, batch=args.batch,
                   seq_len=args.seq_len, lr=3e-3,
                   ckpt_dir=args.ckpt_dir, resume=args.resume,
                   ckpt_every=50)
    first = out["history"][0][1] if out["history"] else float("nan")
    final = out["final"]["loss"]
    print(f"\nloss: {first:.3f} -> {final:.3f} "
          f"(uniform baseline ln(8192) = 9.01)")
    assert final < first, "loss did not decrease"


if __name__ == "__main__":
    main()
