"""Batched serving example: prefill -> KV-cache decode, incl. the SWA
ring cache (mixtral smoke config) and the MLA latent cache (deepseek
smoke config).

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys
sys.path.insert(0, "src")

import numpy as np                                            # noqa: E402

from repro.configs import get_arch                            # noqa: E402
from repro.launch.serve import serve_greedy                   # noqa: E402


def main() -> None:
    rng = np.random.default_rng(0)
    for arch in ("qwen1.5-0.5b", "mixtral-8x22b", "deepseek-v2-236b"):
        cfg = get_arch(arch).smoke_config_fn()
        prompts = rng.integers(0, cfg.vocab_size, (4, 64)).astype(np.int32)
        print(f"== {arch} (smoke config: {cfg.name}) ==")
        gen = serve_greedy(cfg, prompts, max_new=12)
        print("first sequence continuation:", gen[0].tolist())


if __name__ == "__main__":
    main()
