# Makes ``tools`` importable so ``python -m tools.devicelint`` works
# from the repo root (and so tests can import the rule engine).
