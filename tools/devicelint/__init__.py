"""devicelint — repo-specific static analysis for device-purity contracts.

Every speed win since PR 1 rests on invariants nothing machine-checked
until now: fused dispatches must not force host syncs outside the
audited readback points, every public op in ``kernels/ops.py`` must be
pinned by a ``*_ref`` twin in ``kernels/ref.py``, traced-vs-static
argument choices must not silently multiply jit caches (the PR 5
``es_minsup`` bug), and the PR 8 mesh contract forbids ``psum`` over
the ``cls`` axis.  devicelint turns those review-memory contracts into
AST rules that fail CI.

Rules (see ``rules.py`` and docs/ARCHITECTURE.md "Device-purity
contract"):

* **DL001** host-sync: host-forcing operations in ``core/`` /
  ``kernels/`` without a ``# host-sync: <why>`` annotation.
* **DL002** ref-pinning: public dispatch in ``kernels/ops.py`` without
  a ``*_ref`` twin in ``kernels/ref.py`` + a test referencing both.
* **DL003** retrace hazards: uncached ``jax.jit`` in loops/functions,
  bogus or unhashable ``static_argnames``.
* **DL004** mesh-axis discipline: collectives over undeclared axes;
  ``psum`` over the ``cls`` axis.

Usage: ``python -m tools.devicelint src tests benchmarks`` (exit 1 on
any finding not covered by the committed baseline).  Pure stdlib — no
dependency beyond ``ast``.
"""

from tools.devicelint.engine import (  # noqa: F401
    Finding, lint_paths, lint_source, load_baseline, diff_baseline,
)
from tools.devicelint import rules  # noqa: F401
