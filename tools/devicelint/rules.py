"""devicelint rules DL001-DL004 (see docs/ARCHITECTURE.md for the
user-facing contract table; this module is the implementation).

Scope conventions:

* DL001 guards the device-resident engine layers only —
  ``src/repro/core/`` + ``src/repro/kernels/`` minus the two modules
  that are host-side *by design* (``core/oracle.py``, the pure-python
  reference miners, and ``core/cli.py``, user I/O).
* DL002 reads ``kernels/ops.py`` + ``kernels/ref.py`` + ``tests/``
  together (cross-file rule).
* DL003 applies to everything under ``src/`` — retrace hazards are
  costly wherever they occur; debt outside core/kernels is carried in
  the committed baseline rather than annotated away.
* DL004 applies to any scanned file that uses collectives.
"""

from __future__ import annotations

import ast

from tools.devicelint.engine import Finding, RepoIndex, SourceFile, rule

DL001_SCOPE = ("src/repro/core/", "src/repro/kernels/")
DL001_EXEMPT = ("src/repro/core/oracle.py", "src/repro/core/cli.py")

OPS_REL = "src/repro/kernels/ops.py"
REF_REL = "src/repro/kernels/ref.py"

# jax.lax collectives that REDUCE over an axis (forbidden on ``cls``
# per the PR 8 invariance contract) vs. ones that only rearrange
# (``all_gather`` along cls is exactly how survivor metadata travels).
_REDUCING = {"psum", "pmean", "pmax", "pmin", "psum_scatter"}
_COLLECTIVES = _REDUCING | {"all_gather", "all_to_all", "ppermute",
                            "axis_index", "pshuffle"}
# Call names whose string arguments declare mesh axes.
_AXIS_DECLS = {"P", "PartitionSpec", "Mesh", "make_mesh",
               "make_mining_mesh", "AxisNames"}


def _mentions_jnp(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Name) and n.id == "jnp"
               for n in ast.walk(node))


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression ('jax.lax.psum')."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


# --------------------------------------------------------------------------
# DL001 — host-sync discipline
# --------------------------------------------------------------------------

_COMPOUND = (ast.If, ast.While, ast.For, ast.AsyncFor, ast.With,
             ast.AsyncWith, ast.Try, ast.FunctionDef,
             ast.AsyncFunctionDef, ast.ClassDef)


def _is_host_sync_with(node: ast.With | ast.AsyncWith) -> bool:
    """``with host_sync("why"):`` / ``with guards.host_sync("why"):`` —
    the runtime escape doubles as the annotation, provided the why
    string is a non-empty literal."""
    for item in node.items:
        c = item.context_expr
        if isinstance(c, ast.Call) \
                and _dotted(c.func).rsplit(".", 1)[-1] == "host_sync" \
                and c.args and isinstance(c.args[0], ast.Constant) \
                and isinstance(c.args[0].value, str) and c.args[0].value:
            return True
    return False


@rule("DL001", "host-sync")
def dl001_host_sync(index: RepoIndex) -> list[Finding]:
    out: list[Finding] = []
    for sf in index.files:
        if sf.tree is None or sf.rel in DL001_EXEMPT:
            continue
        if not sf.rel.startswith(DL001_SCOPE):
            continue
        _dl001_scan(sf, out)
    return out


def _dl001_scan(sf: SourceFile, out: list[Finding]) -> None:
    def suppressed(lo: int, hi: int) -> bool:
        return any(ln in sf.annotations for ln in range(lo - 1, hi + 1))

    def visit(node: ast.AST, span, escaped: bool) -> None:
        if isinstance(node, (ast.With, ast.AsyncWith)) \
                and _is_host_sync_with(node):
            escaped = True
        # Simple (non-compound) statements set the suppression span:
        # an annotation anywhere in the statement or on the line above
        # covers every hit inside it — multi-line calls keep working.
        if isinstance(node, ast.stmt) \
                and not isinstance(node, _COMPOUND):
            span = (node.lineno, node.end_lineno or node.lineno)
        hit = _dl001_hit(node)
        if hit:
            if isinstance(node, (ast.If, ast.While)):
                lo, hi = node.lineno, (node.test.end_lineno
                                       or node.lineno)
            elif span is not None:
                lo, hi = span
            else:
                lo = node.lineno
                hi = getattr(node, "end_lineno", lo) or lo
            if not escaped and not suppressed(lo, hi):
                out.append(Finding(
                    "DL001", sf.rel, node.lineno,
                    hit + " — annotate `# host-sync: <why>` or keep "
                    "it on-device", sf.snippet(node.lineno)))
        for child in ast.iter_child_nodes(node):
            visit(child, span, escaped)

    visit(sf.tree, None, False)


def _dl001_hit(node: ast.AST) -> str | None:
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute):
            recv = _dotted(f.value)
            if f.attr in ("asarray", "array") and recv in ("np", "numpy"):
                return (f"np.{f.attr}(...) forces a host copy (and a "
                        "device sync when fed a device value)")
            if f.attr == "device_get" and recv == "jax":
                return "jax.device_get(...) is a blocking device->host sync"
            if f.attr == "block_until_ready":
                return ".block_until_ready() blocks the dispatch pipeline"
            if f.attr == "item":
                return ".item() synchronously reads a scalar off device"
        if isinstance(f, ast.Name) and f.id in ("int", "float") and any(
                _mentions_jnp(a) for a in node.args):
            return (f"{f.id}() on a jnp value synchronously reads a "
                    "scalar off device")
    if isinstance(node, (ast.If, ast.While)) and _mentions_jnp(node.test):
        return ("branching on a jnp value forces __bool__, a blocking "
                "device->host sync (and a trace error under jit)")
    return None


# --------------------------------------------------------------------------
# DL002 — ref-pinning
# --------------------------------------------------------------------------

def _public_defs(sf: SourceFile) -> list[ast.FunctionDef]:
    if sf.tree is None:
        return []
    return [n for n in sf.tree.body
            if isinstance(n, ast.FunctionDef)
            and not n.name.startswith("_")]


def _resolve_ref_twin(fn: ast.FunctionDef, ref_names: set) -> str | None:
    """ops-fn -> ref-twin name: direct ``{name}_ref``, factory
    ``make_`` stripped, or a ``*_ref`` the docstring pins it to."""
    for cand in (fn.name + "_ref",
                 fn.name.removeprefix("make_") + "_ref"):
        if cand in ref_names:
            return cand
    doc = ast.get_docstring(fn) or ""
    import re
    for m in re.findall(r"\b(\w+_ref)\b", doc):
        if m in ref_names:
            return m
    return None


@rule("DL002", "ref-pinning")
def dl002_ref_pinning(index: RepoIndex) -> list[Finding]:
    ops = index.get(OPS_REL)
    ref = index.get(REF_REL)
    if ops is None:
        return []      # not linting the kernels layer in this run
    ref_names = {f.name for f in _public_defs(ref)} if ref else set()
    tests = index.matching("tests/")
    out: list[Finding] = []
    for fn in _public_defs(ops):
        twin = _resolve_ref_twin(fn, ref_names)
        if twin is None:
            out.append(Finding(
                "DL002", ops.rel, fn.lineno,
                f"public dispatch `{fn.name}` has no `*_ref` twin in "
                f"kernels/ref.py (add `{fn.name}_ref` or pin one in the "
                "docstring)", ops.snippet(fn.lineno)))
            continue
        if tests and not any(fn.name in t.text and twin in t.text
                             for t in tests):
            out.append(Finding(
                "DL002", ops.rel, fn.lineno,
                f"no test file references both `{fn.name}` and its ref "
                f"twin `{twin}` — the pin is unverified",
                ops.snippet(fn.lineno)))
    return out


# --------------------------------------------------------------------------
# DL003 — retrace hazards
# --------------------------------------------------------------------------

def _is_jit_call(node: ast.Call) -> bool:
    return _dotted(node.func) in ("jax.jit", "jit")


def _jit_decoration(fn: ast.FunctionDef):
    """(static_argnames tuple, found) from @jax.jit /
    @functools.partial(jax.jit, static_argnames=...) decorators."""
    for dec in fn.decorator_list:
        if _dotted(dec) in ("jax.jit", "jit"):
            return (), True
        if isinstance(dec, ast.Call):
            target = dec
            if _dotted(dec.func) in ("functools.partial", "partial") \
                    and dec.args and isinstance(dec.args[0], ast.expr) \
                    and _dotted(dec.args[0]) in ("jax.jit", "jit"):
                pass                      # partial(jax.jit, ...) form
            elif _is_jit_call(dec):
                pass                      # @jax.jit(...) form
            else:
                continue
            statics = []
            for kw in target.keywords:
                if kw.arg == "static_argnames":
                    statics = [e.value for e in ast.walk(kw.value)
                               if isinstance(e, ast.Constant)
                               and isinstance(e.value, str)]
            return tuple(statics), True
    return (), False


def _is_cached(fn: ast.FunctionDef) -> bool:
    return any(_dotted(d if not isinstance(d, ast.Call) else d.func)
               in ("functools.lru_cache", "lru_cache",
                   "functools.cache", "cache")
               for d in fn.decorator_list)


@rule("DL003", "retrace-hazard")
def dl003_retrace(index: RepoIndex) -> list[Finding]:
    out: list[Finding] = []
    for sf in index.files:
        if sf.tree is None or not sf.rel.startswith("src/"):
            continue
        out.extend(_dl003_file(sf))
    return out


def _dl003_file(sf: SourceFile) -> list[Finding]:
    out: list[Finding] = []
    statics_by_fn: dict[str, tuple] = {}

    # (a) decorated jits: static_argnames must name real params, and
    # named params must not carry unhashable defaults.
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        statics, found = _jit_decoration(node)
        if not found:
            continue
        statics_by_fn[node.name] = statics
        a = node.args
        params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        pos = a.posonlyargs + a.args
        defaults = dict(zip([p.arg for p in pos[len(pos)
                                               - len(a.defaults):]],
                            a.defaults, strict=True))
        defaults.update(zip([p.arg for p in a.kwonlyargs],
                            a.kw_defaults, strict=True))
        for s in statics:
            if s not in params:
                out.append(Finding(
                    "DL003", sf.rel, node.lineno,
                    f"static_argnames names `{s}` which is not a "
                    f"parameter of `{node.name}` — the static is dead "
                    "and the real arg is traced", sf.snippet(node.lineno)))
            elif isinstance(defaults.get(s), (ast.List, ast.Dict, ast.Set)):
                out.append(Finding(
                    "DL003", sf.rel, node.lineno,
                    f"static arg `{s}` of `{node.name}` defaults to an "
                    "unhashable literal — every call with the default "
                    "raises or retraces", sf.snippet(node.lineno)))

    # (b) jax.jit constructed inside loops (retrace every iteration)
    # or inside uncached functions (retrace every call).
    class V(ast.NodeVisitor):
        def __init__(self):
            self.loop_depth = 0
            self.fn_stack: list[ast.FunctionDef] = []

        def visit_For(self, n):
            self.loop_depth += 1
            self.generic_visit(n)
            self.loop_depth -= 1
        visit_While = visit_For
        visit_AsyncFor = visit_For

        def visit_FunctionDef(self, n):
            self.fn_stack.append(n)
            saved, self.loop_depth = self.loop_depth, 0
            self.generic_visit(n)
            self.loop_depth = saved
            self.fn_stack.pop()
        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_Call(self, n):
            if _is_jit_call(n):
                if self.loop_depth:
                    out.append(Finding(
                        "DL003", sf.rel, n.lineno,
                        "jax.jit(...) constructed inside a loop — a "
                        "fresh cache per iteration, retraces every time",
                        sf.snippet(n.lineno)))
                elif self.fn_stack and not any(
                        _is_cached(f) for f in self.fn_stack):
                    out.append(Finding(
                        "DL003", sf.rel, n.lineno,
                        "jax.jit(...) constructed inside an uncached "
                        "function — a fresh jit cache per call; hoist "
                        "to module scope or lru_cache the factory",
                        sf.snippet(n.lineno)))
            self.generic_visit(n)

    V().visit(sf.tree)

    # (c) per-call-varying statics: a call site feeding int()/float()
    # (a freshly computed scalar) into a known static kwarg of a jitted
    # function defined in this file — the PR 5 `es_minsup` bug class.
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _dotted(node.func).rsplit(".", 1)[-1]
        statics = statics_by_fn.get(callee)
        if not statics:
            continue
        for kw in node.keywords:
            if kw.arg in statics and isinstance(kw.value, ast.Call) \
                    and isinstance(kw.value.func, ast.Name) \
                    and kw.value.func.id in ("int", "float"):
                out.append(Finding(
                    "DL003", sf.rel, node.lineno,
                    f"static arg `{kw.arg}` of `{callee}` is fed a "
                    f"freshly cast {kw.value.func.id}() scalar — "
                    "per-call-varying statics retrace on every distinct "
                    "value (pass it traced, or bucket it)",
                    sf.snippet(node.lineno)))
    return out


# --------------------------------------------------------------------------
# DL004 — mesh-axis discipline
# --------------------------------------------------------------------------

def _axis_vocabulary(sf: SourceFile) -> set:
    """Axis names the file declares: string constants inside mesh/spec
    constructor calls plus string elements of ``*_axes`` / ``*axis*``
    name assignments."""
    vocab: set = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) and _call_name(node) in _AXIS_DECLS:
            for sub in ast.walk(node):
                if isinstance(sub, ast.Constant) and isinstance(
                        sub.value, str):
                    vocab.add(sub.value)
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets
                       if isinstance(t, ast.Name)]
            if any("axes" in t or "axis" in t for t in targets):
                for sub in ast.walk(node.value):
                    if isinstance(sub, ast.Constant) and isinstance(
                            sub.value, str):
                        vocab.add(sub.value)
    return vocab


def _axis_arg(call: ast.Call) -> ast.AST | None:
    name = _call_name(call)
    for kw in call.keywords:
        if kw.arg in ("axis_name", "axis_names"):
            return kw.value
    idx = 0 if name == "axis_index" else 1
    if len(call.args) > idx:
        return call.args[idx]
    return None


@rule("DL004", "mesh-axis")
def dl004_mesh_axes(index: RepoIndex) -> list[Finding]:
    out: list[Finding] = []
    for sf in index.files:
        if sf.tree is None or not sf.rel.startswith(("src/", "tests/",
                                                     "benchmarks/")):
            continue
        vocab = None     # computed lazily, only for files w/ collectives
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if name not in _COLLECTIVES:
                continue
            dotted = _dotted(node.func)
            if dotted not in (f"jax.lax.{name}", f"lax.{name}", name):
                continue
            axis = _axis_arg(node)
            if axis is None:
                continue
            literals = [n.value for n in ast.walk(axis)
                        if isinstance(n, ast.Constant)
                        and isinstance(n.value, str)]
            named = _dotted(axis)
            # (a) the PR 8 contract: cls is a pair-sharding axis; any
            # REDUCING collective over it double-counts pair metrics.
            if name in _REDUCING and (
                    "cls" in literals or "cls" in named):
                out.append(Finding(
                    "DL004", sf.rel, node.lineno,
                    f"{name} over the `cls` axis — the PR 8 contract "
                    "reduces over block axes only (all_gather along "
                    "cls is the sanctioned move)",
                    sf.snippet(node.lineno)))
                continue
            # (b) literal axis names must be declared in the file's
            # mesh/spec vocabulary.
            if literals:
                if vocab is None:
                    vocab = _axis_vocabulary(sf)
                for lit in literals:
                    if lit not in vocab:
                        out.append(Finding(
                            "DL004", sf.rel, node.lineno,
                            f"{name} over axis '{lit}' which no mesh "
                            "spec / axis declaration in this file "
                            "names — undeclared collective axis",
                            sf.snippet(node.lineno)))
    return out
