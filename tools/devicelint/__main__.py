"""CLI: ``python -m tools.devicelint [paths...]``.

Exit 0 when every finding is covered by the committed baseline and no
baseline entry is stale; exit 1 otherwise.  ``--update-baseline``
rewrites the baseline to the current findings (shrink-only in spirit:
review the diff — the ratchet exists so new debt is a decision, not an
accident).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.devicelint.engine import (
    DEFAULT_BASELINE, diff_baseline, lint_paths, load_baseline,
    save_baseline,
)

DEFAULT_PATHS = ["src", "tests", "benchmarks"]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.devicelint",
        description="repo-specific device-purity static analysis "
                    "(rules DL001-DL004)")
    ap.add_argument("paths", nargs="*", default=DEFAULT_PATHS,
                    help="files/dirs to lint (repo-relative; default: "
                         "%(default)s)")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="baseline JSON (default: %(default)s)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to the current findings")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    args = ap.parse_args(argv)

    findings = lint_paths(list(args.paths))

    if args.update_baseline:
        save_baseline(findings, args.baseline)
        print(f"devicelint: baseline updated with {len(findings)} "
              f"finding(s) -> {args.baseline}", file=sys.stderr)
        return 0

    baseline = [] if args.no_baseline else load_baseline(args.baseline)
    new, stale = diff_baseline(findings, baseline)

    for f in new:
        print(f"{f}")
    for e in stale:
        print(f"{e.get('path')}:{e.get('line')}: {e.get('rule')} "
              f"[stale baseline entry — finding no longer present; "
              f"run --update-baseline to shrink] {e.get('message')}")

    if new or stale:
        print(f"devicelint: {len(new)} new finding(s), {len(stale)} "
              f"stale baseline entr(ies) — failing", file=sys.stderr)
        return 1
    carried = len(findings)
    print(f"devicelint ok: 0 new findings "
          f"({carried} carried in baseline)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
