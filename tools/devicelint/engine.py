"""devicelint rule framework: file index, findings, baseline ratchet.

The engine is deliberately tiny — stdlib ``ast`` only:

* ``SourceFile`` parses one file once and pre-extracts the
  ``# host-sync: <why>`` annotation map (rules share it).
* ``RepoIndex`` holds every parsed file so cross-file rules (DL002
  ref-pinning needs ops.py + ref.py + tests/) see the whole repo.
* A rule is a function ``(RepoIndex) -> list[Finding]`` registered with
  the ``@rule`` decorator; ``lint_paths`` runs them all.
* The baseline ratchet mirrors the bench-gate workflow: findings are
  fingerprinted by ``(rule, path, stripped source line)`` — stable
  under unrelated line drift — and compared as multisets against the
  committed ``baseline.json``.  NEW findings fail; STALE baseline
  entries (debt that got fixed) also fail until the baseline is
  re-shrunk with ``--update-baseline``, so the ratchet only tightens.
"""

from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent.parent
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"

# Annotation grammar (docs/ARCHITECTURE.md "Device-purity contract").
# Case-insensitive, and tolerant of a parenthesised qualifier so PR 7's
# existing ``# HOST-SYNC (load-bearing): why`` audit comments count.
_ANNOT_RE = re.compile(r"#\s*host-sync\b[^:#]*:\s*(\S.*)", re.IGNORECASE)


@dataclass(frozen=True)
class Finding:
    rule: str          # "DL001" .. "DL004"
    path: str          # repo-relative posix path
    line: int          # 1-based line of the offending node
    message: str
    snippet: str       # stripped source line — fingerprint component

    @property
    def fingerprint(self) -> tuple:
        # Line numbers are display-only: renames/reorders above a
        # finding must not churn the baseline.
        return (self.rule, self.path, self.snippet)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class SourceFile:
    """One parsed python file plus its host-sync annotation map."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        try:
            self.tree = ast.parse(text)
        except SyntaxError:
            self.tree = None     # rules skip unparsable files
        # line (1-based) -> why-string for every annotated line
        self.annotations: dict[int, str] = {}
        for i, ln in enumerate(self.lines, start=1):
            m = _ANNOT_RE.search(ln)
            if m:
                self.annotations[i] = m.group(1).strip()

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def annotated(self, node: ast.AST) -> bool:
        """True if the statement carrying ``node`` has a ``# host-sync:``
        annotation on the line above it, on its first line, or on any
        line the (possibly multi-line) statement spans."""
        lo = getattr(node, "lineno", 0)
        hi = getattr(node, "end_lineno", lo) or lo
        return any(ln in self.annotations for ln in range(lo - 1, hi + 1))


class RepoIndex:
    def __init__(self, root: Path, files: list[SourceFile]):
        self.root = root
        self.files = files
        self.by_rel = {f.rel: f for f in files}

    def get(self, rel: str) -> SourceFile | None:
        return self.by_rel.get(rel)

    def matching(self, prefix: str) -> list[SourceFile]:
        return [f for f in self.files if f.rel.startswith(prefix)]


# --------------------------------------------------------------------------
# rule registry
# --------------------------------------------------------------------------

RULES: dict[str, tuple[str, object]] = {}   # code -> (name, fn)


def rule(code: str, name: str):
    def deco(fn):
        RULES[code] = (name, fn)
        return fn
    return deco


def build_index(paths: list[str], root: Path = REPO) -> RepoIndex:
    seen: dict[str, SourceFile] = {}
    for p in paths:
        base = (root / p) if not Path(p).is_absolute() else Path(p)
        candidates = [base] if base.is_file() else sorted(base.rglob("*.py"))
        for f in candidates:
            if "__pycache__" in f.parts or f.suffix != ".py":
                continue
            try:
                rel = f.resolve().relative_to(root).as_posix()
            except ValueError:
                rel = f.as_posix()
            if rel not in seen:
                seen[rel] = SourceFile(
                    f, rel, f.read_text(encoding="utf-8"))
    return RepoIndex(root, list(seen.values()))


def lint_index(index: RepoIndex,
               only: set[str] | None = None) -> list[Finding]:
    findings: list[Finding] = []
    for code, (_, fn) in sorted(RULES.items()):
        if only and code not in only:
            continue
        findings.extend(fn(index))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def lint_paths(paths: list[str], root: Path = REPO,
               only: set[str] | None = None) -> list[Finding]:
    # Rules register on import; keep this import local so engine.py has
    # no import-time dependency on rules.py (tests import either alone).
    from tools.devicelint import rules  # noqa: F401
    return lint_index(build_index(paths, root), only=only)


def lint_source(text: str, rel: str = "src/repro/core/snippet.py",
                only: set[str] | None = None,
                extra: dict[str, str] | None = None) -> list[Finding]:
    """Lint in-memory sources (the fixture-test entry point).

    ``extra`` maps additional rel-paths to sources so cross-file rules
    (DL002) can be exercised hermetically.
    """
    from tools.devicelint import rules  # noqa: F401
    files = [SourceFile(Path(rel), rel, text)]
    for r, t in (extra or {}).items():
        files.append(SourceFile(Path(r), r, t))
    return lint_index(RepoIndex(REPO, files), only=only)


# --------------------------------------------------------------------------
# baseline ratchet
# --------------------------------------------------------------------------

def load_baseline(path: Path = DEFAULT_BASELINE) -> list[dict]:
    if not path.exists():
        return []
    return json.loads(path.read_text(encoding="utf-8"))


def save_baseline(findings: list[Finding],
                  path: Path = DEFAULT_BASELINE) -> None:
    entries = [{"rule": f.rule, "path": f.path, "line": f.line,
                "snippet": f.snippet, "message": f.message}
               for f in findings]
    path.write_text(json.dumps(entries, indent=2) + "\n", encoding="utf-8")


def diff_baseline(findings: list[Finding], baseline: list[dict]
                  ) -> tuple[list[Finding], list[dict]]:
    """Multiset diff: (new findings, stale baseline entries)."""
    remaining = [dict(e) for e in baseline]
    new: list[Finding] = []
    for f in findings:
        for e in remaining:
            if (e.get("rule"), e.get("path"),
                    e.get("snippet")) == f.fingerprint:
                remaining.remove(e)
                break
        else:
            new.append(f)
    return new, remaining
