"""Docs consistency checks, run in the CI lint job (ISSUE 6 satellite).

Two guards:

1. **Relative links resolve.**  Every relative markdown link target in
   README.md, ROADMAP.md and docs/*.md must exist on disk (anchors are
   stripped; external http(s)/mailto links are skipped).  A renamed or
   dropped file breaks the build instead of leaving a dead link.

2. **docs/ARCHITECTURE.md stays in sync with the scheduler client
   protocol.**  The architecture document must name every public
   protocol method a ``FrontierScheduler`` client implements — the
   method set is read from ``core/frontier.py``'s class docstring
   contract (the miners implement it directly, so there is no ABC to
   introspect), kept here as the single explicit list.  Adding a
   protocol method without documenting it fails lint.

3. **Required-term coverage (ISSUE 9).**  The 2-D sharding and
   full-tier bench surfaces must stay documented: docs/ARCHITECTURE.md
   has to mention the mining-mesh builder, the ``cls`` axis semantics
   and the scheduler's ``chunk_quantum`` contract, and
   benchmarks/README.md has to document the ``--full`` tier and the
   ``BENCH_full.json`` schema.  Renaming or dropping those sections
   fails lint.

Usage: ``python tools/check_docs.py`` (exit 1 on any failure).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DOC_FILES = ["README.md", "ROADMAP.md", "benchmarks/README.md"]

# The FrontierScheduler client protocol (core/frontier.py).  When a
# method is added there, document it in docs/ARCHITECTURE.md and extend
# this list — that is the point of the guard.
PROTOCOL_METHODS = [
    "pair_columns",
    "evaluate_pairs",
    "make_class",
    "emit",
    "release",
    "maybe_compact",
    "chunk_sort_key",
    "chunk_widths",
]

# Required-term coverage (ISSUE 9): file -> terms that must appear.
REQUIRED_TERMS = {
    "docs/ARCHITECTURE.md": [
        "make_mining_mesh",      # the 2-D mesh builder
        "cls",                   # the pair-sharding axis
        "psum",                  # reduction axes must stay documented
        "chunk_quantum",         # the scheduler alignment contract
        "all_gather",            # scatter locality story
        # Device-purity contract (ISSUE 10): every devicelint rule code
        # plus the annotation grammar and the runtime guard entry points
        # must stay documented.
        "DL001",
        "DL002",
        "DL003",
        "DL004",
        "# host-sync:",
        "device_purity_guard",
        "host_sync",
        "--update-baseline",
    ],
    "benchmarks/README.md": [
        "--full",
        "BENCH_full.json",
        "peak_device_words_per_host",
        "stream_paper_dataset",
    ],
}

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _iter_doc_paths():
    for name in DOC_FILES:
        p = REPO / name
        if p.exists():
            yield p
    yield from sorted((REPO / "docs").glob("*.md"))


def check_links() -> list:
    failures = []
    for doc in _iter_doc_paths():
        text = doc.read_text(encoding="utf-8")
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (doc.parent / rel).exists():
                failures.append(
                    f"{doc.relative_to(REPO)}: dead link -> {target}")
    return failures


def check_protocol_documented() -> list:
    arch = REPO / "docs" / "ARCHITECTURE.md"
    if not arch.exists():
        return ["docs/ARCHITECTURE.md is missing"]
    text = arch.read_text(encoding="utf-8")
    return [
        f"docs/ARCHITECTURE.md: client-protocol method "
        f"`{m}` is not documented"
        for m in PROTOCOL_METHODS if m not in text
    ]


def check_protocol_list_current() -> list:
    """The explicit list above must itself cover every method the
    frontier module's protocol docstring declares (``name(...) ->`` or
    ``name(...)`` lines in the module docstring's protocol section)."""
    frontier = REPO / "src" / "repro" / "core" / "frontier.py"
    text = frontier.read_text(encoding="utf-8")
    declared = set(re.findall(r"``(\w+)\([^)]*\)", text))
    declared -= {"min", "max", "ClassNode", "EngineAccounting"}
    missing = declared - set(PROTOCOL_METHODS) - {
        "drain_group", "run", "push", "remap", "_assemble"}
    return [
        f"tools/check_docs.py: PROTOCOL_METHODS is stale — frontier.py "
        f"declares `{m}` in its protocol docs" for m in sorted(missing)
    ]


def check_required_terms() -> list:
    failures = []
    for rel, terms in REQUIRED_TERMS.items():
        path = REPO / rel
        if not path.exists():
            failures.append(f"{rel} is missing")
            continue
        text = path.read_text(encoding="utf-8")
        failures.extend(
            f"{rel}: required term `{t}` is no longer documented"
            for t in terms if t not in text)
    return failures


def main() -> None:
    failures = (check_links() + check_protocol_documented()
                + check_protocol_list_current() + check_required_terms())
    if failures:
        print("DOCS CHECK FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        sys.exit(1)
    n_docs = len(list(_iter_doc_paths()))
    print(f"docs ok: links resolve in {n_docs} files, "
          f"{len(PROTOCOL_METHODS)} protocol methods documented",
          file=sys.stderr)


if __name__ == "__main__":
    main()
