"""End-to-end integration: train driver (ckpt/restart), serve driver,
and the dry-run machinery on a small subprocess mesh."""

import subprocess
import sys
import textwrap

import numpy as np
import pytest
import dataclasses

from repro.configs import get_arch


@pytest.mark.slow
def test_train_lm_loss_decreases_and_resumes(tmp_path):
    from repro.launch.train import train_lm

    cfg = dataclasses.replace(
        get_arch("qwen1.5-0.5b").smoke_config_fn(),
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=512, attn_chunk=32)
    d = str(tmp_path / "ckpt")
    out = train_lm(cfg, steps=30, batch=4, seq_len=64, lr=5e-3,
                   ckpt_dir=d, ckpt_every=10, log_every=10,
                   log_fn=lambda *a: None)
    first = out["history"][0][1]
    final = out["final"]["loss"]
    assert final < first, (first, final)

    # resume continues from step 30 and trains further without blowup
    out2 = train_lm(cfg, steps=40, batch=4, seq_len=64, lr=5e-3,
                    ckpt_dir=d, ckpt_every=10, resume=True, log_every=5,
                    log_fn=lambda *a: None)
    assert out2["history"][0][0] > 30   # started past the restore point
    assert np.isfinite(out2["final"]["loss"])


def test_serve_greedy_deterministic():
    from repro.launch.serve import serve_greedy

    cfg = get_arch("qwen1.5-0.5b").smoke_config_fn()
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    a = serve_greedy(cfg, prompts, max_new=4, seed=1,
                     log_fn=lambda *a: None)
    b = serve_greedy(cfg, prompts, max_new=4, seed=1,
                     log_fn=lambda *a: None)
    assert np.array_equal(a, b)
    assert a.shape == (2, 4)


DRYRUN_SMALL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys
    sys.path.insert(0, "src")
    import jax
    from repro.compat import make_mesh
    mesh = make_mesh((4, 4), ("data", "model"))
    import repro.launch.dryrun as DR
    rec = DR.run_cell("qwen1.5-0.5b", "decode_32k", mesh, "test4x4",
                      "/tmp/dryrun_test_ci")
    assert rec.get("ok"), rec.get("error")
    assert rec["cost_analysis"]["flops"] > 0
    assert rec["collectives"]["total"]["count"] >= 0
    assert rec["peak_memory_per_chip"] > 0
    # cost fit present for LM cells (scan reconstruction)
    assert "cost_fit" in rec and rec["cost_fit"]["n_layers_extrapolated"] == 24
    print("DRYRUN_SMALL_OK")
""")


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    proc = subprocess.run([sys.executable, "-c", DRYRUN_SMALL],
                          capture_output=True, text=True, timeout=900,
                          cwd=".")
    assert "DRYRUN_SMALL_OK" in proc.stdout, proc.stderr[-3000:]


def test_roofline_terms_math():
    from repro.roofline.analysis import RooflineTerms, PEAK_FLOPS, HBM_BW

    t = RooflineTerms(arch="a", shape="train_x", mesh="m", chips=256,
                      flops_per_chip=PEAK_FLOPS,      # exactly 1s compute
                      bytes_per_chip=HBM_BW * 0.5,    # 0.5s memory
                      link_bytes_per_chip=0.0,
                      model_flops=0.5 * 256 * PEAK_FLOPS)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(0.5)
    assert t.bottleneck == "compute"
    assert t.step_time_lower_bound == pytest.approx(1.0)
    assert t.useful_flops_ratio == pytest.approx(0.5)
    assert t.roofline_fraction == pytest.approx(0.5)


def test_hlo_collective_parser():
    from repro.roofline.hlo import parse_collectives, _shape_bytes

    hlo = '''
      %p0 = f32[16,128]{1,0} parameter(0)
      %ag = f32[16,2048]{1,0} all-gather(%p0), dimensions={1}
      %ar = bf16[4,256]{1,0} all-reduce(%p1), to_apply=%add
      %cp = f32[8]{0} collective-permute(%p2)
    '''
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    # operand of the all-gather is p0: 16*128*4 bytes
    assert out["all-gather"]["operand_bytes"] == 16 * 128 * 4
    assert out["all-reduce"]["count"] == 1
    assert out["total"]["count"] == 3
    assert _shape_bytes("bf16[4,256]") == 4 * 256 * 2


def test_mining_cli(tmp_path, capsys):
    """The CLI mines a FIMI file and both engines agree."""
    import sys as _sys
    from repro.core import cli

    f = tmp_path / "db.dat"
    f.write_text("1 2 3\n1 2\n2 3\n1 2 3 4\n2 4\n")
    outs = {}
    for engine in ("oracle", "bitmap"):
        _sys.argv = ["cli", "--input", str(f), "--minsup", "2",
                     "--engine", engine,
                     "--json-out", str(tmp_path / f"{engine}.json")]
        cli.main()
        import json as _json
        outs[engine] = _json.load(open(tmp_path / f"{engine}.json"))
    assert outs["oracle"] == outs["bitmap"]
    assert outs["oracle"]["2"] == 5
    # adaptive scheme + its knobs flow through to the bitmap engine
    # (oracle has no adaptive mode: the CLI maps it to eclat there)
    _sys.argv = ["cli", "--input", str(f), "--minsup", "2",
                 "--engine", "bitmap", "--scheme", "adaptive",
                 "--block-words", "1", "--diff-density", "0.3",
                 "--diff-hysteresis", "0.05",
                 "--json-out", str(tmp_path / "adaptive.json")]
    cli.main()
    assert _json.load(open(tmp_path / "adaptive.json")) == outs["oracle"]
