"""Device-resident PrePost+ engine: dispatch-count and pool tests.

The fused-path contract (ISSUE 3; ISSUE 5 split the dispatch in two,
mirroring test_fused_engine.py / test_distributed.py for the bitmap
engines):

  * ``DevicePrePost.mine`` issues exactly TWO device dispatches per
    pair chunk — the merge pre-pass (``ops.nlist_presize``) and the
    survivor-only scatter (``ops.nlist_scatter``), skipped when a chunk
    has no survivors — and never the host-padded ``nlist_intersect``
    or the legacy one-dispatch ``nlist_extend`` path, and never
    materialises N-lists on host between levels;
  * child extents are allocated tight (exact pre-pass lengths,
    survivors only);
  * N-list pool growth preserves live rows bit-for-bit;
  * extent bucketing falls back to powers of two past the largest tuned
    bucket instead of raising.
"""

import random

import numpy as np
import pytest

from repro.core.bitmap import nl_pad_len, NL_LEN_BUCKETS
from repro.core.oracle import mine
from repro.core.prepost import DevicePrePost, _pad_len, mine_prepost_device
from repro.core.rowstore import NListPool
from repro.kernels import ops


def _random_db(seed, n_items=(3, 9), n_trans=(4, 30)):
    rng = random.Random(seed)
    ni = rng.randint(*n_items)
    nt = rng.randint(*n_trans)
    dens = rng.choice([0.2, 0.4, 0.6])
    db = [[i for i in range(ni) if rng.random() < dens] for _ in range(nt)]
    db = [t for t in db if t] or [[0]]
    minsup = rng.randint(1, max(1, len(db) // 2))
    return db, minsup


def test_two_nlist_dispatches_per_pair_chunk(monkeypatch):
    """Every pair chunk is one ``nlist_presize`` plus at most one
    ``nlist_scatter`` (skipped when nothing survived); the host-padded
    ``nlist_intersect`` and the legacy one-dispatch ``nlist_extend``
    are never called by the miner."""
    calls = {"presize": 0, "scatter": 0}
    real_presize = ops.nlist_presize
    real_scatter = ops.nlist_scatter

    def counting_presize(*a, **k):
        calls["presize"] += 1
        return real_presize(*a, **k)

    def counting_scatter(*a, **k):
        calls["scatter"] += 1
        return real_scatter(*a, **k)

    def forbidden(*a, **k):
        raise AssertionError("legacy nlist dispatch path used")

    monkeypatch.setattr(ops, "nlist_presize", counting_presize)
    monkeypatch.setattr(ops, "nlist_scatter", counting_scatter)
    monkeypatch.setattr(ops, "nlist_intersect", forbidden)
    monkeypatch.setattr(ops, "nlist_extend", forbidden)

    db, minsup = _random_db(3, n_items=(8, 8), n_trans=(25, 30))
    miner = DevicePrePost(early_stop=True, pair_chunk=2)
    out, stats = miner.mine(db, minsup)
    assert calls["presize"] + calls["scatter"] == stats.device_calls
    assert calls["scatter"] <= calls["presize"]   # no-survivor chunks skip
    # small pair_chunk forces several chunks
    assert calls["presize"] >= 2
    expected, _ = mine(db, minsup, "prepost", early_stop=True)
    assert out == expected


def test_pool_extents_recycled_end_to_end(monkeypatch):
    """Spent rows return their extents: when the DFS finishes every
    extent is back on the free list, and the peak live mass stays below
    the cumulative allocation (recycling actually happened).  Since
    ISSUE 5 only *surviving* children allocate at all (a dead candidate
    never touches the pool), so the cumulative mass itself is tight —
    the seed here is a deep DFS where classes are released and reused
    across many drain groups."""
    import repro.core.prepost as PP

    created = []
    real_pool = PP.NListPool

    class CapturePool(real_pool):
        def __init__(self, *a, **k):
            super().__init__(*a, **k)
            created.append(self)

    monkeypatch.setattr(PP, "NListPool", CapturePool)
    db, minsup = _random_db(8, n_items=(9, 9), n_trans=(28, 30))
    out, stats = mine_prepost_device(db, minsup, pair_chunk=8)
    expected, _ = mine(db, minsup, "prepost", early_stop=True)
    assert out == expected
    (pool,) = created
    assert pool.live_codes == 0 and pool.n_live_rows == 0
    assert stats.peak_codes == pool.peak_codes
    assert pool.peak_codes < pool.total_alloc_codes


def test_child_extents_allocated_tight_and_survivor_only(monkeypatch):
    """ISSUE 5 allocation contract: after the level-1 upload, the pool
    only ever receives allocation requests for FREQUENT children (one
    per itemset of size >= 2 — dead candidates never touch the pool),
    and each request carries the child's exact merge length, never a
    pessimistic ``min(|U|, |V|)`` bound."""
    import repro.core.prepost as PP

    calls = []
    real = PP.NListPool.alloc_rows

    def spy(self, lengths):
        calls.append([int(v) for v in lengths])
        return real(self, lengths)

    monkeypatch.setattr(PP.NListPool, "alloc_rows", spy)
    db, minsup = _random_db(8, n_items=(9, 9), n_trans=(28, 30))
    out, stats = mine_prepost_device(db, minsup, pair_chunk=8)
    expected, _ = mine(db, minsup, "prepost", early_stop=True)
    assert out == expected
    n_children = sum(1 for s in out if len(s) >= 2)
    child_calls = calls[1:]                  # calls[0] = level-1 upload
    assert sum(len(c) for c in child_calls) == n_children
    assert stats.child_scatters == n_children
    assert stats.candidates > n_children     # some candidates died
    # every allocated length is a real (positive) merge result
    assert all(ln >= 1 for c in child_calls for ln in c)


def test_pool_growth_preserves_live_rows_bit_for_bit():
    rng = np.random.default_rng(0)
    pool = NListPool(capacity=64)
    cap0 = pool.capacity
    lens = [3, 8, 5, 1]
    rows = pool.alloc_rows(lens)
    arrays = [rng.integers(0, 100, (ln, 3)).astype(np.int32) for ln in lens]
    pool.write_rows(rows, arrays)
    before = [pool.read_row(r) for r in rows]
    # force growth well past the current capacity
    big = pool.alloc_rows([cap0, cap0])
    assert pool.grows >= 1 and pool.capacity > cap0
    for r, a, b in zip(rows, arrays, before, strict=True):
        assert np.array_equal(pool.read_row(r), a)
        assert np.array_equal(pool.read_row(r), b)
    pool.free_rows(big)


def test_pool_alloc_free_reuses_extents():
    pool = NListPool(capacity=64)
    r1 = pool.alloc_rows([5])          # bucket 8
    off1 = pool.offsets(r1)[0]
    live = pool.live_codes
    pool.free_rows(r1)
    assert pool.live_codes == live - 8
    r2 = pool.alloc_rows([7])          # same bucket: extent reused
    assert pool.offsets(r2)[0] == off1
    r3 = pool.alloc_rows([9])          # different bucket: fresh extent
    assert pool.offsets(r3)[0] != off1


def test_pad_len_power_of_two_fallback():
    """Past the largest tuned bucket, sizes fall back to powers of two
    instead of raising (the old ``_pad_len`` ValueError)."""
    top = NL_LEN_BUCKETS[-1]
    assert _pad_len(top) == top == nl_pad_len(top)
    assert _pad_len(top + 1) == 2 * top
    assert nl_pad_len(3 * top) == 4 * top
    assert nl_pad_len(1) == NL_LEN_BUCKETS[0]
    # the pool allocates oversized extents rather than dying
    pool = NListPool(capacity=64)
    rows = pool.alloc_rows([top + 1])
    assert pool.capacity >= 2 * top
    pool.free_rows(rows)


def test_cross_class_drain_batching_bounds_dispatches():
    """The frontier scheduler (ISSUE 4) batches pairs across classes:
    with a roomy pair_chunk the whole mine is a handful of drain-group
    dispatches, far below one per expanded class member (the pre-ISSUE-4
    dispatch pattern, which made deep DFS regions launch-latency-bound:
    compare ``device_calls`` 1021 -> single digits on the longpat smoke
    regime in benchmarks/baselines/BENCH_smoke.json)."""
    db, minsup = _random_db(5, n_items=(9, 9), n_trans=(28, 30))
    out, stats = mine_prepost_device(db, minsup, pair_chunk=8192)
    expected, _ = mine(db, minsup, "prepost", early_stop=True)
    assert out == expected
    # multi-member classes alone used to cost >= 1 dispatch each; the
    # drain-group count is bounded by the DFS wave structure instead
    assert stats.device_calls < stats.nodes / 4
    assert stats.device_calls <= 16


@pytest.mark.parametrize("es", [False, True])
def test_engine_matches_oracle_with_exact_counters(es):
    """Seeded end-to-end sweep (invariant I4 without hypothesis): result
    sets AND comparison counters equal the oracle's."""
    for seed in range(8):
        db, minsup = _random_db(seed)
        o_out, o_st = mine(db, minsup, "prepost", early_stop=es)
        d_out, d_st = mine_prepost_device(db, minsup, early_stop=es)
        assert d_out == o_out, (seed, es)
        assert d_st.comparisons == o_st.comparisons, (seed, es)
        assert d_st.es_checks == o_st.es_checks, (seed, es)
        assert d_st.es_aborts == o_st.es_aborts, (seed, es)
