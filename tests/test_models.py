"""Model semantics: decode==forward, prefill->decode, MoE invariants."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.transformer import (LMConfig, init_params, forward,
                                      prefill, init_cache, decode_step)
from repro.models import layers as L


def _decode_all(cfg, params, tokens, max_len=None):
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len or S)
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    out = []
    for i in range(S):
        lg, cache = step(params, tokens[:, i], cache)
        out.append(lg)
    return jnp.stack(out, 1), cache


CFGS = {
    "gqa-bias": LMConfig(name="t", n_layers=3, d_model=64, n_heads=4,
                         n_kv_heads=2, d_ff=128, vocab_size=101,
                         qkv_bias=True, tie_embeddings=True, attn_chunk=8,
                         dtype="float32", remat="none"),
    "mla": LMConfig(name="t2", n_layers=3, d_model=64, n_heads=4,
                    n_kv_heads=4, d_ff=128, vocab_size=101, mla=True,
                    q_lora=32, kv_lora=16, qk_nope_dim=16, qk_rope_dim=8,
                    v_head_dim=16, attn_chunk=8, dtype="float32",
                    remat="none"),
    "swa": LMConfig(name="t3", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=128, vocab_size=101,
                    sliding_window=8, attn_chunk=8, dtype="float32",
                    remat="none"),
    "moe": LMConfig(name="t4", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=128, vocab_size=101, moe=True,
                    n_experts=4, top_k=2, moe_d_ff=64, capacity_factor=8.0,
                    attn_chunk=8, dtype="float32", remat="none"),
}


@pytest.mark.parametrize("name", sorted(CFGS))
def test_decode_matches_forward(name):
    cfg = CFGS[name]
    rng = jax.random.PRNGKey(0)
    params, _ = init_params(jax.random.PRNGKey(1), cfg)
    tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    ref, _ = forward(params, cfg, tokens)
    dec, _ = _decode_all(cfg, params, tokens)
    scale = float(jnp.abs(ref).max())
    err = float(jnp.abs(dec - ref).max()) / max(scale, 1e-6)
    # MLA decode uses the ABSORBED formulation (different matmul
    # association): a few % relative drift at these tiny latent dims is
    # expected; greedy decisions must still agree exactly.
    tol = 3e-2 if name == "mla" else 3e-3
    assert err < tol, err
    agree = (dec.argmax(-1) == ref.argmax(-1)).mean()
    assert float(agree) > 0.98


@pytest.mark.parametrize("name", sorted(CFGS))
def test_prefill_then_decode_matches_forward(name):
    cfg = CFGS[name]
    rng = jax.random.PRNGKey(0)
    params, _ = init_params(jax.random.PRNGKey(2), cfg)
    tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    ref, _ = forward(params, cfg, tokens)
    lg, cache = jax.jit(
        lambda p, t: prefill(p, cfg, t, max_len=16))(params, tokens[:, :12])
    scale = float(jnp.abs(ref).max())
    errs = [float(jnp.abs(lg - ref[:, 11]).max()) / scale]
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    for i in range(12, 16):
        lg, cache = step(params, tokens[:, i], cache)
        errs.append(float(jnp.abs(lg - ref[:, i]).max()) / scale)
    assert max(errs) < (3e-2 if name == "mla" else 3e-3), errs


def test_remat_does_not_change_loss():
    from repro.models.transformer import loss_fn
    import dataclasses
    cfg = CFGS["gqa-bias"]
    params, _ = init_params(jax.random.PRNGKey(3), cfg)
    rng = jax.random.PRNGKey(4)
    tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    labels = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    vals = {}
    for remat in ("none", "dots", "full"):
        c = dataclasses.replace(cfg, remat=remat)
        (loss_v, _), g = jax.value_and_grad(
            lambda p: loss_fn(p, c, tokens, labels), has_aux=True)(params)
        vals[remat] = (float(loss_v), float(jnp.abs(
            jax.tree.leaves(g)[0]).sum()))
    assert vals["none"] == pytest.approx(vals["dots"], rel=1e-6)
    assert vals["none"] == pytest.approx(vals["full"], rel=1e-6)


def test_unroll_layers_matches_scan():
    import dataclasses
    cfg = CFGS["moe"]
    params, _ = init_params(jax.random.PRNGKey(5), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0, 101)
    a, _ = forward(params, cfg, tokens)
    b, _ = forward(params, dataclasses.replace(cfg, unroll_layers=True),
                   tokens)
    assert float(jnp.abs(a - b).max()) < 1e-5


def test_moe_group_invariance_without_drops():
    """With capacity high enough for zero drops, the grouped dispatch must
    be exact regardless of group count."""
    dims = L.MoEDims(d_model=32, n_experts=4, top_k=2, d_ff=16,
                     capacity_factor=16.0, dispatch_groups=1)
    rng = jax.random.PRNGKey(0)
    p, _ = L.moe_init(rng, dims, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    import dataclasses
    y1, _ = L.moe_apply(p, x, dims, compute_dtype=jnp.float32)
    y4, _ = L.moe_apply(p, x, dataclasses.replace(dims, dispatch_groups=4),
                        compute_dtype=jnp.float32)
    assert float(jnp.abs(y1 - y4).max()) < 1e-5


def test_moe_matches_dense_expert_sum():
    """Grouped sort-based MoE == explicit per-token expert mixture."""
    dims = L.MoEDims(d_model=16, n_experts=4, top_k=2, d_ff=8,
                     capacity_factor=16.0, dispatch_groups=2)
    p, _ = L.moe_init(jax.random.PRNGKey(0), dims, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))
    y, _ = L.moe_apply(p, x, dims, compute_dtype=jnp.float32)

    xt = x.reshape(-1, 16)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(4):
        g = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        ye = g @ p["w_down"][e]
        w = ((ids == e) * gates).sum(-1)
        ref = ref + ye * w[:, None]
    assert float(jnp.abs(y.reshape(-1, 16) - ref).max()) < 1e-4


def test_rmsnorm_custom_vjp_matches_autodiff():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))
    sc = jax.random.normal(jax.random.PRNGKey(1), (64,)) * 0.1 + 1.0

    def ref(x, sc):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, -1, keepdims=True)
        return (xf * jax.lax.rsqrt(var + 1e-5) * sc).astype(x.dtype)

    f = lambda x, sc: (L.rmsnorm({"scale": sc}, x) ** 2).sum()  # noqa: E731
    fr = lambda x, sc: (ref(x, sc) ** 2).sum()                  # noqa: E731
    gx, gs = jax.grad(f, (0, 1))(x, sc)
    rx, rs = jax.grad(fr, (0, 1))(x, sc)
    np.testing.assert_allclose(gx, rx, atol=1e-4)
    np.testing.assert_allclose(gs, rs, atol=1e-3)


def test_swa_ring_cache_bounded():
    cfg = CFGS["swa"]
    params, _ = init_params(jax.random.PRNGKey(7), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(8), (1, 24), 0, 101)
    _, cache = _decode_all(cfg, params, tokens, max_len=24)
    # ring cache never exceeds the window regardless of decode length
    assert cache["k"].shape[2] == cfg.sliding_window
    assert int(cache["len"][0]) == 24
