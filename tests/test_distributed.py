"""Distribution layer: sharding rules, compression, multi-device mining.

The multi-device pieces run in a subprocess with
``--xla_force_host_platform_device_count=8`` so the main pytest process
keeps the real single-device view.
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (DEFAULT_RULES, logical_spec,
                                        use_rules, divisibility_report)
from repro.distributed.compression import (quantize_int8, dequantize_int8,
                                           ErrorFeedback)


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_logical_spec_resolution():
    mesh = _mesh11()
    assert logical_spec(("batch", None, "act_ff"), mesh) == P(
        "data", None, "model")
    # unknown names replicate
    assert logical_spec(("nope_axis",), mesh) == P(None)
    # "pod" is dropped on a single-pod mesh
    spec = logical_spec(("batch",), mesh)
    assert spec == P("data")


def test_logical_spec_no_axis_reuse():
    mesh = _mesh11()
    with use_rules({"a1": "model", "a2": "model"}):
        spec = logical_spec(("a1", "a2"), mesh)
    assert spec == P("model", None)     # second use dropped


def test_use_rules_is_scoped():
    mesh = _mesh11()
    base = logical_spec(("kv_heads",), mesh)
    with use_rules({"kv_heads": None}):
        assert logical_spec(("kv_heads",), mesh) == P(None)
    assert logical_spec(("kv_heads",), mesh) == base


def test_divisibility_report():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    assert divisibility_report((16, 16), P("data", "model"), mesh) == []


def test_arch_rules_divisible_on_production_mesh():
    """Every param of every FULL arch config divides the 16x16 mesh under
    its rules (the xdeepfm CIN bug class)."""
    # run in subprocess: needs 512 devices? No — divisibility is pure math
    # on the mesh SHAPE; emulate with a fake mesh object.
    from repro.configs import REGISTRY

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    import repro.distributed.sharding as S
    for arch_id, spec in REGISTRY.items():
        if spec.family == "fim":
            continue
        with use_rules(spec.rules_override):
            pass  # rule resolution itself checked in dry-run tests
    # the real end-to-end divisibility proof is the dry-run compile; here
    # we just assert the registry is complete and consistent.
    assert len(REGISTRY) == 11


def test_int8_quant_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 64)) * 3, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.51 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
    res = ErrorFeedback.init(g)
    acc = jnp.zeros((32,))
    for _ in range(50):
        comp, res = ErrorFeedback.apply(g, res)
        acc = acc + comp["w"]
    # accumulated compressed grads ~ 50 * g (residual carries the error)
    np.testing.assert_allclose(np.asarray(acc) / 50,
                               np.asarray(g["w"]), atol=0.02)


MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import random
    import numpy as np
    import jax
    from repro.core.oracle import mine_bruteforce
    from repro.core.distributed import DistributedMiner, make_mining_round
    from repro.core.bitmap import popcount32_np

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = random.Random(7)
    for trial in range(4):
        n_items = rng.randint(4, 9)
        n_trans = rng.randint(10, 60)
        db = [[i for i in range(n_items) if rng.random() < 0.5]
              for _ in range(n_trans)]
        db = [t for t in db if t]
        minsup = rng.randint(2, max(2, len(db) // 3))
        bf = mine_bruteforce(db, minsup)
        for es in (False, True):
            m = DistributedMiner(mesh, early_stop=es, capacity=512,
                                 block_words=2)
            out, st = m.mine(db, minsup)
            assert out == bf, (trial, es)

    # mining_round on the multi-axis mesh matches a local computation
    round_fn = jax.jit(make_mining_round(mesh, pair_chunk=8))
    r = np.random.default_rng(0)
    store = r.integers(0, 2**32, (16, 8, 8), dtype=np.uint64
                       ).astype(np.uint32)
    pairs = np.stack([r.integers(0, 16, 16), r.integers(0, 16, 16)],
                     1).astype(np.int32)
    bound, counts = round_fn(store, pairs, np.zeros(16, np.int32))
    expect = popcount32_np(store[pairs[:, 0]] & store[pairs[:, 1]]
                           ).reshape(16, -1).sum(1)
    assert np.array_equal(np.asarray(counts), expect)
    assert (np.asarray(bound) >= expect).all()
    print("MULTI_DEVICE_OK")
""")


@pytest.mark.slow
def test_distributed_miner_multi_device():
    proc = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          cwd=".")
    assert "MULTI_DEVICE_OK" in proc.stdout, proc.stderr[-3000:]


def test_compressed_psum_int8_single_axis():
    """compressed_psum under shard_map on a 1-device mesh is identity-ish."""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from repro.distributed.compression import compressed_psum_int8

    mesh = _mesh11()
    x = jnp.linspace(-1, 1, 64).reshape(8, 8)

    @partial(shard_map, mesh=mesh, in_specs=P(None, None),
             out_specs=P(None, None))
    def f(x):
        return compressed_psum_int8(x, "data")

    y = f(x)
    assert float(jnp.abs(y - x).max()) < 1e-2


CROSSPOD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed.compression import compressed_crosspod_allreduce

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    g = {"w": jnp.linspace(-2, 2, 256).reshape(16, 16),
         "b": jnp.ones((16,)) * 0.5}
    out = compressed_crosspod_allreduce(g, mesh)
    # replicated input -> mean across pods == input (within int8 error)
    for k in g:
        err = float(jnp.abs(out[k] - g[k]).max())
        assert err < 0.05, (k, err)
    print("CROSSPOD_OK")
""")


@pytest.mark.slow
def test_compressed_crosspod_allreduce_multipod():
    proc = subprocess.run([sys.executable, "-c", CROSSPOD_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          cwd=".")
    assert "CROSSPOD_OK" in proc.stdout, proc.stderr[-2000:]
