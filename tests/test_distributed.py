"""Distribution layer: sharding rules, compression, multi-device mining.

The multi-device pieces run in a subprocess with
``--xla_force_host_platform_device_count=8`` so the main pytest process
keeps the real single-device view.  Mesh construction goes through
``repro.compat`` (JAX-version shim — the supported floor 0.4.30 has
neither ``jax.sharding.AxisType`` nor ``get_abstract_mesh``).
"""

import random
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.distributed.sharding import (logical_spec, use_rules,
                                        divisibility_report)
from repro.distributed.compression import (quantize_int8, dequantize_int8,
                                           ErrorFeedback)


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def test_logical_spec_resolution():
    mesh = _mesh11()
    assert logical_spec(("batch", None, "act_ff"), mesh) == P(
        "data", None, "model")
    # unknown names replicate
    assert logical_spec(("nope_axis",), mesh) == P(None)
    # "pod" is dropped on a single-pod mesh
    spec = logical_spec(("batch",), mesh)
    assert spec == P("data")


def test_logical_spec_no_axis_reuse():
    mesh = _mesh11()
    with use_rules({"a1": "model", "a2": "model"}):
        spec = logical_spec(("a1", "a2"), mesh)
    assert spec == P("model", None)     # second use dropped


def test_use_rules_is_scoped():
    mesh = _mesh11()
    base = logical_spec(("kv_heads",), mesh)
    with use_rules({"kv_heads": None}):
        assert logical_spec(("kv_heads",), mesh) == P(None)
    assert logical_spec(("kv_heads",), mesh) == base


def test_divisibility_report():
    mesh = _mesh11()
    assert divisibility_report((16, 16), P("data", "model"), mesh) == []


def test_mesh_compat_shim(monkeypatch):
    """The version shim must keep working on newer JAX where AxisType /
    get_abstract_mesh exist: strip them and assert the fallbacks engage
    (on JAX < 0.5 this exercises the one production path)."""
    from repro import compat

    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    monkeypatch.delattr(jax.sharding, "get_abstract_mesh", raising=False)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert tuple(mesh.axis_names) == ("data", "model")
    assert compat.get_abstract_mesh() is None
    # rules resolution (distributed/sharding.py) survives the absence
    assert logical_spec(("batch",), mesh) == P("data")
    assert logical_spec(("batch",), None) == P(None)
    # oldest floor: no jax.make_mesh at all -> mesh_utils fallback
    monkeypatch.delattr(jax, "make_mesh", raising=False)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert tuple(mesh.axis_names) == ("data", "model")


def test_arch_rules_divisible_on_production_mesh():
    """Every param of every FULL arch config divides the 16x16 mesh under
    its rules (the xdeepfm CIN bug class)."""
    # run in subprocess: needs 512 devices? No — divisibility is pure math
    # on the mesh SHAPE; emulate with a fake mesh object.
    from repro.configs import REGISTRY

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for _arch_id, spec in REGISTRY.items():
        if spec.family == "fim":
            continue
        with use_rules(spec.rules_override):
            pass  # rule resolution itself checked in dry-run tests
    # the real end-to-end divisibility proof is the dry-run compile; here
    # we just assert the registry is complete and consistent.
    assert len(REGISTRY) == 11


def test_int8_quant_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 64)) * 3, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.51 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
    res = ErrorFeedback.init(g)
    acc = jnp.zeros((32,))
    for _ in range(50):
        comp, res = ErrorFeedback.apply(g, res)
        acc = acc + comp["w"]
    # accumulated compressed grads ~ 50 * g (residual carries the error)
    np.testing.assert_allclose(np.asarray(acc) / 50,
                               np.asarray(g["w"]), atol=0.02)


# ---------------------------------------------------------------------------
# Unified distributed miner (ISSUE 2): shared DeviceRowStore + one fused
# shard_map dispatch per pair chunk
# ---------------------------------------------------------------------------


def _random_db(seed, n_items=(4, 9), n_trans=(10, 60)):
    rng = random.Random(seed)
    ni = rng.randint(*n_items)
    nt = rng.randint(*n_trans)
    db = [[i for i in range(ni) if rng.random() < 0.5] for _ in range(nt)]
    db = [t for t in db if t] or [[0]]
    minsup = rng.randint(2, max(2, len(db) // 3))
    return db, minsup


@pytest.mark.parametrize("mode", ["and", "andnot"])
@pytest.mark.parametrize("early_stop", [False, True])
def test_fused_sharded_dispatch_matches_ref(early_stop, mode):
    """ops.make_screen_and_intersect_sharded == kernels.ref oracle,
    bit-exact across minsup values, the in-dispatch ES flag and both
    representations (tidset "and" / diffset "andnot", ISSUE 6) — 1
    shard here; the 8-shard version runs in the subprocess test below."""
    from repro.core.bitmap import popcount32_np
    from repro.core.rowstore import DeviceRowStore
    from repro.kernels import ops, ref

    mesh = _mesh11()
    r = np.random.default_rng(3)
    rows_np = r.integers(0, 2 ** 32, (16, 4, 4), dtype=np.uint64
                         ).astype(np.uint32)
    n = 12
    ua = r.integers(0, 16, n).astype(np.int32)
    vb = r.integers(0, 16, n).astype(np.int32)
    slots = np.arange(16, 16 + n, dtype=np.int32)
    if mode == "and":
        rho = r.integers(0, 100, n).astype(np.int32)
    else:
        # diffset invariant: |U & ~V| <= |U| = rho, so support >= 0
        rho = popcount32_np(rows_np).reshape(16, -1).sum(1).astype(
            np.int32)[ua]

    fused = ops.make_screen_and_intersect_sharded(
        mesh, tid_axes=("data", "model"), mode=mode,
        early_stop=early_stop)
    for minsup in (0, 8, 40, 200):
        store = DeviceRowStore(rows_np, capacity=32, mesh=mesh)
        rows0 = np.asarray(store.rows)
        suf0 = np.asarray(store.suffix)
        er, esuf, eb, ec, ebl, eal = ref.screen_and_intersect_sharded_ref(
            rows0, suf0, ua, vb, slots, rho, jnp.int32(minsup),
            n_shards=store.n_shards, mode=mode, early_stop=early_stop)
        gr, gs, gb, gc, gbl, gal = fused(store.rows, store.suffix, ua, vb,
                                         slots, rho, minsup)
        key = (early_stop, mode, minsup)
        assert np.array_equal(np.asarray(gb), np.asarray(eb)), key
        assert np.array_equal(np.asarray(gc), np.asarray(ec)), key
        assert np.array_equal(np.asarray(gbl), np.asarray(ebl)), key
        assert np.array_equal(np.asarray(gal), np.asarray(eal)), key
        assert np.array_equal(np.asarray(gr), np.asarray(er)), key
        assert np.array_equal(np.asarray(gs), np.asarray(esuf)), key
        # screen soundness for alive pairs (dead counts are frozen
        # partials): "and" bounds the count from above, "andnot" bounds
        # the support rho - count from above
        gb_, gc_, gal_ = np.asarray(gb), np.asarray(gc), np.asarray(gal)
        if mode == "and":
            assert (gb_[gal_] >= gc_[gal_]).all(), key
        else:
            assert (gb_[gal_] >= (rho - gc_)[gal_]).all(), key
        if not early_stop:
            assert np.asarray(gal).all(), key
            gbl_ = np.asarray(gbl)
            if mode == "and":
                # ES off: every pair walks every local block, all shards
                assert (gbl_ == store.n_blocks).all(), key
            else:
                # diffset work counter is skip-aware even with ES off:
                # only visited blocks with positive U mass are charged
                mass = popcount32_np(rows0).sum(axis=2)
                assert np.array_equal(gbl_, (mass[ua] > 0).sum(1)), key


def test_sharded_row_store_grow_preserves_sharding_and_contents():
    from repro.core.rowstore import DeviceRowStore, _local_suffix_tables

    mesh = _mesh11()
    tid_spec = ("data", "model")
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 2 ** 32, (3, 2, 4), dtype=np.uint64
                        ).astype(np.uint32)
    store = DeviceRowStore(rows, capacity=4, mesh=mesh)
    # block axis padded to a multiple of the shard count
    assert store.n_blocks % store.n_shards == 0
    cap0 = store.capacity
    expected_rows = NamedSharding(mesh, P(None, tid_spec, None))
    expected_suffix = NamedSharding(mesh, P(None, tid_spec))
    assert store.rows.sharding == expected_rows
    assert store.suffix.sharding == expected_suffix
    big = store.alloc(cap0)
    assert store.capacity > cap0 and store.grows == 1
    # sharding survives growth; contents + suffix layout preserved
    assert store.rows.sharding == expected_rows
    assert store.suffix.sharding == expected_suffix
    padded = np.zeros((3, store.n_blocks, 4), np.uint32)
    padded[:, :2] = rows
    assert np.array_equal(np.asarray(store.rows[:3]), padded)
    assert np.array_equal(np.asarray(store.suffix[:3]),
                          _local_suffix_tables(padded, store.n_shards))
    store.free(big)


def test_unified_miner_one_fused_dispatch_per_chunk(monkeypatch):
    """Mirror of test_fused_engine.py's dispatch guard: every pair chunk
    is exactly ONE fused shard_map dispatch; no separate screen / count /
    materialize program exists or is called."""
    import repro.core.distributed as D
    from repro.core.oracle import mine
    from repro.kernels import ops

    for name in ("make_round_fns", "screen_round", "count_round",
                 "materialize_rep"):
        assert not hasattr(D, name), f"legacy round program {name} back"

    calls = {"fused": 0}
    real_maker = ops.make_screen_and_intersect_sharded

    def counting_maker(mesh, **kw):
        fn = real_maker(mesh, **kw)

        def wrapper(*a, **k):
            calls["fused"] += 1
            return fn(*a, **k)

        return wrapper

    def forbidden(*a, **k):
        raise AssertionError("single-device / legacy dispatch used")

    monkeypatch.setattr(ops, "make_screen_and_intersect_sharded",
                        counting_maker)
    monkeypatch.setattr(ops, "screen_and_intersect", forbidden)
    monkeypatch.setattr(ops, "screen_pairs", forbidden)
    monkeypatch.setattr(ops, "bitmap_intersect_es", forbidden)
    monkeypatch.setattr(ops, "bitmap_intersect_full", forbidden)

    db, minsup = _random_db(3, n_items=(8, 8), n_trans=(25, 30))
    m = D.DistributedMiner(_mesh11(), early_stop=True, block_words=1,
                           pair_chunk=4)
    out, stats = m.mine(db, minsup)
    assert calls["fused"] == stats.device_calls
    assert stats.device_calls >= 2     # small pair_chunk forces chunking
    expected, _ = mine(db, minsup, "eclat", early_stop=True)
    assert out == expected


@pytest.mark.parametrize("es", [False, True])
def test_unified_miner_matches_oracle_single_device(es):
    from repro.core.distributed import DistributedMiner
    from repro.core.eclat import BitmapMiner
    from repro.core.oracle import mine

    mesh = _mesh11()
    for seed in range(6):
        db, minsup = _random_db(seed)
        expected, _ = mine(db, minsup, "eclat", early_stop=es)
        out, stats = DistributedMiner(mesh, early_stop=es, capacity=512,
                                      block_words=2).mine(db, minsup)
        assert out == expected, (seed, es)
        # work + scatter telemetry is engine-invariant (ISSUE 5): the
        # non-ES work baseline comes from the REAL block count and the
        # survivor-only scatter count equals the frequent children
        _, st1 = BitmapMiner(scheme="eclat", early_stop=es,
                             block_words=2).mine(db, minsup)
        assert stats.word_ops_full == st1.word_ops_full, (seed, es)
        n_children = sum(1 for s in out if len(s) >= 2)
        assert stats.child_scatters == st1.child_scatters == n_children
        assert stats.scatter_words == st1.scatter_words, (seed, es)
        if es:
            # the distributed screen is attributed, even single-block
            assert stats.screened_out >= 0
            assert stats.candidates >= stats.screened_out


MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import random
    import numpy as np
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    from repro.core.oracle import mine_bruteforce
    from repro.core.distributed import DistributedMiner, make_mining_round
    from repro.core.rowstore import DeviceRowStore, _local_suffix_tables
    from repro.core.bitmap import popcount32_np
    from repro.kernels import ops, ref

    assert jax.device_count() == 8
    mesh = make_mesh((4, 2), ("data", "model"))

    # unified miner == oracle on 8 devices, ES on/off, ONE fused dispatch
    # per pair chunk (wrapped counter vs stats.device_calls); work and
    # scatter telemetry must be shard-count invariant (ISSUE 5):
    # word_ops_full from the REAL block count (the 8-shard store pads
    # its block axis, which used to inflate it) and child_scatters ==
    # frequent children, equal to the single-device run on the same DB
    from repro.core.eclat import BitmapMiner
    rng = random.Random(7)
    nonzero_wof = 0
    for trial in range(4):
        n_items = rng.randint(4, 9)
        n_trans = rng.randint(10, 60)
        db = [[i for i in range(n_items) if rng.random() < 0.5]
              for _ in range(n_trans)]
        db = [t for t in db if t]
        minsup = rng.randint(2, max(2, len(db) // 3))
        bf = mine_bruteforce(db, minsup)
        n_children = sum(1 for s in bf if len(s) >= 2)
        for es in (False, True):
            m = DistributedMiner(mesh, early_stop=es, capacity=512,
                                 block_words=2)
            calls = [0]
            inner = m._fused
            def counted(*a, _i=inner, _c=calls, **k):
                _c[0] += 1
                return _i(*a, **k)
            m._fused = counted
            out, st = m.mine(db, minsup)
            assert out == bf, (trial, es)
            assert calls[0] == st.device_calls >= 1, (trial, es)
            assert st.child_scatters == n_children, (trial, es)
            _, st1 = BitmapMiner(scheme="eclat", early_stop=es,
                                 block_words=2).mine(db, minsup)
            assert st.word_ops_full == st1.word_ops_full, (trial, es)
            assert st.child_scatters == st1.child_scatters, (trial, es)
            assert st.scatter_words == st1.scatter_words, (trial, es)
            # the numerator is unpadded too: ES off scans exactly the
            # real blocks, ES on never scans more (saved_frac >= 0)
            if es:
                assert st.word_ops <= st.word_ops_full, (trial, es)
                assert st.word_ops_saved_frac >= 0.0, (trial, es)
            else:
                assert st.word_ops == st.word_ops_full, (trial, es)
            nonzero_wof += st.word_ops_full > 0
    assert nonzero_wof > 0      # the padding bug would have inflated these

    # density-adaptive representation switching (ISSUE 6) on 8 shards:
    # declat and adaptive miners match the bruteforce oracle exactly and
    # every pair chunk is still ONE fused dispatch ("and" + "andnot"
    # wrappers together account for all device calls)
    for trial in range(2):
        n_items = rng.randint(5, 8)
        n_trans = rng.randint(20, 50)
        db = [[i for i in range(n_items) if rng.random() < 0.6]
              for _ in range(n_trans)]
        db = [t for t in db if t]
        minsup = rng.randint(2, max(2, len(db) // 3))
        bf = mine_bruteforce(db, minsup)
        for scheme, dd in (("declat", None), ("adaptive", 0.3)):
            for es in (False, True):
                m = DistributedMiner(mesh, early_stop=es, capacity=512,
                                     block_words=2, scheme=scheme,
                                     diff_density=dd,
                                     diff_hysteresis=0.1)
                calls = [0]
                for attr in ("_fused", "_fused_diff"):
                    def counted(*a, _i=getattr(m, attr), _c=calls, **k):
                        _c[0] += 1
                        return _i(*a, **k)
                    setattr(m, attr, counted)
                out, st = m.mine(db, minsup)
                assert out == bf, (trial, scheme, es)
                assert calls[0] == st.device_calls >= 1, (trial, scheme, es)

    # fused dispatch is bit-exact against the 8-shard ref oracle,
    # in-dispatch shard-local ES on and off, both representations
    r = np.random.default_rng(0)
    rows_np = r.integers(0, 2**32, (16, 8, 4), dtype=np.uint64
                         ).astype(np.uint32)
    ua = r.integers(0, 16, 12).astype(np.int32)
    vb = r.integers(0, 16, 12).astype(np.int32)
    slots = np.arange(16, 28, dtype=np.int32)
    rho_and = r.integers(0, 100, 12).astype(np.int32)
    rho_diff = popcount32_np(rows_np).reshape(16, -1).sum(1).astype(
        np.int32)[ua]
    for mode, rho in (("and", rho_and), ("andnot", rho_diff)):
        for es in (False, True):
            for minsup in (0, 64, 400):
                store = DeviceRowStore(rows_np, capacity=32, mesh=mesh)
                assert store.n_shards == 8
                rows0, suf0 = np.asarray(store.rows), np.asarray(store.suffix)
                er, esuf, eb, ec, ebl, eal = ref.screen_and_intersect_sharded_ref(
                    rows0, suf0, ua, vb, slots, rho, np.int32(minsup),
                    n_shards=8, mode=mode, early_stop=es)
                fused = ops.make_screen_and_intersect_sharded(
                    mesh, tid_axes=("data", "model"), mode=mode,
                    early_stop=es)
                gr, gs, gb, gc, gbl, gal = fused(
                    store.rows, store.suffix, ua, vb, slots, rho, minsup)
                key = (mode, es, minsup)
                assert np.array_equal(np.asarray(gb), np.asarray(eb)), key
                assert np.array_equal(np.asarray(gc), np.asarray(ec)), key
                assert np.array_equal(np.asarray(gbl), np.asarray(ebl)), key
                assert np.array_equal(np.asarray(gal), np.asarray(eal)), key
                assert np.array_equal(np.asarray(gr), np.asarray(er)), key
                assert np.array_equal(np.asarray(gs), np.asarray(esuf)), key

    # sharded slab growth preserves the NamedSharding + contents
    store2 = DeviceRowStore(rows_np, capacity=32, mesh=mesh)
    cap0 = store2.capacity
    big = store2.alloc(cap0)
    assert store2.grows == 1
    assert store2.rows.sharding == NamedSharding(
        mesh, P(None, ("data", "model"), None))
    assert np.array_equal(np.asarray(store2.rows[:16]), rows_np)
    assert np.array_equal(np.asarray(store2.suffix[:16]),
                          _local_suffix_tables(rows_np, 8))

    # compaction SHRINKS the sharded slab back, preserving sharding,
    # live contents bit-for-bit, and remapping slots densely
    store2.free(big)
    before_rows = np.asarray(store2.rows[:16])
    before_suf = np.asarray(store2.suffix[:16])
    mapping = store2.compact(reserve=4)
    assert store2.capacity < cap0 * 2 and store2.compactions == 1
    assert store2.rows.sharding == NamedSharding(
        mesh, P(None, ("data", "model"), None))
    new_ids = mapping[np.arange(16)]
    assert (new_ids >= 0).all()
    assert np.array_equal(np.asarray(store2.rows)[new_ids], before_rows)
    assert np.array_equal(np.asarray(store2.suffix)[new_ids], before_suf)

    # mining_round on the multi-axis mesh matches a local computation
    round_fn = jax.jit(make_mining_round(mesh, pair_chunk=8))
    store = r.integers(0, 2**32, (16, 8, 8), dtype=np.uint64
                       ).astype(np.uint32)
    pairs = np.stack([r.integers(0, 16, 16), r.integers(0, 16, 16)],
                     1).astype(np.int32)
    bound, counts = round_fn(store, pairs, np.zeros(16, np.int32))
    expect = popcount32_np(store[pairs[:, 0]] & store[pairs[:, 1]]
                           ).reshape(16, -1).sum(1)
    assert np.array_equal(np.asarray(counts), expect)
    assert (np.asarray(bound) >= expect).all()
    print("MULTI_DEVICE_OK")
""")


@pytest.mark.slow
def test_distributed_miner_multi_device():
    proc = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          cwd=".")
    assert "MULTI_DEVICE_OK" in proc.stdout, proc.stderr[-3000:]


def test_compressed_psum_int8_single_axis():
    """compressed_psum under shard_map on a 1-device mesh is identity-ish."""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from repro.distributed.compression import compressed_psum_int8

    mesh = _mesh11()
    x = jnp.linspace(-1, 1, 64).reshape(8, 8)

    @partial(shard_map, mesh=mesh, in_specs=P(None, None),
             out_specs=P(None, None))
    def f(x):
        return compressed_psum_int8(x, "data")

    y = f(x)
    assert float(jnp.abs(y - x).max()) < 1e-2


CROSSPOD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.compat import make_mesh
    from repro.distributed.compression import compressed_crosspod_allreduce

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    g = {"w": jnp.linspace(-2, 2, 256).reshape(16, 16),
         "b": jnp.ones((16,)) * 0.5}
    out = compressed_crosspod_allreduce(g, mesh)
    # replicated input -> mean across pods == input (within int8 error)
    for k in g:
        err = float(jnp.abs(out[k] - g[k]).max())
        assert err < 0.05, (k, err)
    print("CROSSPOD_OK")
""")


@pytest.mark.slow
def test_compressed_crosspod_allreduce_multipod():
    proc = subprocess.run([sys.executable, "-c", CROSSPOD_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          cwd=".")
    assert "CROSSPOD_OK" in proc.stdout, proc.stderr[-2000:]
