"""Correctness of the paper's algorithms + the device engines.

Invariants (paper §III-IV):
  I1  every miner x {ES on/off} returns exactly the frequent itemsets;
  I2  early stopping NEVER changes the result set (the criterion is exact);
  I3  ES never increases the comparison count (paper's guarantee);
  I4  the device PrePost+ comparison counts equal the oracle's exactly;
  I5  bitmap engines agree with the oracle bit-for-bit.
"""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.oracle import (mine, mine_bruteforce, MINERS)
from repro.core.eclat import mine_bitmap
from repro.core.prepost import mine_prepost_device

PAPER_DB = [list(t) for t in
            ["ade", "bcd", "ace", "acde", "ae", "acd", "bc", "acde",
             "bce", "ade"]]


# ---------------------------------------------------------------------------
# the paper's running example (Table I, minSup=3, 15 frequent itemsets)
# ---------------------------------------------------------------------------

def test_paper_example_bruteforce():
    out = mine_bruteforce(PAPER_DB, 3)
    assert len(out) == 15
    assert out[frozenset("ac")] == 4
    assert out[frozenset("dac")] == 3
    assert out[frozenset("ec")] == 4          # Example 4.1
    assert frozenset("bd") not in out         # Examples 3.1 / 4.2


@pytest.mark.parametrize("scheme", sorted(MINERS))
@pytest.mark.parametrize("es", [False, True])
def test_paper_example_all_schemes(scheme, es):
    expected = mine_bruteforce(PAPER_DB, 3)
    out, stats = mine(PAPER_DB, 3, scheme, early_stop=es)
    assert out == expected
    assert stats.nodes == 15


@pytest.mark.parametrize("scheme", sorted(MINERS))
def test_es_reduces_comparisons(scheme):
    _, std = mine(PAPER_DB, 3, scheme, early_stop=False)
    _, es = mine(PAPER_DB, 3, scheme, early_stop=True)
    assert es.comparisons <= std.comparisons          # I3
    assert es.es_aborts > 0                           # ES actually fired


# ---------------------------------------------------------------------------
# property-based: random DBs, all miners agree with brute force
# ---------------------------------------------------------------------------

@st.composite
def small_db(draw):
    n_items = draw(st.integers(3, 8))
    n_trans = draw(st.integers(3, 24))
    dens = draw(st.sampled_from([0.25, 0.5, 0.75]))
    rng = random.Random(draw(st.integers(0, 2 ** 31)))
    db = [[i for i in range(n_items) if rng.random() < dens]
          for _ in range(n_trans)]
    db = [t for t in db if t]
    if not db:
        db = [[0]]
    minsup = draw(st.integers(1, max(1, len(db) // 2)))
    return db, minsup


@settings(max_examples=30, deadline=None)
@given(small_db())
def test_oracles_match_bruteforce(case):
    db, minsup = case
    expected = mine_bruteforce(db, minsup)
    for scheme in MINERS:
        for es in (False, True):
            out, _ = mine(db, minsup, scheme, early_stop=es)
            assert out == expected, (scheme, es, minsup)   # I1, I2


@settings(max_examples=15, deadline=None)
@given(small_db())
def test_bitmap_engines_match_bruteforce(case):
    db, minsup = case
    expected = mine_bruteforce(db, minsup)
    for scheme in ("eclat", "declat"):
        for es in (False, True):
            out, _ = mine_bitmap(db, minsup, scheme=scheme, early_stop=es,
                                 block_words=8)
            assert out == expected, (scheme, es)           # I5


@settings(max_examples=15, deadline=None)
@given(small_db())
def test_device_prepost_matches_oracle_exactly(case):
    db, minsup = case
    for es in (False, True):
        o_out, o_stats = mine(db, minsup, "prepost", early_stop=es)
        d_out, d_stats = mine_prepost_device(db, minsup, early_stop=es)
        assert d_out == o_out
        assert d_stats.comparisons == o_stats.comparisons   # I4
        assert d_stats.es_aborts == o_stats.es_aborts


@settings(max_examples=15, deadline=None)
@given(small_db())
def test_es_never_increases_comparisons_property(case):
    db, minsup = case
    for scheme in MINERS:
        _, std = mine(db, minsup, scheme, early_stop=False)
        _, es = mine(db, minsup, scheme, early_stop=True)
        assert es.comparisons <= std.comparisons, scheme


def test_bitmap_word_ops_savings_on_sparse_data():
    """The paper's headline effect: sparse, high candidate/node-ratio data
    shows large ES work savings in the device engine."""
    from repro.data import make_dataset
    db, minsups = make_dataset("retail-like")
    out_es, st_es = mine_bitmap(db, minsups[2], "eclat", True, block_words=8)
    out_no, st_no = mine_bitmap(db, minsups[2], "eclat", False,
                                block_words=8)
    assert out_es == out_no
    assert st_es.word_ops < st_no.word_ops
    assert st_es.word_ops_saved_frac > 0.15
    assert st_es.kernel_aborts > 0 and st_es.screened_out > 0


@settings(max_examples=10, deadline=None)
@given(small_db())
def test_block_granularity_invariance(case):
    """ES block size changes WORK, never RESULTS: any block_words gives
    the identical frequent-itemset dict (the bound is exact at every
    granularity)."""
    db, minsup = case
    ref = None
    for bw in (1, 4, 16):
        out, _ = mine_bitmap(db, minsup, "eclat", early_stop=True,
                             block_words=bw)
        if ref is None:
            ref = out
        assert out == ref, bw


def test_distributed_screen_bound_tighter_than_central():
    """Sum of per-shard minima <= min of sums: the distributed screen is
    at least as tight as the centralized bound (DESIGN.md §2.4)."""
    import numpy as np
    from repro.core.bitmap import popcount32_np
    rng = np.random.default_rng(0)
    u = rng.integers(0, 2**32, (8, 16), dtype=np.uint64).astype(np.uint32)
    v = rng.integers(0, 2**32, (8, 16), dtype=np.uint64).astype(np.uint32)
    # two shards of 4 blocks each; bound from block 1.. (suffix after blk0)
    cu = popcount32_np(u).reshape(2, 4, -1).sum(-1)
    cv = popcount32_np(v).reshape(2, 4, -1).sum(-1)
    local = sum(min(cu[s, 1:].sum(), cv[s, 1:].sum()) for s in range(2))
    central = min(cu[:, 1:].sum(), cv[:, 1:].sum())
    assert local <= central
