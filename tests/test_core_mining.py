"""The paper's running example + headline-effect checks.

The invariants I1-I5 (every miner x {ES on/off} x backend == brute
force, ES never increases comparisons, device PrePost+ counters equal
the oracle's) are pinned by the property-based cross-engine harness in
tests/test_equivalence.py; this module keeps the paper's worked example
(Table I / Examples 3.1-4.2) and the qualitative ES-savings claims.
"""

import pytest

from repro.core.oracle import (mine, mine_bruteforce, MINERS)
from repro.core.eclat import mine_bitmap

PAPER_DB = [list(t) for t in
            ["ade", "bcd", "ace", "acde", "ae", "acd", "bc", "acde",
             "bce", "ade"]]


# ---------------------------------------------------------------------------
# the paper's running example (Table I, minSup=3, 15 frequent itemsets)
# ---------------------------------------------------------------------------

def test_paper_example_bruteforce():
    out = mine_bruteforce(PAPER_DB, 3)
    assert len(out) == 15
    assert out[frozenset("ac")] == 4
    assert out[frozenset("dac")] == 3
    assert out[frozenset("ec")] == 4          # Example 4.1
    assert frozenset("bd") not in out         # Examples 3.1 / 4.2


@pytest.mark.parametrize("scheme", sorted(MINERS))
@pytest.mark.parametrize("es", [False, True])
def test_paper_example_all_schemes(scheme, es):
    expected = mine_bruteforce(PAPER_DB, 3)
    out, stats = mine(PAPER_DB, 3, scheme, early_stop=es)
    assert out == expected
    assert stats.nodes == 15


@pytest.mark.parametrize("scheme", sorted(MINERS))
def test_es_reduces_comparisons(scheme):
    _, std = mine(PAPER_DB, 3, scheme, early_stop=False)
    _, es = mine(PAPER_DB, 3, scheme, early_stop=True)
    assert es.comparisons <= std.comparisons          # I3
    assert es.es_aborts > 0                           # ES actually fired


def test_bitmap_word_ops_savings_on_sparse_data():
    """The paper's headline effect: sparse, high candidate/node-ratio data
    shows large ES work savings in the device engine."""
    from repro.data import make_dataset
    db, minsups = make_dataset("retail-like")
    out_es, st_es = mine_bitmap(db, minsups[2], "eclat", True, block_words=8)
    out_no, st_no = mine_bitmap(db, minsups[2], "eclat", False,
                                block_words=8)
    assert out_es == out_no
    assert st_es.word_ops < st_no.word_ops
    assert st_es.word_ops_saved_frac > 0.15
    assert st_es.kernel_aborts > 0 and st_es.screened_out > 0


def test_distributed_screen_bound_tighter_than_central():
    """Sum of per-shard minima <= min of sums: the distributed screen is
    at least as tight as the centralized bound (DESIGN.md §2.4)."""
    import numpy as np
    from repro.core.bitmap import popcount32_np
    rng = np.random.default_rng(0)
    u = rng.integers(0, 2**32, (8, 16), dtype=np.uint64).astype(np.uint32)
    v = rng.integers(0, 2**32, (8, 16), dtype=np.uint64).astype(np.uint32)
    # two shards of 4 blocks each; bound from block 1.. (suffix after blk0)
    cu = popcount32_np(u).reshape(2, 4, -1).sum(-1)
    cv = popcount32_np(v).reshape(2, 4, -1).sum(-1)
    local = sum(min(cu[s, 1:].sum(), cv[s, 1:].sum()) for s in range(2))
    central = min(cu[:, 1:].sum(), cv[:, 1:].sum())
    assert local <= central
