"""Data pipeline: determinism, statistical regimes, sampler validity."""

import numpy as np

from repro.data import make_dataset, DATASET_REPLICAS
from repro.data.transactions import gen_quest
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.data.graph_data import (gen_powerlaw_graph, NeighborSampler,
                                   gen_batched_molecules)
from repro.data import recsys_data as RD


def test_datasets_deterministic():
    for name in ("t40-like", "chess-like"):
        a, ma = make_dataset(name, seed=3)
        b, mb = make_dataset(name, seed=3)
        assert a == b and ma == mb
        c, _ = make_dataset(name, seed=4)
        assert a != c


def test_dataset_regimes():
    """Dense replicas: fixed-length transactions (one item per column);
    sparse replicas: variable-length."""
    dense, _ = make_dataset("chess-like")
    lens = {len(t) for t in dense}
    assert len(lens) == 1
    sparse, _ = make_dataset("retail-like")
    assert len({len(t) for t in sparse}) > 5


def test_all_replicas_generate():
    for name in DATASET_REPLICAS:
        db, minsups = make_dataset(name)
        assert len(db) > 100
        assert len(minsups) == 4
        assert minsups == sorted(minsups)


def test_quest_items_sorted_unique():
    db = gen_quest(n_trans=100, seed=1)
    for t in db:
        assert t == sorted(set(t))


def test_lm_data_reproducible_and_bigram_structure():
    cfg = LMDataConfig(vocab_size=100, batch=4, seq_len=64, seed=0,
                       bigram_weight=0.9)
    ds = SyntheticLM(cfg)
    t1, l1 = ds.batch(5)
    t2, l2 = ds.batch(5)
    assert np.array_equal(t1, t2)
    # labels are next tokens
    assert np.array_equal(t1[:, 1:], l1[:, :-1])
    # bigram structure: successor map hit rate ~ bigram_weight
    succ = ds._succ
    hits = (succ[t1[:, :-1]] == t1[:, 1:]).mean()
    assert hits > 0.7


def test_neighbor_sampler_validity():
    g = gen_powerlaw_graph(200, 5.0, 8, 4, seed=0)
    s = NeighborSampler(g.edge_src, g.edge_dst, 200, seed=0)
    seeds = np.arange(32)
    (x0, x1, x2), (m1, m2) = s.sample_batch(seeds, (5, 3), g.x)
    assert x0.shape == (32, 8)
    assert x1.shape == (32, 5, 8)
    assert x2.shape == (32, 5, 3, 8)
    nbrs, mask = s.sample_hop(seeds, 5)
    # every masked-in neighbor must be a real in-neighbor
    adj = {}
    for src, dst in zip(g.edge_src, g.edge_dst, strict=True):
        adj.setdefault(int(dst), set()).add(int(src))
    for i, seed in enumerate(seeds):
        for j in range(5):
            if mask[i, j]:
                assert int(nbrs[i, j]) in adj.get(int(seed), set())


def test_isolated_nodes_get_self_loops_masked_out():
    # node 199 with no in-edges
    src = np.zeros(10, np.int32)
    dst = np.ones(10, np.int32)
    s = NeighborSampler(src, dst, 200, seed=0)
    nbrs, mask = s.sample_hop(np.array([199]), 4)
    assert not mask.any()
    assert (nbrs == 199).all()


def test_molecule_batch_disjoint():
    g = gen_batched_molecules(4, 10, 16, 8, 3, seed=0)
    assert g.x.shape == (40, 8)
    for i in range(4):
        lo, hi = i * 10, (i + 1) * 10
        sel = (g.edge_src >= lo) & (g.edge_src < hi)
        assert ((g.edge_dst[sel] >= lo) & (g.edge_dst[sel] < hi)).all()


def test_recsys_batches():
    b = RD.sasrec_batch(0, 8, 20, 1000, 5)
    assert b["seq_ids"].shape == (8, 20)
    assert b["neg_ids"].shape == (8, 20, 5)
    assert (b["seq_ids"] >= 0).all() and (b["seq_ids"] < 1000).all()

    b = RD.din_batch(0, 8, 20, 1000, 100, 4)
    assert set(b) == {"hist_ids", "target_id", "ctx_ids", "labels"}
    assert set(np.unique(b["labels"])) <= {0.0, 1.0}

    b = RD.xdeepfm_batch(0, 8, 10, 50)
    # field offsets: column j ids live in [j*50, (j+1)*50)
    for j in range(10):
        col = b["field_ids"][:, j]
        assert ((col >= j * 50) & (col < (j + 1) * 50)).all()

    b = RD.twotower_batch(0, 8, 100, 50, 10)
    assert b["hist_mask"].any(axis=1).all()   # every user has history
