"""Per-architecture smoke tests: REDUCED config, one forward/train step on
CPU, asserting output shapes and finiteness (the brief's requirement).
The FULL configs are exercised via the dry-run only."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, get_arch


def _finite(x):
    return bool(jnp.isfinite(jnp.asarray(x, jnp.float32)).all())


LM_ARCHS = [a for a, s in REGISTRY.items() if s.family == "lm"]
RECSYS_ARCHS = [a for a, s in REGISTRY.items() if s.family == "recsys"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch):
    from repro.models import transformer as T

    cfg = get_arch(arch).smoke_config_fn()
    rng = jax.random.PRNGKey(0)
    params, logical = T.init_params(rng, cfg)
    assert len(jax.tree.leaves(params)) > 0

    B, S = 2, 32
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)

    loss, metrics = jax.jit(
        lambda p: T.loss_fn(p, cfg, tokens, labels))(params)
    assert _finite(loss) and float(loss) > 0
    assert _finite(metrics["ppl"])

    logits, aux = T.forward(params, cfg, tokens)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert _finite(logits)

    # one decode step from an empty cache
    cache = T.init_cache(cfg, B, S)
    lg, cache2 = jax.jit(
        lambda p, c: T.decode_step(p, cfg, tokens[:, 0], c))(params, cache)
    assert lg.shape == (B, cfg.padded_vocab)
    assert _finite(lg)
    assert int(cache2["len"][0]) == 1

    # prefill produces a usable cache
    lg_p, cache_p = T.prefill(params, cfg, tokens, max_len=S + 4)
    assert lg_p.shape == (B, cfg.padded_vocab)
    assert _finite(lg_p)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_one_optimizer_step_decreases_nothing_nan(arch):
    from repro.models import transformer as T
    from repro.train.optimizer import OptConfig, opt_init, opt_update

    cfg = get_arch(arch).smoke_config_fn()
    rng = jax.random.PRNGKey(1)
    params, _ = T.init_params(rng, cfg)
    opt_cfg = OptConfig(kind="adamw", lr=1e-3, warmup_steps=1)
    state = opt_init(params, opt_cfg)
    tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    labels = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)

    def step(p, s):
        (loss, m), g = jax.value_and_grad(
            lambda p_: T.loss_fn(p_, cfg, tokens, labels),
            has_aux=True)(p)
        p2, s2, om = opt_update(p, g, s, opt_cfg)
        return p2, s2, loss

    p2, s2, loss = jax.jit(step)(params, state)
    assert _finite(loss)
    assert all(_finite(x) for x in jax.tree.leaves(p2))


def test_gnn_smoke_full_and_sampled():
    from repro.models import gnn as G
    from repro.data.graph_data import gen_powerlaw_graph, NeighborSampler

    cfg = get_arch("graphsage-reddit").smoke_config_fn()
    g = gen_powerlaw_graph(80, 4.0, cfg.d_feat, cfg.n_classes, seed=0)
    params, _ = G.init_params(jax.random.PRNGKey(0), cfg)

    logits = G.forward_full(params, cfg, jnp.asarray(g.x),
                            jnp.asarray(g.edge_src), jnp.asarray(g.edge_dst))
    assert logits.shape == (80, cfg.n_classes)
    assert _finite(logits)

    sampler = NeighborSampler(g.edge_src, g.edge_dst, 80, seed=0)
    seeds = np.arange(8)
    feats, masks = sampler.sample_batch(seeds, cfg.fanouts, g.x)
    logits2 = G.forward_sampled(params, cfg,
                                tuple(jnp.asarray(f) for f in feats),
                                tuple(jnp.asarray(m) for m in masks))
    assert logits2.shape == (8, cfg.n_classes)
    assert _finite(logits2)

    loss, m = G.loss_full(params, cfg, jnp.asarray(g.x),
                          jnp.asarray(g.edge_src), jnp.asarray(g.edge_dst),
                          jnp.asarray(g.labels),
                          jnp.ones(80, bool))
    assert _finite(loss)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    from repro.models import recsys as R
    from repro.data import recsys_data as D

    cfg = get_arch(arch).smoke_config_fn()
    rng = jax.random.PRNGKey(0)
    B = 8

    if arch == "sasrec":
        params, _ = R.sasrec_init(rng, cfg)
        b = D.sasrec_batch(0, B, cfg.seq_len, cfg.n_items, cfg.n_negatives)
        loss, _ = R.sasrec_loss(params, cfg, b["seq_ids"], b["pos_ids"],
                                b["neg_ids"])
        scores = R.sasrec_score(params, cfg, jnp.asarray(b["seq_ids"]))
        assert scores.shape == (B, cfg.n_items)
    elif arch == "din":
        params, _ = R.din_init(rng, cfg)
        b = D.din_batch(0, B, cfg.seq_len, cfg.n_items, cfg.n_context,
                        cfg.n_context_fields)
        loss, _ = R.din_loss(params, cfg, b["hist_ids"], b["target_id"],
                             b["ctx_ids"], b["labels"])
        sc = R.din_score_candidates(params, cfg,
                                    jnp.asarray(b["hist_ids"][:1]),
                                    jnp.asarray(b["ctx_ids"][:1]),
                                    jnp.arange(64))
        assert sc.shape == (64,)
    elif arch == "xdeepfm":
        params, _ = R.xdeepfm_init(rng, cfg)
        b = D.xdeepfm_batch(0, B, cfg.n_fields, cfg.vocab_per_field)
        loss, _ = R.xdeepfm_loss(params, cfg, b["field_ids"], b["labels"])
        logits = R.xdeepfm_forward(params, cfg, jnp.asarray(b["field_ids"]))
        assert logits.shape == (B,)
    else:
        params, _ = R.twotower_init(rng, cfg)
        b = D.twotower_batch(0, B, cfg.n_users, cfg.n_items,
                             cfg.n_user_hist)
        loss, _ = R.twotower_loss(params, cfg, b["user_id"], b["hist_ids"],
                                  b["hist_mask"], b["pos_item"],
                                  b["item_logq"])
        vals, idx = R.retrieval_scores(params, cfg, b["user_id"][:1],
                                       b["hist_ids"][:1], b["hist_mask"][:1],
                                       jnp.arange(cfg.n_items), topk=10)
        assert vals.shape == (1, 10)
    assert _finite(loss) and float(loss) > 0


def test_fim_smoke_mining_round_single_device():
    """The paper's workload lowers and runs on a 1x1 mesh."""
    import jax
    import numpy as np
    from repro.compat import make_mesh
    from repro.core.distributed import make_mining_round
    from repro.core.bitmap import popcount32_np

    mesh = make_mesh((1, 1), ("data", "model"))
    round_fn = jax.jit(make_mining_round(mesh, pair_chunk=8))
    rng = np.random.default_rng(0)
    store = rng.integers(0, 2 ** 32, (16, 2, 8), dtype=np.uint64
                         ).astype(np.uint32)
    pairs = np.stack([rng.integers(0, 16, 16), rng.integers(0, 16, 16)],
                     1).astype(np.int32)
    rho = np.zeros(16, np.int32)
    bound, counts = round_fn(store, pairs, rho)
    expect = popcount32_np(store[pairs[:, 0]] & store[pairs[:, 1]]
                           ).reshape(16, -1).sum(1)
    assert np.array_equal(np.asarray(counts), expect)
    assert (np.asarray(bound) >= expect).all()


def test_all_assigned_archs_have_smoke_and_cells():
    from repro.configs import ASSIGNED_ARCHS, all_cells
    assert len(ASSIGNED_ARCHS) == 10
    cells = list(all_cells(include_fim=False))
    assert len(cells) == 40     # 10 archs x 4 shapes each
