"""Property-based cross-engine equivalence harness (ISSUE 3 satellite).

Replaces the one-strategy smoke coverage that previously lived in
test_core_mining.py.  Invariants (paper §III-IV):

  I1  every miner x {ES on/off} x backend returns exactly the frequent
      itemset -> support map of the brute-force oracle;
  I2  early stopping NEVER changes the result set (the criterion is
      exact);
  I3  ES never increases the comparison count (paper's guarantee);
  I4  the device PrePost+ comparison counts equal the oracle's exactly;
  I5  bitmap engines agree with the oracle bit-for-bit.

DB generation spans the regimes of the paper's dataset families —
dense tabular, sparse, powerlaw (retail-like), single-item,
duplicate-transaction and empty-transaction DBs.  The hypothesis
strategy (CI) and the deterministic seeded sweeps (which run even when
hypothesis is absent — see the conftest shim) draw from the same
generator, so local runs keep real coverage.
"""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.oracle import mine, mine_bruteforce, MINERS
from repro.core.eclat import mine_bitmap
from repro.core.prepost import mine_prepost_device

REGIMES = ("dense", "sparse", "powerlaw", "single-item", "dup-trans",
           "empty-trans")


def gen_db(regime: str, seed: int):
    """One (db, minsup) case for a regime; deterministic in ``seed``."""
    rng = random.Random(REGIMES.index(regime) * 65_537 + seed)
    ni = rng.randint(3, 9)
    nt = rng.randint(4, 24)
    if regime == "dense":
        ni = rng.randint(3, 6)
        dens = rng.uniform(0.6, 0.9)
        db = [[i for i in range(ni) if rng.random() < dens]
              for _ in range(nt)]
    elif regime == "sparse":
        dens = rng.uniform(0.1, 0.25)
        db = [[i for i in range(ni) if rng.random() < dens]
              for _ in range(nt)]
    elif regime == "powerlaw":
        weights = [1.0 / (r + 1) ** 1.5 for r in range(ni)]
        db = [sorted(set(rng.choices(range(ni), weights=weights,
                                     k=rng.randint(1, 6))))
              for _ in range(nt)]
    elif regime == "single-item":
        db = [[rng.randrange(ni)] for _ in range(nt)]
        if rng.random() < 0.5:           # occasionally one longer basket
            db.append(sorted(rng.sample(range(ni), min(3, ni))))
    elif regime == "dup-trans":
        distinct = [[i for i in range(ni) if rng.random() < 0.5] or [0]
                    for _ in range(rng.randint(2, 4))]
        db = [list(rng.choice(distinct)) for _ in range(nt)]
    elif regime == "empty-trans":
        dens = rng.uniform(0.15, 0.4)
        db = [[i for i in range(ni) if rng.random() < dens]
              for _ in range(nt)]
        for k in rng.sample(range(len(db)), max(1, len(db) // 3)):
            db[k] = []                   # empties stay in the DB
    else:
        raise ValueError(regime)
    if not any(db):
        db.append([0])                   # at least one item overall
    minsup = rng.randint(1, max(1, len(db) // 2))
    return db, minsup


def _engines(backend: str):
    """Every miner as ``name -> fn(db, minsup, es) -> (out, stats)``."""
    eng = {f"oracle-{s}": (lambda s: lambda db, ms, es: mine(
        db, ms, s, early_stop=es))(s) for s in sorted(MINERS)}
    for s in ("eclat", "declat"):
        eng[f"bitmap-{s}"] = (lambda s: lambda db, ms, es: mine_bitmap(
            db, ms, scheme=s, early_stop=es, block_words=4,
            backend=backend))(s)
    eng["device-prepost"] = lambda db, ms, es: mine_prepost_device(
        db, ms, early_stop=es, backend=backend)
    return eng


def assert_all_engines_match(db, minsup, backend="jnp"):
    expected = mine_bruteforce(db, minsup)
    for name, fn in _engines(backend).items():
        for es in (False, True):
            out, _ = fn(db, minsup, es)
            assert out == expected, (name, es, minsup)       # I1, I2, I5


# ---------------------------------------------------------------------------
# deterministic regime sweeps (run without hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("regime", REGIMES)
def test_regime_sweep_all_engines_match_bruteforce(regime):
    for seed in range(6):
        db, minsup = gen_db(regime, seed)
        assert_all_engines_match(db, minsup)


def test_all_transactions_empty():
    """A DB whose every transaction is empty has no frequent itemsets."""
    db = [[] for _ in range(5)] + [[0]]
    assert_all_engines_match(db, 2)


@pytest.mark.parametrize("regime", ["dense", "powerlaw"])
def test_pallas_backend_matches_bruteforce(regime):
    """backend="pallas" (interpret on CPU) through the full engines."""
    db, minsup = gen_db(regime, 0)
    assert_all_engines_match(db, minsup, backend="pallas")


@pytest.mark.parametrize("regime", REGIMES)
def test_es_never_increases_comparisons_sweep(regime):
    """I3, including the device PrePost+ path."""
    for seed in range(4):
        db, minsup = gen_db(regime, seed)
        for scheme in MINERS:
            _, full = mine(db, minsup, scheme, early_stop=False)
            _, es = mine(db, minsup, scheme, early_stop=True)
            assert es.comparisons <= full.comparisons, (regime, scheme)
        _, dfull = mine_prepost_device(db, minsup, early_stop=False)
        _, des = mine_prepost_device(db, minsup, early_stop=True)
        assert des.comparisons <= dfull.comparisons, regime


@pytest.mark.parametrize("regime", REGIMES)
def test_device_prepost_counts_equal_oracle_sweep(regime):
    """I4: same merges, same abort points, exactly the same counters."""
    for seed in range(4):
        db, minsup = gen_db(regime, seed)
        for es in (False, True):
            _, o = mine(db, minsup, "prepost", early_stop=es)
            _, d = mine_prepost_device(db, minsup, early_stop=es)
            assert d.comparisons == o.comparisons, (regime, seed, es)
            assert d.es_checks == o.es_checks, (regime, seed, es)
            assert d.es_aborts == o.es_aborts, (regime, seed, es)


def test_block_granularity_invariance():
    """ES block size changes WORK, never RESULTS: any block_words gives
    the identical frequent-itemset dict (the bound is exact at every
    granularity)."""
    for regime in ("sparse", "powerlaw"):
        db, minsup = gen_db(regime, 1)
        ref = None
        for bw in (1, 4, 16):
            out, _ = mine_bitmap(db, minsup, "eclat", early_stop=True,
                                 block_words=bw)
            if ref is None:
                ref = out
            assert out == ref, (regime, bw)


# ---------------------------------------------------------------------------
# hypothesis: the same generator, fuzz-driven (CI)
# ---------------------------------------------------------------------------

@st.composite
def db_case(draw):
    regime = draw(st.sampled_from(REGIMES))
    seed = draw(st.integers(0, 2 ** 31))
    return gen_db(regime, seed)


@settings(max_examples=25, deadline=None)
@given(db_case())
def test_property_all_engines_match_bruteforce(case):
    db, minsup = case
    assert_all_engines_match(db, minsup)


@settings(max_examples=20, deadline=None)
@given(db_case())
def test_property_es_never_increases_comparisons(case):
    db, minsup = case
    for scheme in MINERS:
        _, full = mine(db, minsup, scheme, early_stop=False)
        _, es = mine(db, minsup, scheme, early_stop=True)
        assert es.comparisons <= full.comparisons, scheme           # I3


@settings(max_examples=20, deadline=None)
@given(db_case())
def test_property_device_prepost_counts_equal_oracle(case):
    db, minsup = case
    for es in (False, True):
        _, o = mine(db, minsup, "prepost", early_stop=es)
        _, d = mine_prepost_device(db, minsup, early_stop=es)
        assert d.comparisons == o.comparisons                       # I4
        assert d.es_checks == o.es_checks
        assert d.es_aborts == o.es_aborts
