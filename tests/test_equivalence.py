"""Property-based cross-engine equivalence harness (ISSUE 3 satellite;
ISSUE 4 added the distributed engine, the frontier scheduler and the
allocator-compaction invariants).

Replaces the one-strategy smoke coverage that previously lived in
test_core_mining.py.  Invariants (paper §III-IV):

  I1  every miner x {ES on/off} x backend returns exactly the frequent
      itemset -> support map of the brute-force oracle;
  I2  early stopping NEVER changes the result set (the criterion is
      exact);
  I3  ES never increases the comparison count (paper's guarantee);
  I4  the device PrePost+ comparison counts equal the oracle's exactly;
  I5  bitmap engines agree with the oracle bit-for-bit;
  I6  allocator compaction is invisible: live rows/extents survive
      bit-for-bit, frontier handles are remapped correctly, and mining
      with compaction forced at every opportunity returns I1's exact
      result map.

DB generation spans the regimes of the paper's dataset families —
dense tabular, sparse, powerlaw (retail-like), single-item,
duplicate-transaction and empty-transaction DBs.  The hypothesis
strategy (CI) and the deterministic seeded sweeps (which run even when
hypothesis is absent — see the conftest shim) draw from the same
generator, so local runs keep real coverage.
"""

import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.compat import make_mesh
from repro.core.guards import device_purity_guard, purity_guard_active
from repro.core.oracle import mine, mine_bruteforce, MINERS
from repro.core.eclat import BitmapMiner, mine_bitmap
from repro.core.prepost import DevicePrePost, mine_prepost_device
from repro.core.rowstore import DeviceRowStore, NListPool

REGIMES = ("dense", "sparse", "powerlaw", "single-item", "dup-trans",
           "empty-trans")

_MESH = None


def _mesh():
    """One lazily built single-device mesh shared by every distributed
    case (keeps the shard_map jit cache warm across the sweep)."""
    global _MESH
    if _MESH is None:
        _MESH = make_mesh((1, 1), ("data", "model"))
    return _MESH


def gen_db(regime: str, seed: int):
    """One (db, minsup) case for a regime; deterministic in ``seed``."""
    rng = random.Random(REGIMES.index(regime) * 65_537 + seed)
    ni = rng.randint(3, 9)
    nt = rng.randint(4, 24)
    if regime == "dense":
        ni = rng.randint(3, 6)
        dens = rng.uniform(0.6, 0.9)
        db = [[i for i in range(ni) if rng.random() < dens]
              for _ in range(nt)]
    elif regime == "sparse":
        dens = rng.uniform(0.1, 0.25)
        db = [[i for i in range(ni) if rng.random() < dens]
              for _ in range(nt)]
    elif regime == "powerlaw":
        weights = [1.0 / (r + 1) ** 1.5 for r in range(ni)]
        db = [sorted(set(rng.choices(range(ni), weights=weights,
                                     k=rng.randint(1, 6))))
              for _ in range(nt)]
    elif regime == "single-item":
        db = [[rng.randrange(ni)] for _ in range(nt)]
        if rng.random() < 0.5:           # occasionally one longer basket
            db.append(sorted(rng.sample(range(ni), min(3, ni))))
    elif regime == "dup-trans":
        distinct = [[i for i in range(ni) if rng.random() < 0.5] or [0]
                    for _ in range(rng.randint(2, 4))]
        db = [list(rng.choice(distinct)) for _ in range(nt)]
    elif regime == "empty-trans":
        dens = rng.uniform(0.15, 0.4)
        db = [[i for i in range(ni) if rng.random() < dens]
              for _ in range(nt)]
        for k in rng.sample(range(len(db)), max(1, len(db) // 3)):
            db[k] = []                   # empties stay in the DB
    else:
        raise ValueError(regime)
    if not any(db):
        db.append([0])                   # at least one item overall
    minsup = rng.randint(1, max(1, len(db) // 2))
    return db, minsup


def _engines(backend: str):
    """Every miner as ``name -> fn(db, minsup, es) -> (out, stats)``."""
    from repro.core.distributed import DistributedMiner

    eng = {f"oracle-{s}": (lambda s: lambda db, ms, es: mine(
        db, ms, s, early_stop=es))(s) for s in sorted(MINERS)}
    for s in ("eclat", "declat"):
        eng[f"bitmap-{s}"] = (lambda s: lambda db, ms, es: mine_bitmap(
            db, ms, scheme=s, early_stop=es, block_words=4,
            backend=backend))(s)
    # density-adaptive tidset->diffset switching (ISSUE 6); the low
    # threshold + wide hysteresis forces flips in the dense regimes and
    # leaves the sparse ones tidset, so both paths are exercised
    eng["bitmap-adaptive"] = lambda db, ms, es: mine_bitmap(
        db, ms, scheme="adaptive", diff_density=0.3, diff_hysteresis=0.1,
        early_stop=es, block_words=4, backend=backend)
    eng["device-prepost"] = lambda db, ms, es: mine_prepost_device(
        db, ms, early_stop=es, backend=backend)
    if backend == "jnp":                 # shard_map path is jnp-only
        eng["distributed-eclat"] = lambda db, ms, es: DistributedMiner(
            _mesh(), early_stop=es, block_words=4).mine(db, ms)
        eng["distributed-adaptive"] = lambda db, ms, es: DistributedMiner(
            _mesh(), early_stop=es, block_words=4, scheme="adaptive",
            diff_density=0.3, diff_hysteresis=0.1).mine(db, ms)
    return eng


def assert_all_engines_match(db, minsup, backend="jnp"):
    expected = mine_bruteforce(db, minsup)
    # The harness itself runs under the device-purity guard (ISSUE 10):
    # on accelerator backends any device->host readback outside a
    # `# host-sync:`-annotated host_sync() escape raises here; on CPU
    # (zero-copy d2h) the guard is inert and devicelint's DL001 is the
    # enforcement with teeth.
    with device_purity_guard():
        for name, fn in _engines(backend).items():
            for es in (False, True):
                out, _ = fn(db, minsup, es)
                assert out == expected, (name, es, minsup)   # I1, I2, I5


# ---------------------------------------------------------------------------
# deterministic regime sweeps (run without hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("regime", REGIMES)
def test_regime_sweep_all_engines_match_bruteforce(regime):
    for seed in range(6):
        db, minsup = gen_db(regime, seed)
        assert_all_engines_match(db, minsup)


def test_all_transactions_empty():
    """A DB whose every transaction is empty has no frequent itemsets."""
    db = [[] for _ in range(5)] + [[0]]
    assert_all_engines_match(db, 2)


def test_transfer_guard_smoke_every_engine():
    """A full mine on every engine under ``device_purity_guard`` (d2h
    transfer guard at "disallow") triggers zero unannotated transfers
    and mines the exact bruteforce result (ISSUE 10 satellite).  The
    guard must actually be armed for the whole sweep — on CPU that
    depth flag is the observable part of the contract."""
    db, minsup = gen_db("powerlaw", 1)
    expected = mine_bruteforce(db, minsup)
    with device_purity_guard():
        assert purity_guard_active()
        for name, fn in _engines("jnp").items():
            out, _ = fn(db, minsup, True)
            assert out == expected, name
        assert purity_guard_active()   # no engine leaked an un-exited escape
    assert not purity_guard_active()


@pytest.mark.parametrize("regime", ["dense", "powerlaw"])
def test_pallas_backend_matches_bruteforce(regime):
    """backend="pallas" (interpret on CPU) through the full engines."""
    db, minsup = gen_db(regime, 0)
    assert_all_engines_match(db, minsup, backend="pallas")


@pytest.mark.parametrize("regime", REGIMES)
def test_es_never_increases_comparisons_sweep(regime):
    """I3, including the device PrePost+ path."""
    for seed in range(4):
        db, minsup = gen_db(regime, seed)
        for scheme in MINERS:
            _, full = mine(db, minsup, scheme, early_stop=False)
            _, es = mine(db, minsup, scheme, early_stop=True)
            assert es.comparisons <= full.comparisons, (regime, scheme)
        _, dfull = mine_prepost_device(db, minsup, early_stop=False)
        _, des = mine_prepost_device(db, minsup, early_stop=True)
        assert des.comparisons <= dfull.comparisons, regime


@pytest.mark.parametrize("regime", REGIMES)
def test_device_prepost_counts_equal_oracle_sweep(regime):
    """I4: same merges, same abort points, exactly the same counters."""
    for seed in range(4):
        db, minsup = gen_db(regime, seed)
        for es in (False, True):
            _, o = mine(db, minsup, "prepost", early_stop=es)
            _, d = mine_prepost_device(db, minsup, early_stop=es)
            assert d.comparisons == o.comparisons, (regime, seed, es)
            assert d.es_checks == o.es_checks, (regime, seed, es)
            assert d.es_aborts == o.es_aborts, (regime, seed, es)


def test_block_granularity_invariance():
    """ES block size changes WORK, never RESULTS: any block_words gives
    the identical frequent-itemset dict (the bound is exact at every
    granularity)."""
    for regime in ("sparse", "powerlaw"):
        db, minsup = gen_db(regime, 1)
        ref = None
        for bw in (1, 4, 16):
            out, _ = mine_bitmap(db, minsup, "eclat", early_stop=True,
                                 block_words=bw)
            if ref is None:
                ref = out
            assert out == ref, (regime, bw)


# ---------------------------------------------------------------------------
# hypothesis: the same generator, fuzz-driven (CI)
# ---------------------------------------------------------------------------

@st.composite
def db_case(draw):
    regime = draw(st.sampled_from(REGIMES))
    seed = draw(st.integers(0, 2 ** 31))
    return gen_db(regime, seed)


@settings(max_examples=25, deadline=None)
@given(db_case())
def test_property_all_engines_match_bruteforce(case):
    db, minsup = case
    assert_all_engines_match(db, minsup)


@settings(max_examples=20, deadline=None)
@given(db_case())
def test_property_es_never_increases_comparisons(case):
    db, minsup = case
    for scheme in MINERS:
        _, full = mine(db, minsup, scheme, early_stop=False)
        _, es = mine(db, minsup, scheme, early_stop=True)
        assert es.comparisons <= full.comparisons, scheme           # I3


@settings(max_examples=20, deadline=None)
@given(db_case())
def test_property_device_prepost_counts_equal_oracle(case):
    db, minsup = case
    for es in (False, True):
        _, o = mine(db, minsup, "prepost", early_stop=es)
        _, d = mine_prepost_device(db, minsup, early_stop=es)
        assert d.comparisons == o.comparisons                       # I4
        assert d.es_checks == o.es_checks
        assert d.es_aborts == o.es_aborts


# ---------------------------------------------------------------------------
# ISSUE 5: non-ES runs report zero deaths, and child materialization is
# survivor-only (scatter telemetry == frequent children, not candidates)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("regime", REGIMES)
def test_non_es_runs_report_zero_deaths_every_engine(regime):
    """With early stopping disabled no engine may attribute an ES death
    (the pre-ISSUE-5 PrePost+ path bumped ``es_aborts`` from the merge's
    alive vector even when the guard was never armed)."""
    from repro.core.distributed import DistributedMiner

    for seed in range(3):
        db, minsup = gen_db(regime, seed)
        for scheme in ("eclat", "declat", "adaptive"):
            _, st = mine_bitmap(db, minsup, scheme=scheme, early_stop=False,
                                block_words=4)
            assert st.deaths == 0, (regime, seed, scheme)
            assert st.screened_out == 0 and st.kernel_aborts == 0
        _, st = mine_prepost_device(db, minsup, early_stop=False)
        assert st.deaths == 0 and st.es_aborts == 0, (regime, seed)
        _, st = DistributedMiner(_mesh(), early_stop=False,
                                 block_words=4).mine(db, minsup)
        assert st.deaths == 0, (regime, seed, "distributed")


def _n_children(out):
    return sum(1 for s in out if len(s) >= 2)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_survivor_only_scatter_telemetry(backend):
    """Child scatters == frequent children (NOT candidates) for every
    engine on screened-out-heavy regimes, ES on and off, with outputs
    still exact (ISSUE 5 acceptance).  The jnp and pallas(interpret)
    backends gate identically; the 8-shard distributed check lives in
    test_distributed.py's subprocess sweep."""
    from repro.core.distributed import DistributedMiner

    for regime in ("sparse", "powerlaw"):
        for seed in range(3):
            db, minsup = gen_db(regime, seed)
            expected = mine_bruteforce(db, minsup)
            for es in (False, True):
                runs = {
                    "bitmap-eclat": mine_bitmap(
                        db, minsup, "eclat", early_stop=es, block_words=4,
                        backend=backend),
                    "bitmap-declat": mine_bitmap(
                        db, minsup, "declat", early_stop=es, block_words=4,
                        backend=backend),
                    "bitmap-adaptive": mine_bitmap(
                        db, minsup, "adaptive", diff_density=0.3,
                        diff_hysteresis=0.1, early_stop=es, block_words=4,
                        backend=backend),
                    "device-prepost": mine_prepost_device(
                        db, minsup, early_stop=es, backend=backend),
                }
                if backend == "jnp":     # shard_map path is jnp-only
                    runs["distributed-eclat"] = DistributedMiner(
                        _mesh(), early_stop=es, block_words=4,
                        ).mine(db, minsup)
                for name, (out, st) in runs.items():
                    key = (regime, seed, es, name)
                    assert out == expected, key
                    assert st.child_scatters == _n_children(out), key
                    assert st.child_scatters <= st.candidates, key
                    if es and st.deaths:
                        # dead candidates really were not materialised
                        assert st.child_scatters < st.candidates, key


def test_scatter_words_track_survivors_only():
    """scatter_words is the exact device word cost of the materialised
    children: rows * row_words for the bitmap engine (the tiny DBs here
    pack into one 4-word block), 3 * sum(child lengths) for the N-list
    engine — identical between the ES and non-ES run of the same DB
    because both materialise exactly the frequent children."""
    db, minsup = gen_db("powerlaw", 1)
    out_es, st_es = mine_bitmap(db, minsup, "eclat", early_stop=True,
                                block_words=4)
    _, st_no = mine_bitmap(db, minsup, "eclat", early_stop=False,
                           block_words=4)
    assert st_es.child_scatters == st_no.child_scatters == _n_children(
        out_es)
    assert st_es.scatter_words == st_es.child_scatters * 1 * 4
    assert st_es.scatter_words == st_no.scatter_words
    p_out, p_st = mine_prepost_device(db, minsup, early_stop=True)
    assert p_st.child_scatters == _n_children(p_out)
    assert p_st.scatter_words % 3 == 0
    assert p_st.scatter_words >= 3 * p_st.child_scatters


# ---------------------------------------------------------------------------
# ISSUE 5: compaction reserve covers the whole drain group
# ---------------------------------------------------------------------------

def test_compaction_reserve_covers_whole_drain_group(monkeypatch):
    """Forced compaction (threshold 1.0) on a DB big enough to grow the
    slab: the scheduler must pass every ``maybe_compact`` the WHOLE
    drain group's pair count as the reserve (the pre-ISSUE-5
    ``min(total, pair_chunk)`` clamp under-reserved multi-chunk groups),
    and consequently the allocator never grows between a compaction and
    its group's last chunk (no compact->grow thrash).

    Pinned to ``inflight=1``: with an empty pipeline ring the reserve
    is EXACTLY the group's pair count.  The pipelined generalisation
    (reserve additionally covers every in-flight group) is asserted in
    tests/test_pipeline.py."""
    import repro.core.eclat as E
    from repro.data.transactions import gen_powerlaw_baskets

    events = []
    real_eval = E.BitmapMiner.evaluate_pairs
    real_comp = E.BitmapMiner.maybe_compact

    def eval_spy(self, cols):
        r = real_eval(self, cols)
        events.append(("eval", self._store.grows, int(cols["ua"].size)))
        return r

    def comp_spy(self, reserve):
        m = real_comp(self, reserve)
        events.append(("compact", self._store.grows, m is not None,
                       int(reserve)))
        return m

    monkeypatch.setattr(E.BitmapMiner, "evaluate_pairs", eval_spy)
    monkeypatch.setattr(E.BitmapMiner, "maybe_compact", comp_spy)

    pair_chunk = 64
    db = gen_powerlaw_baskets(n_trans=120, n_items=60, avg_trans_len=5,
                              seed=0)
    minsup = 3
    out, stats = E.BitmapMiner(
        scheme="eclat", early_stop=True, block_words=2,
        pair_chunk=pair_chunk, compact_occupancy=1.0,
        inflight=1).mine(db, minsup)
    assert out == mine_bruteforce(db, minsup)
    assert stats.compactions > 0         # forcing actually fired

    # split the event stream into drain groups (one compact each)
    groups, cur = [], None
    for ev in events:
        if ev[0] == "compact":
            if cur is not None:
                groups.append(cur)
            cur = {"grows": ev[1], "fired": ev[2], "reserve": ev[3],
                   "pairs": 0, "grows_after": ev[1]}
        else:
            cur["pairs"] += ev[2]
            cur["grows_after"] = ev[1]
    groups.append(cur)
    multi_chunk = 0
    for g in groups:
        # reserve == the whole group's evaluated pairs, never clamped
        assert g["reserve"] == g["pairs"], g
        if g["pairs"] > pair_chunk:
            multi_chunk += 1
        if g["fired"]:
            assert g["grows_after"] == g["grows"], g
    assert multi_chunk > 0               # the clamp would have bitten


# ---------------------------------------------------------------------------
# I6: allocator compaction invariants (ISSUE 4)
# ---------------------------------------------------------------------------

def _alloc_free_pattern(rng, store_like, rounds=4):
    """Random alloc/free churn; returns the surviving handle list."""
    live = []
    for _ in range(rounds):
        k = rng.randint(1, 6)
        live.extend(int(s) for s in store_like.alloc(k))
        rng.shuffle(live)
        drop = live[:rng.randint(0, len(live) // 2)]
        live = live[len(drop):]
        store_like.free(drop)
    return live


def _check_rowstore_compaction(seed):
    """Compaction preserves every live row AND its suffix table
    bit-for-bit, maps live slots densely onto [0, n_live), and leaves
    dead slots unmapped (-1)."""
    rng = random.Random(seed)
    r = np.random.default_rng(seed)
    rows_np = r.integers(0, 2 ** 32, (5, 3, 4), dtype=np.uint64
                         ).astype(np.uint32)
    store = DeviceRowStore(rows_np, capacity=8)
    live = list(range(5)) + _alloc_free_pattern(rng, store)
    before = {s: (np.asarray(store.rows[s]), np.asarray(store.suffix[s]))
              for s in live}
    old_cap = store.capacity
    mapping = store.compact(reserve=rng.randint(0, 8))
    assert mapping.shape == (old_cap,)
    new_ids = mapping[np.asarray(live, np.int64)]
    assert (new_ids >= 0).all()
    assert sorted(new_ids.tolist()) == list(range(len(live)))  # dense
    dead = np.setdiff1d(np.arange(old_cap), np.asarray(live, np.int64))
    assert (mapping[dead] == -1).all()
    for s, ni in zip(live, new_ids, strict=True):
        assert np.array_equal(np.asarray(store.rows[int(ni)]), before[s][0])
        assert np.array_equal(np.asarray(store.suffix[int(ni)]),
                              before[s][1])
    # post-compaction alloc/free still works and hands out fresh slots
    fresh = store.alloc(3)
    assert len(set(fresh.tolist()) & set(new_ids.tolist())) == 0
    store.free(fresh)


def _check_pool_compaction(seed):
    """Pool compaction preserves live extents bit-for-bit under stable
    row ids, shrinks extents to the bucket of their actual length, and
    recycles the freed mass (live_codes never grows)."""
    rng = random.Random(seed)
    r = np.random.default_rng(seed)
    pool = NListPool(capacity=64)
    live = {}
    for _ in range(4):
        lens = [rng.randint(1, 40) for _ in range(rng.randint(1, 5))]
        rows = pool.alloc_rows(lens)
        arrays = [r.integers(0, 1000, (ln, 3)).astype(np.int32)
                  for ln in lens]
        pool.write_rows(rows, arrays)
        for row, a in zip(rows, arrays, strict=True):
            live[int(row)] = a
        drop = rng.sample(sorted(live), rng.randint(0, len(live) // 2))
        pool.free_rows(drop)
        for row in drop:
            del live[row]
    live_before = pool.live_codes
    pool.compact()
    assert pool.compactions == 1
    assert pool.live_codes <= live_before         # tight buckets only
    for row, a in live.items():
        assert np.array_equal(pool.read_row(row), a), row
    # the pool still serves allocations after the epoch
    rows = pool.alloc_rows([3])
    pool.free_rows(rows)


def test_compaction_bit_exact_sweep():
    """Deterministic seeds of the two compaction properties (run even
    when hypothesis is absent — same generator as the @given tests)."""
    for seed in range(6):
        _check_rowstore_compaction(seed)
        _check_pool_compaction(seed)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31))
def test_property_rowstore_compaction_bit_exact(seed):
    _check_rowstore_compaction(seed)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31))
def test_property_nlist_pool_compaction_bit_exact(seed):
    _check_pool_compaction(seed)


def test_sharded_rowstore_compaction_shrinks_slab():
    """Under the block-sharded NamedSharding slab, compaction shrinks
    capacity back after a growth spike and preserves placement (the
    "long distributed runs can shrink again" ROADMAP item)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = _mesh()
    r = np.random.default_rng(1)
    rows_np = r.integers(0, 2 ** 32, (6, 2, 4), dtype=np.uint64
                         ).astype(np.uint32)
    store = DeviceRowStore(rows_np, capacity=8, mesh=mesh)
    big = store.alloc(200)               # force growth
    grown_cap = store.capacity
    store.free(big)
    assert store.compact_if_sparse(0.5, reserve=4) is not None
    assert store.capacity < grown_cap
    expected = NamedSharding(mesh, P(None, ("data", "model"), None))
    assert store.rows.sharding == expected
    assert np.array_equal(np.asarray(store.rows[:6, :2]), rows_np)


@pytest.mark.parametrize("regime", ["dense", "powerlaw", "sparse"])
def test_compaction_forced_engines_match_bruteforce(regime):
    """I6 end-to-end: compact at EVERY drain-group boundary where the
    slab could halve (threshold 1.0) and the result maps stay exact —
    this exercises frontier-handle remapping under real DFS churn."""
    from repro.core.distributed import DistributedMiner

    for seed in range(3):
        db, minsup = gen_db(regime, seed)
        expected = mine_bruteforce(db, minsup)
        out, st_b = BitmapMiner(
            scheme="eclat", early_stop=True, block_words=2, pair_chunk=8,
            compact_occupancy=1.0).mine(db, minsup)
        assert out == expected, (regime, seed, "bitmap")
        out, _ = BitmapMiner(
            scheme="adaptive", diff_density=0.3, diff_hysteresis=0.1,
            early_stop=True, block_words=2, pair_chunk=8,
            compact_occupancy=1.0).mine(db, minsup)
        assert out == expected, (regime, seed, "bitmap-adaptive")
        out, st_p = DevicePrePost(
            early_stop=True, pair_chunk=8,
            compact_occupancy=1.0).mine(db, minsup)
        assert out == expected, (regime, seed, "prepost")
        out, st_d = DistributedMiner(
            _mesh(), early_stop=True, block_words=2, pair_chunk=8,
            compact_occupancy=1.0).mine(db, minsup)
        assert out == expected, (regime, seed, "distributed")


def test_nlist_free_list_split_recycles_larger_extents():
    """A small-bucket allocation with an empty free list recycles a
    LARGER free extent by splitting it (head = requested bucket, tail
    released to smaller buckets) instead of bumping fresh capacity."""
    pool = NListPool(capacity=1024)
    big = pool.alloc_rows([500])         # bucket 512
    off_big = pool.offsets(big)[0]
    pool.free_rows(big)
    bump0 = pool._bump
    small = pool.alloc_rows([5] * 4)     # 4x bucket 8, no 8-bucket frees
    assert pool._bump == bump0           # no new capacity consumed
    offs = sorted(pool.offsets(small).tolist())
    assert offs[0] == off_big            # head of the split extent
    assert all(off_big <= o < off_big + 512 for o in offs)
    pool.free_rows(small)


# ---------------------------------------------------------------------------
# scheduler: one drain-group code path shared by all three engines
# ---------------------------------------------------------------------------

def test_all_engines_share_frontier_scheduler(monkeypatch):
    """All three miners drive their DFS through
    ``core.frontier.FrontierScheduler.drain_group`` — no duplicated
    drain loop is left in eclat.py / prepost.py / distributed.py."""
    import repro.core.eclat as E
    import repro.core.prepost as PP
    import repro.core.distributed as D
    from repro.core import frontier

    # the per-engine traversal loops are gone
    for mod, names in ((E, ("_traverse",)), (PP, ("_traverse",)),
                       (D, ("_traverse",))):
        for name in names:
            assert not hasattr(mod, name)
    assert not hasattr(E.BitmapMiner, "_traverse")
    assert not hasattr(PP.DevicePrePost, "_traverse")

    drained_by = {}
    real = frontier.FrontierScheduler.drain_group

    def counting(self, *a, **k):
        drained_by[type(self.client).__name__] = drained_by.get(
            type(self.client).__name__, 0) + 1
        return real(self, *a, **k)

    monkeypatch.setattr(frontier.FrontierScheduler, "drain_group",
                        counting)
    db, minsup = gen_db("dense", 0)
    expected = mine_bruteforce(db, minsup)
    out, _ = E.BitmapMiner(block_words=2, pair_chunk=4).mine(db, minsup)
    assert out == expected
    out, _ = PP.DevicePrePost(pair_chunk=4).mine(db, minsup)
    assert out == expected
    out, _ = D.DistributedMiner(_mesh(), block_words=2,
                                pair_chunk=4).mine(db, minsup)
    assert out == expected
    assert set(drained_by) == {"BitmapMiner", "DevicePrePost",
                               "DistributedMiner"}
    assert all(v >= 1 for v in drained_by.values())
