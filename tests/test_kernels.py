"""Per-kernel allclose sweeps: Pallas (interpret) vs pure-jnp oracles."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.bitmap import (pack_tidlists, suffix_popcounts_np,
                               popcount32_np, unpack_row)
from repro.kernels import ops
from repro.kernels.ref import (bitmap_intersect_es_ref, flash_attention_ref,
                               embedding_bag_ref, screen_pairs_ref,
                               screen_and_intersect_ref)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.segment_embed import embedding_bag


# ---------------------------------------------------------------------------
# bitmap intersection kernel: bit-exact across modes / shapes / minsup
# ---------------------------------------------------------------------------

def _random_bitmaps(rng, n_pairs, n_blocks, bw, density=0.25):
    u = rng.integers(0, 2 ** 32, (n_pairs, n_blocks, bw),
                     dtype=np.uint64).astype(np.uint32)
    m = rng.integers(0, 2 ** 32, (n_pairs, n_blocks, bw),
                     dtype=np.uint64).astype(np.uint32)
    if density < 0.5:
        u &= m
    return u


@pytest.mark.parametrize("mode", ["and", "andnot"])
@pytest.mark.parametrize("n_blocks,bw", [(1, 128), (3, 128), (5, 8)])
def test_bitmap_kernel_matches_ref(mode, n_blocks, bw):
    rng = np.random.default_rng(42)
    n_pairs = 7
    U = _random_bitmaps(rng, n_pairs, n_blocks, bw)
    V = _random_bitmaps(rng, n_pairs, n_blocks, bw)
    su = suffix_popcounts_np(U)
    sv = suffix_popcounts_np(V)
    rho = popcount32_np(U).reshape(n_pairs, -1).sum(1).astype(np.int32)
    n_trans = n_blocks * bw * 32
    for minsup in (0, 1, n_trans // 64, n_trans // 8, n_trans):
        r = bitmap_intersect_es_ref(U, V, su, sv, rho, jnp.int32(minsup),
                                    mode=mode)
        p = ops.bitmap_intersect_es(U, V, su, sv, rho, jnp.int32(minsup),
                                    mode=mode, backend="pallas")
        for name, a, b in zip(("Z", "cnt", "blocks", "alive"), r, p):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                mode, minsup, name)


def test_bitmap_kernel_es_aborts_and_freezes():
    """Dead pairs stop processing blocks and freeze counts (the paper's
    semantics quantised to blocks)."""
    rng = np.random.default_rng(0)
    U = _random_bitmaps(rng, 16, 6, 8, density=0.2)
    V = _random_bitmaps(rng, 16, 6, 8, density=0.2)
    su, sv = suffix_popcounts_np(U), suffix_popcounts_np(V)
    rho = np.zeros(16, np.int32)
    minsup = 6 * 8 * 32 // 4   # high threshold: most pairs die early
    Z, cnt, blocks, alive = ops.bitmap_intersect_es(
        U, V, su, sv, rho, jnp.int32(minsup), mode="and", backend="pallas")
    blocks = np.asarray(blocks)
    assert (blocks < 6).any()
    # dead pairs: output blocks beyond the abort point are zeroed
    Z = np.asarray(Z)
    for i in range(16):
        if blocks[i] < 6:
            assert not Z[i, blocks[i]:].any()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("mode", ["and", "andnot"])
@pytest.mark.parametrize("n_blocks,bw", [(1, 128), (3, 128), (5, 8)])
def test_fused_screen_and_intersect_matches_ref(backend, mode, n_blocks, bw):
    """ops.screen_and_intersect == gather + ES ref + scatter, bit-for-bit:
    child rows and suffix tables land at `slots`, padding slots (>= cap)
    are dropped, untouched store rows are untouched."""
    rng = np.random.default_rng(11)
    cap, n_pairs = 32, 9
    store0 = _random_bitmaps(rng, cap, n_blocks, bw)
    suffix0 = suffix_popcounts_np(store0)
    ua = rng.integers(0, 12, n_pairs).astype(np.int32)
    vb = rng.integers(0, 12, n_pairs).astype(np.int32)
    slots = np.arange(12, 12 + n_pairs, dtype=np.int32)
    slots[-1] = cap + 3          # OOB sentinel: must be dropped
    rho = suffix0[ua, 0].astype(np.int32)
    n_trans = n_blocks * bw * 32
    for minsup in (0, 1, n_trans // 64, n_trans // 8):
        Zr, cnt_r, blocks_r, alive_r = screen_and_intersect_ref(
            store0, suffix0, ua, vb, rho, jnp.int32(minsup), mode=mode)
        rows, suffix, cnt, blocks, alive = ops.screen_and_intersect(
            jnp.asarray(store0), jnp.asarray(suffix0), ua, vb, slots, rho,
            jnp.int32(minsup), mode=mode, backend=backend)
        rows, suffix = np.asarray(rows), np.asarray(suffix)
        key = (backend, mode, minsup)
        assert np.array_equal(np.asarray(cnt), np.asarray(cnt_r)), key
        assert np.array_equal(np.asarray(blocks), np.asarray(blocks_r)), key
        assert np.array_equal(np.asarray(alive), np.asarray(alive_r)), key
        Zr = np.asarray(Zr)
        for i, s in enumerate(slots):
            if s < cap:
                assert np.array_equal(rows[s], Zr[i]), key
                assert np.array_equal(suffix[s],
                                      suffix_popcounts_np(Zr[i:i+1])[0]), key
        untouched = [r for r in range(cap) if r not in set(slots.tolist())]
        assert np.array_equal(rows[untouched], store0[untouched]), key
        assert np.array_equal(suffix[untouched], suffix0[untouched]), key


def test_screen_bound_is_sound():
    rng = np.random.default_rng(1)
    U = _random_bitmaps(rng, 32, 4, 16)
    V = _random_bitmaps(rng, 32, 4, 16)
    su, sv = suffix_popcounts_np(U), suffix_popcounts_np(V)
    true_count = popcount32_np(U & V).reshape(32, -1).sum(1)
    bound, _ = screen_pairs_ref(U[:, 0], V[:, 0], su[:, 1], sv[:, 1],
                                np.zeros(32, np.int32), jnp.int32(0))
    assert (np.asarray(bound) >= true_count).all()


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(2)
    tids = sorted(rng.choice(5000, size=700, replace=False).tolist())
    packed = pack_tidlists([tids], 5000, block_words=8)
    assert unpack_row(packed[0]).tolist() == tids


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 999), min_size=0, max_size=200,
                unique=True))
def test_pack_popcount_property(tids):
    packed = pack_tidlists([sorted(tids)], 1000, block_words=4)
    assert popcount32_np(packed).sum() == len(tids)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "B,Sq,Skv,H,KH,D,Dv,causal,dtype,tol",
    [
        (2, 128, 128, 4, 2, 32, 32, True, jnp.float32, 2e-5),
        (1, 256, 256, 8, 8, 64, 64, True, jnp.float32, 2e-5),
        (2, 128, 256, 4, 1, 32, 16, False, jnp.float32, 2e-5),
        (1, 128, 128, 4, 4, 128, 128, True, jnp.float32, 2e-5),
        (1, 128, 128, 4, 2, 32, 32, True, jnp.bfloat16, 3e-2),
    ])
def test_flash_attention_sweep(B, Sq, Skv, H, KH, D, Dv, causal, dtype, tol):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, KH, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, KH, Dv)), dtype)
    out = flash_attention(q, k, v, causal=causal, q_block=64, kv_block=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    assert err < tol, err


# ---------------------------------------------------------------------------
# embedding bag kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("V,D,B,L,comb", [
    (100, 16, 8, 5, "mean"), (64, 32, 16, 9, "sum"),
    (257, 8, 4, 3, "mean"), (1000, 64, 8, 20, "mean"),
])
def test_embedding_bag_sweep(V, D, B, L, comb):
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, V, (B, L)), jnp.int32)
    mask = jnp.asarray(rng.random((B, L)) < 0.8)
    out = embedding_bag(table, ids, mask, combiner=comb, bag_block=4)
    ref = embedding_bag_ref(table, ids, mask, combiner=comb)
    assert float(jnp.abs(out - ref).max()) < 1e-5


def test_embedding_bag_all_masked_bag():
    table = jnp.ones((8, 4), jnp.float32)
    ids = jnp.zeros((2, 3), jnp.int32)
    mask = jnp.asarray([[False] * 3, [True] * 3])
    out = embedding_bag(table, ids, mask, combiner="mean", bag_block=2)
    assert float(out[0].sum()) == 0.0
    assert float(out[1, 0]) == 1.0
