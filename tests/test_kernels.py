"""Per-kernel allclose sweeps: Pallas (interpret) vs pure-jnp oracles."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.bitmap import (pack_tidlists, suffix_popcounts_np,
                               popcount32_np, unpack_row)
from repro.kernels import ops
from repro.kernels.ref import (bitmap_intersect_es_ref, bitmap_diff_es_ref,
                               bitmap_intersect_full_ref, bitmap_count_ref,
                               flash_attention_ref, embedding_bag_ref,
                               screen_pairs_ref, screen_and_intersect_ref,
                               screen_and_diff_ref)
from repro.kernels.bitmap_diff import bitmap_diff_es
from repro.kernels.flash_attention import flash_attention
from repro.kernels.segment_embed import embedding_bag


# ---------------------------------------------------------------------------
# bitmap intersection kernel: bit-exact across modes / shapes / minsup
# ---------------------------------------------------------------------------

def _random_bitmaps(rng, n_pairs, n_blocks, bw, density=0.25):
    u = rng.integers(0, 2 ** 32, (n_pairs, n_blocks, bw),
                     dtype=np.uint64).astype(np.uint32)
    m = rng.integers(0, 2 ** 32, (n_pairs, n_blocks, bw),
                     dtype=np.uint64).astype(np.uint32)
    if density < 0.5:
        u &= m
    return u


@pytest.mark.parametrize("mode", ["and", "andnot"])
@pytest.mark.parametrize("n_blocks,bw", [(1, 128), (3, 128), (5, 8)])
def test_bitmap_kernel_matches_ref(mode, n_blocks, bw):
    rng = np.random.default_rng(42)
    n_pairs = 7
    U = _random_bitmaps(rng, n_pairs, n_blocks, bw)
    V = _random_bitmaps(rng, n_pairs, n_blocks, bw)
    su = suffix_popcounts_np(U)
    sv = suffix_popcounts_np(V)
    rho = popcount32_np(U).reshape(n_pairs, -1).sum(1).astype(np.int32)
    n_trans = n_blocks * bw * 32
    for minsup in (0, 1, n_trans // 64, n_trans // 8, n_trans):
        r = bitmap_intersect_es_ref(U, V, su, sv, rho, jnp.int32(minsup),
                                    mode=mode)
        p = ops.bitmap_intersect_es(U, V, su, sv, rho, jnp.int32(minsup),
                                    mode=mode, backend="pallas")
        for name, a, b in zip(("Z", "cnt", "blocks", "alive"), r, p, strict=True):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                mode, minsup, name)


def test_bitmap_kernel_es_aborts_and_freezes():
    """Dead pairs stop processing blocks and freeze counts (the paper's
    semantics quantised to blocks)."""
    rng = np.random.default_rng(0)
    U = _random_bitmaps(rng, 16, 6, 8, density=0.2)
    V = _random_bitmaps(rng, 16, 6, 8, density=0.2)
    su, sv = suffix_popcounts_np(U), suffix_popcounts_np(V)
    rho = np.zeros(16, np.int32)
    minsup = 6 * 8 * 32 // 4   # high threshold: most pairs die early
    Z, cnt, blocks, alive = ops.bitmap_intersect_es(
        U, V, su, sv, rho, jnp.int32(minsup), mode="and", backend="pallas")
    blocks = np.asarray(blocks)
    assert (blocks < 6).any()
    # dead pairs: output blocks beyond the abort point are zeroed
    Z = np.asarray(Z)
    for i in range(16):
        if blocks[i] < 6:
            assert not Z[i, blocks[i]:].any()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("mode", ["and", "andnot"])
def test_full_intersect_and_count_match_refs(backend, mode):
    """DL002 pins for the no-ES dispatches: ``ops.bitmap_intersect_full``
    vs ``bitmap_intersect_full_ref`` and ``ops.bitmap_count`` vs
    ``bitmap_count_ref``, cross-checked against a numpy oracle (the
    pallas count path reuses the ES kernel with minsup=0)."""
    rng = np.random.default_rng(7)
    U = _random_bitmaps(rng, 5, 3, 8, density=0.3)
    V = _random_bitmaps(rng, 5, 3, 8, density=0.3)
    expect = (U & V) if mode == "and" else (U & ~V)
    expect_cnt = popcount32_np(expect).reshape(5, -1).sum(1)

    Z, cnt = ops.bitmap_intersect_full(U, V, mode=mode, backend=backend)
    rZ, rcnt = bitmap_intersect_full_ref(U, V, mode=mode)
    assert np.array_equal(np.asarray(Z), expect)
    assert np.array_equal(np.asarray(Z), np.asarray(rZ))
    assert np.array_equal(np.asarray(cnt), expect_cnt)
    assert np.array_equal(np.asarray(cnt), np.asarray(rcnt))

    if mode == "and":
        c = ops.bitmap_count(U, V, backend=backend)
        assert np.array_equal(np.asarray(c), expect_cnt)
        assert np.array_equal(np.asarray(c),
                              np.asarray(bitmap_count_ref(U, V)))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("es", [False, True])
@pytest.mark.parametrize("mode", ["and", "andnot"])
@pytest.mark.parametrize("n_blocks,bw", [(1, 128), (3, 128), (5, 8)])
def test_fused_screen_and_intersect_matches_ref(backend, es, mode,
                                                n_blocks, bw):
    """ops.screen_and_intersect == screen_and_intersect_ref bit-for-bit
    (the ref now pins the whole dispatch, survivor-gated scatter
    included): child rows and suffix tables land at `slots` ONLY for
    pairs whose support cleared minsup and that finished alive; dead
    pairs' slots, padding slots (>= cap) and untouched store rows are
    all left untouched (ISSUE 5)."""
    rng = np.random.default_rng(11)
    cap, n_pairs = 32, 9
    store0 = _random_bitmaps(rng, cap, n_blocks, bw)
    suffix0 = suffix_popcounts_np(store0)
    ua = rng.integers(0, 12, n_pairs).astype(np.int32)
    vb = rng.integers(0, 12, n_pairs).astype(np.int32)
    slots = np.arange(12, 12 + n_pairs, dtype=np.int32)
    slots[-1] = cap + 3          # OOB sentinel: must be dropped
    rho = suffix0[ua, 0].astype(np.int32)
    n_trans = n_blocks * bw * 32
    for minsup in (0, 1, n_trans // 64, n_trans // 8):
        rows_r, suf_r, cnt_r, blocks_r, alive_r = screen_and_intersect_ref(
            store0, suffix0, ua, vb, slots, rho, jnp.int32(minsup),
            mode=mode, early_stop=es)
        rows, suffix, cnt, blocks, alive = ops.screen_and_intersect(
            jnp.asarray(store0), jnp.asarray(suffix0), ua, vb, slots, rho,
            jnp.int32(minsup), mode=mode, early_stop=es, backend=backend)
        rows, suffix = np.asarray(rows), np.asarray(suffix)
        key = (backend, es, mode, minsup)
        assert np.array_equal(np.asarray(cnt), np.asarray(cnt_r)), key
        assert np.array_equal(np.asarray(blocks), np.asarray(blocks_r)), key
        assert np.array_equal(np.asarray(alive), np.asarray(alive_r)), key
        assert np.array_equal(rows, np.asarray(rows_r)), key
        assert np.array_equal(suffix, np.asarray(suf_r)), key
        # survivor-only scatter: recompute the expected Z and check each
        # slot was written iff its pair survived the frequency gate
        es_minsup = minsup if es else 0
        Zr, _, _, _ = bitmap_intersect_es_ref(
            store0[ua], store0[vb], suffix0[ua], suffix0[vb], rho,
            jnp.int32(es_minsup), mode=mode)
        Zr = np.asarray(Zr)
        support = (np.asarray(cnt) if mode == "and"
                   else rho - np.asarray(cnt))
        keep = np.logical_and(np.asarray(alive), support >= minsup)
        for i, s in enumerate(slots):
            if s >= cap:
                continue
            if keep[i]:
                assert np.array_equal(rows[s], Zr[i]), key
                assert np.array_equal(
                    suffix[s], suffix_popcounts_np(Zr[i:i+1])[0]), key
            else:
                assert np.array_equal(rows[s], store0[s]), (key, i)
                assert np.array_equal(suffix[s], suffix0[s]), (key, i)
        untouched = [r for r in range(cap) if r not in set(slots.tolist())]
        assert np.array_equal(rows[untouched], store0[untouched]), key
        assert np.array_equal(suffix[untouched], suffix0[untouched]), key


# ---------------------------------------------------------------------------
# diffset (dEclat) kernels: bit-exact vs the ref, skip-aware work counter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_blocks,bw", [(1, 128), (3, 128), (6, 8)])
def test_bitmap_diff_kernel_matches_ref(n_blocks, bw):
    """Pallas diff kernel == bitmap_diff_es_ref bit-for-bit across shapes
    and minsup (ISSUE 6): Z, count, skip-aware blocks and aliveness."""
    rng = np.random.default_rng(23)
    n_pairs = 9
    U = _random_bitmaps(rng, n_pairs, n_blocks, bw)
    V = _random_bitmaps(rng, n_pairs, n_blocks, bw)
    su = suffix_popcounts_np(U)
    rho = su[:, 0].astype(np.int32)     # parent support: |d| <= rho holds
    n_trans = n_blocks * bw * 32
    for minsup in (0, 1, n_trans // 64, n_trans // 8, n_trans):
        r = bitmap_diff_es_ref(U, V, su, rho, jnp.int32(minsup))
        p = bitmap_diff_es(U, V, su, rho, jnp.int32(minsup))
        for name, a, b in zip(("Z", "cnt", "blocks", "alive"), r, p, strict=True):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                minsup, name)


def test_diff_scan_skips_zero_mass_u_blocks():
    """The diff scan is bit-identical to the legacy andnot scan on Z /
    count / aliveness, but its work counter charges only visited blocks
    whose U suffix mass is positive (Z = U & ~V is zero wherever U is) —
    the representation saving the dense word_ops win comes from."""
    rng = np.random.default_rng(31)
    n_pairs, n_blocks, bw = 12, 6, 8
    U = _random_bitmaps(rng, n_pairs, n_blocks, bw, density=0.2)
    U[:, 1] = 0                          # skippable zero-mass U blocks
    U[:, 4] = 0
    V = _random_bitmaps(rng, n_pairs, n_blocks, bw)
    su, sv = suffix_popcounts_np(U), suffix_popcounts_np(V)
    rho = su[:, 0].astype(np.int32)
    mass = (su[:, :-1] - su[:, 1:]).astype(np.int64)
    for minsup in (0, 5, 40):
        Zd, cd, bd, ad = bitmap_diff_es_ref(U, V, su, rho,
                                            jnp.int32(minsup))
        Za, ca, ba, aa = bitmap_intersect_es_ref(U, V, su, sv, rho,
                                                 jnp.int32(minsup),
                                                 mode="andnot")
        assert np.array_equal(np.asarray(Zd), np.asarray(Za)), minsup
        assert np.array_equal(np.asarray(cd), np.asarray(ca)), minsup
        assert np.array_equal(np.asarray(ad), np.asarray(aa)), minsup
        bd, ba = np.asarray(bd), np.asarray(ba)
        assert (bd <= ba).all(), minsup
        # aliveness is a prefix property, so the andnot scan's visited
        # set is exactly range(ba[i]); the diff counter drops the
        # zero-mass members of that set
        for i in range(n_pairs):
            expect = int(((np.arange(n_blocks) < ba[i])
                          & (mass[i] > 0)).sum())
            assert bd[i] == expect, (minsup, i)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("es", [False, True])
@pytest.mark.parametrize("n_blocks,bw", [(1, 128), (3, 128), (5, 8)])
def test_fused_screen_and_diff_matches_ref(backend, es, n_blocks, bw):
    """ops.screen_and_diff == screen_and_diff_ref bit-for-bit, survivor
    gated scatter included (ISSUE 6): difference rows and suffix tables
    land at `slots` ONLY for pairs whose support rho - |d| cleared
    minsup and that finished alive; dead pairs' slots, padding slots
    (>= cap) and untouched store rows are all left untouched."""
    rng = np.random.default_rng(13)
    cap, n_pairs = 32, 9
    store0 = _random_bitmaps(rng, cap, n_blocks, bw)
    suffix0 = suffix_popcounts_np(store0)
    ua = rng.integers(0, 12, n_pairs).astype(np.int32)
    vb = rng.integers(0, 12, n_pairs).astype(np.int32)
    slots = np.arange(12, 12 + n_pairs, dtype=np.int32)
    slots[-1] = cap + 3          # OOB sentinel: must be dropped
    rho = suffix0[ua, 0].astype(np.int32)
    n_trans = n_blocks * bw * 32
    for minsup in (0, 1, n_trans // 64, n_trans // 8):
        rows_r, suf_r, cnt_r, blocks_r, alive_r = screen_and_diff_ref(
            store0, suffix0, ua, vb, slots, rho, jnp.int32(minsup),
            early_stop=es)
        rows, suffix, cnt, blocks, alive = ops.screen_and_diff(
            jnp.asarray(store0), jnp.asarray(suffix0), ua, vb, slots, rho,
            jnp.int32(minsup), early_stop=es, backend=backend)
        rows, suffix = np.asarray(rows), np.asarray(suffix)
        key = (backend, es, minsup)
        assert np.array_equal(np.asarray(cnt), np.asarray(cnt_r)), key
        assert np.array_equal(np.asarray(blocks), np.asarray(blocks_r)), key
        assert np.array_equal(np.asarray(alive), np.asarray(alive_r)), key
        assert np.array_equal(rows, np.asarray(rows_r)), key
        assert np.array_equal(suffix, np.asarray(suf_r)), key
        es_minsup = minsup if es else 0
        Zr, _, _, _ = bitmap_diff_es_ref(
            store0[ua], store0[vb], suffix0[ua], rho, jnp.int32(es_minsup))
        Zr = np.asarray(Zr)
        support = rho - np.asarray(cnt)
        keep = np.logical_and(np.asarray(alive), support >= minsup)
        for i, s in enumerate(slots):
            if s >= cap:
                continue
            if keep[i]:
                assert np.array_equal(rows[s], Zr[i]), key
                assert np.array_equal(
                    suffix[s], suffix_popcounts_np(Zr[i:i+1])[0]), key
            else:
                assert np.array_equal(rows[s], store0[s]), (key, i)
                assert np.array_equal(suffix[s], suffix0[s]), (key, i)
        untouched = [r for r in range(cap) if r not in set(slots.tolist())]
        assert np.array_equal(rows[untouched], store0[untouched]), key
        assert np.array_equal(suffix[untouched], suffix0[untouched]), key


def test_screen_bound_is_sound():
    rng = np.random.default_rng(1)
    U = _random_bitmaps(rng, 32, 4, 16)
    V = _random_bitmaps(rng, 32, 4, 16)
    su, sv = suffix_popcounts_np(U), suffix_popcounts_np(V)
    true_count = popcount32_np(U & V).reshape(32, -1).sum(1)
    bound, _ = screen_pairs_ref(U[:, 0], V[:, 0], su[:, 1], sv[:, 1],
                                np.zeros(32, np.int32), jnp.int32(0))
    assert (np.asarray(bound) >= true_count).all()


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(2)
    tids = sorted(rng.choice(5000, size=700, replace=False).tolist())
    packed = pack_tidlists([tids], 5000, block_words=8)
    assert unpack_row(packed[0]).tolist() == tids


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 999), min_size=0, max_size=200,
                unique=True))
def test_pack_popcount_property(tids):
    packed = pack_tidlists([sorted(tids)], 1000, block_words=4)
    assert popcount32_np(packed).sum() == len(tids)


# ---------------------------------------------------------------------------
# allocator compaction gather: bit-exact across slab ranks and backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("new_cap", [4, 16, 48])
def test_compact_rows_matches_ref(backend, new_cap):
    """ops.compact_rows == compact_gather_ref on rows AND suffix slabs:
    live destinations carry their source bit-for-bit, dead destinations
    (perm < 0) come up zeroed."""
    from repro.kernels.ref import compact_gather_ref

    rng = np.random.default_rng(5)
    cap = 32
    rows = rng.integers(0, 2 ** 32, (cap, 3, 8), dtype=np.uint64
                        ).astype(np.uint32)
    suffix = suffix_popcounts_np(rows)
    perm = rng.permutation(cap)[:new_cap].astype(np.int32)
    perm[::3] = -1                       # scattered dead slots
    er = np.asarray(compact_gather_ref(jnp.asarray(rows), perm))
    es = np.asarray(compact_gather_ref(jnp.asarray(suffix), perm))
    gr, gs = ops.compact_rows(jnp.asarray(rows), jnp.asarray(suffix),
                              perm, backend=backend)
    assert np.array_equal(np.asarray(gr), er), (backend, new_cap)
    assert np.array_equal(np.asarray(gs), es), (backend, new_cap)
    for i, src in enumerate(perm):
        if src >= 0:
            assert np.array_equal(er[i], rows[src])
        else:
            assert not er[i].any()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_compact_codes_matches_ref(backend):
    from repro.kernels.ref import compact_gather_ref

    rng = np.random.default_rng(6)
    codes = rng.integers(0, 1000, (64, 3)).astype(np.int32)
    perm = np.concatenate([rng.permutation(64)[:20],
                           np.full(12, -1)]).astype(np.int32)
    e = np.asarray(compact_gather_ref(jnp.asarray(codes), perm))
    g = np.asarray(ops.compact_codes(jnp.asarray(codes), perm,
                                     backend=backend))
    assert np.array_equal(g, e), backend


# ---------------------------------------------------------------------------
# N-list kernels (PrePost+): fused extend + standalone merge vs the ref
# ---------------------------------------------------------------------------

def _random_pool(rng, cap, offs_lens):
    """Random PPC-code slab with ascending-pre extents at (off, len)."""
    codes = np.stack([rng.integers(0, 1000, cap),
                      rng.integers(0, 1000, cap),
                      rng.integers(1, 20, cap)], axis=1).astype(np.int32)
    for off, ln in offs_lens:
        seg = codes[off:off + ln]
        codes[off:off + ln] = seg[np.argsort(seg[:, 0], kind="stable")]
    return codes


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("es", [False, True])
@pytest.mark.parametrize("lu,lv", [(8, 8), (8, 32), (32, 8)])
def test_nlist_extend_matches_ref(backend, es, lu, lv):
    """ops.nlist_extend == ref.nlist_extend_ref bit-for-bit on both
    backends: scattered child extents, lengths, supports, comparison
    counts and aliveness (ISSUE 3 acceptance)."""
    from repro.kernels.ref import nlist_extend_ref

    rng = np.random.default_rng(7)
    cap, n_pairs = 1024, 9
    u_off = rng.integers(0, 256, n_pairs).astype(np.int32)
    v_off = rng.integers(256, 512 - lv, n_pairs).astype(np.int32)
    u_len = rng.integers(1, lu + 1, n_pairs).astype(np.int32)
    v_len = rng.integers(1, lv + 1, n_pairs).astype(np.int32)
    codes = _random_pool(rng, cap, list(zip(u_off, u_len, strict=True))
                         + list(zip(v_off, v_len, strict=True)))
    out_off = (512 + lu * np.arange(n_pairs)).astype(np.int32)
    out_off[-1] = cap + 5            # OOB sentinel: must be dropped
    rho = rng.integers(0, 120, n_pairs).astype(np.int32)

    for minsup in (0, 1, 10, 80):
        r = nlist_extend_ref(jnp.asarray(codes), u_off, u_len, v_off,
                             v_len, out_off, rho, jnp.int32(minsup),
                             lu=lu, lv=lv, early_stop=es)
        g = ops.nlist_extend(jnp.asarray(codes), u_off, u_len, v_off,
                             v_len, out_off, rho, jnp.int32(minsup),
                             lu=lu, lv=lv, early_stop=es, backend=backend)
        for name, a, b in zip(("codes", "child_len", "support",
                               "comparisons", "checks", "alive"), r, g,
                               strict=True):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                backend, es, minsup, name)
        # survivor-only scatter (ISSUE 5): only extents of pairs whose
        # support cleared minsup are written; dead pairs' extents, OOB
        # extents and untouched pool rows all stay untouched
        new_codes = np.asarray(g[0])
        child_len = np.asarray(g[1])
        support = np.asarray(g[2])
        written = set()
        for p in range(n_pairs - 1):
            if support[p] >= minsup:
                written.update(range(out_off[p], out_off[p] + child_len[p]))
        untouched = [i for i in range(cap) if i not in written]
        assert np.array_equal(new_codes[untouched], codes[untouched]), (
            backend, es, minsup)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("es", [False, True])
def test_nlist_presize_scatter_split_matches_ref_and_extend(backend, es):
    """The two-dispatch split (ISSUE 5 tentpole) is pinned twice over:
    ops.nlist_presize == ref.nlist_presize_ref bit-for-bit on both
    backends, and presize + tight survivor-only nlist_scatter writes
    exactly the children the one-dispatch nlist_extend would have
    (same contents, read back from tight extents)."""
    from repro.kernels.ref import (nlist_presize_ref, nlist_scatter_ref,
                                   nlist_extend_ref)

    rng = np.random.default_rng(17)
    cap, n_pairs, lu, lv = 2048, 9, 8, 32
    u_off = rng.integers(0, 256, n_pairs).astype(np.int32)
    v_off = rng.integers(256, 512 - lv, n_pairs).astype(np.int32)
    u_len = rng.integers(1, lu + 1, n_pairs).astype(np.int32)
    v_len = rng.integers(1, lv + 1, n_pairs).astype(np.int32)
    codes = _random_pool(rng, cap, list(zip(u_off, u_len, strict=True))
                         + list(zip(v_off, v_len, strict=True)))
    rho = rng.integers(0, 120, n_pairs).astype(np.int32)

    for minsup in (0, 1, 10, 80):
        r = nlist_presize_ref(jnp.asarray(codes), u_off, u_len, v_off,
                              v_len, rho, jnp.int32(minsup),
                              lu=lu, lv=lv, early_stop=es)
        g = ops.nlist_presize(jnp.asarray(codes), u_off, u_len, v_off,
                              v_len, rho, jnp.int32(minsup),
                              lu=lu, lv=lv, early_stop=es, backend=backend)
        for name, a, b in zip(("out_slot", "child_len", "support",
                               "comparisons", "checks", "alive"), r, g,
                               strict=True):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                backend, es, minsup, name)
        out_slot, child_len, support = (np.asarray(g[0]),
                                        np.asarray(g[1]),
                                        np.asarray(g[2]))
        # host side of the split: tight extents for survivors only
        keep = support >= minsup
        out_off = np.full(n_pairs, cap, np.int32)       # dropped
        bump = 512
        for p in np.nonzero(keep)[0]:
            out_off[p] = bump
            bump += int(child_len[p])                   # TIGHT: exact len
        sc_codes, sc_len = ops.nlist_scatter(
            jnp.asarray(codes), g[0], u_off, u_len, v_off, v_len,
            out_off, lu=lu, lv=lv, backend=backend)
        rc_codes, rc_len = nlist_scatter_ref(
            jnp.asarray(codes), r[0], u_off, u_len, v_off, v_len,
            out_off, lu=lu, lv=lv)
        assert np.array_equal(np.asarray(sc_codes), np.asarray(rc_codes))
        assert np.array_equal(np.asarray(sc_len), np.asarray(rc_len))
        # the one-dispatch composition scatters the same children
        ex_off = (512 + lu * np.arange(n_pairs)).astype(np.int32)
        ex = nlist_extend_ref(jnp.asarray(codes), u_off, u_len, v_off,
                              v_len, ex_off, rho, jnp.int32(minsup),
                              lu=lu, lv=lv, early_stop=es)
        ex_codes = np.asarray(ex[0])
        sc_codes = np.asarray(sc_codes)
        assert np.array_equal(np.asarray(ex[1]), child_len)
        for p in np.nonzero(keep)[0]:
            ln = int(child_len[p])
            assert np.array_equal(sc_codes[out_off[p]:out_off[p] + ln],
                                  ex_codes[ex_off[p]:ex_off[p] + ln]), (
                backend, es, minsup, p)
        # non-survivors and the rest of the slab stay untouched
        written = set()
        for p in np.nonzero(keep)[0]:
            written.update(range(out_off[p], out_off[p] + int(child_len[p])))
        untouched = [i for i in range(cap) if i not in written]
        assert np.array_equal(sc_codes[untouched], codes[untouched])


@pytest.mark.parametrize("es", [False, True])
def test_nlist_merge_pallas_matches_ref(es):
    """Standalone padded-batch merge: pallas kernel vs the jnp ref."""
    from repro.kernels.ref import nlist_intersect_ref

    rng = np.random.default_rng(3)
    n_pairs, lu, lv = 16, 8, 32

    def mk(n, width):
        pre = np.sort(rng.integers(0, 500, (n, width)).astype(np.int32), 1)
        post = rng.integers(0, 500, (n, width)).astype(np.int32)
        freq = rng.integers(1, 10, (n, width)).astype(np.int32)
        return pre, post, freq

    up, upo, uf = mk(n_pairs, lu)
    vp, vpo, vf = mk(n_pairs, lv)
    ul = rng.integers(1, lu + 1, n_pairs).astype(np.int32)
    vl = rng.integers(1, lv + 1, n_pairs).astype(np.int32)
    rho = rng.integers(0, 100, n_pairs).astype(np.int32)
    for minsup in (0, 1, 20):
        r = nlist_intersect_ref(up, upo, uf, vp, vpo, vf, ul, vl, rho,
                                jnp.int32(minsup), early_stop=es)
        p = ops.nlist_intersect(up, upo, uf, vp, vpo, vf, ul, vl, rho,
                                jnp.int32(minsup), early_stop=es,
                                backend="pallas")
        for name, a, b in zip(("out_slot", "support", "cmps", "checks",
                               "alive"), r, p, strict=True):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                es, minsup, name)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "B,Sq,Skv,H,KH,D,Dv,causal,dtype,tol",
    [
        (2, 128, 128, 4, 2, 32, 32, True, jnp.float32, 2e-5),
        (1, 256, 256, 8, 8, 64, 64, True, jnp.float32, 2e-5),
        (2, 128, 256, 4, 1, 32, 16, False, jnp.float32, 2e-5),
        (1, 128, 128, 4, 4, 128, 128, True, jnp.float32, 2e-5),
        (1, 128, 128, 4, 2, 32, 32, True, jnp.bfloat16, 3e-2),
    ])
def test_flash_attention_sweep(B, Sq, Skv, H, KH, D, Dv, causal, dtype, tol):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, KH, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, KH, Dv)), dtype)
    out = flash_attention(q, k, v, causal=causal, q_block=64, kv_block=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    assert err < tol, err


# ---------------------------------------------------------------------------
# embedding bag kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("V,D,B,L,comb", [
    (100, 16, 8, 5, "mean"), (64, 32, 16, 9, "sum"),
    (257, 8, 4, 3, "mean"), (1000, 64, 8, 20, "mean"),
])
def test_embedding_bag_sweep(V, D, B, L, comb):
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, V, (B, L)), jnp.int32)
    mask = jnp.asarray(rng.random((B, L)) < 0.8)
    out = embedding_bag(table, ids, mask, combiner=comb, bag_block=4)
    ref = embedding_bag_ref(table, ids, mask, combiner=comb)
    assert float(jnp.abs(out - ref).max()) < 1e-5


def test_embedding_bag_all_masked_bag():
    table = jnp.ones((8, 4), jnp.float32)
    ids = jnp.zeros((2, 3), jnp.int32)
    mask = jnp.asarray([[False] * 3, [True] * 3])
    out = embedding_bag(table, ids, mask, combiner="mean", bag_block=2)
    assert float(out[0].sum()) == 0.0
    assert float(out[1, 0]) == 1.0
