"""Training substrate: optimizers, microbatching, checkpoint/restart."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train.optimizer import (OptConfig, opt_init, lr_at,
                                   clip_by_global_norm, opt_state_logical)
from repro.train.train_step import make_train_step
from repro.train.checkpoint import (save_checkpoint, restore_checkpoint,
                                    latest_step, AsyncCheckpointer)


def _quadratic_problem(seed=0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(8, 8)) / 4 + np.eye(8), jnp.float32)
    b = jnp.asarray(rng.normal(size=(8,)), jnp.float32)

    def loss_fn(params, batch):
        r = A @ params["w"] - b + 0 * batch["x"].sum()
        return (r ** 2).sum(), {"r": (r ** 2).sum()}

    params = {"w": jnp.zeros((8,), jnp.float32)}
    return loss_fn, params


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_converges_on_quadratic(kind):
    loss_fn, params = _quadratic_problem()
    cfg = OptConfig(kind=kind, lr=0.05, warmup_steps=5, decay_steps=400,
                    weight_decay=0.0, grad_clip=100.0)
    step = jax.jit(make_train_step(loss_fn, cfg))
    state = opt_init(params, cfg)
    batch = {"x": jnp.zeros((4, 1))}
    losses = []
    for _ in range(300):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.05
    assert np.isfinite(losses).all()


def test_microbatching_matches_full_batch_grads():
    """n_mb gradient accumulation == single big batch (linear loss avg)."""
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(16,)), jnp.float32)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return ((pred - batch["y"]) ** 2).mean(), {}

    params = {"w": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    cfg = OptConfig(kind="adamw", lr=1e-2, weight_decay=0.0)
    batch = {"x": X, "y": y}

    p1, s1, _ = jax.jit(make_train_step(loss_fn, cfg, 1))(
        params, opt_init(params, cfg), batch)
    p4, s4, _ = jax.jit(make_train_step(loss_fn, cfg, 4))(
        params, opt_init(params, cfg), batch)
    np.testing.assert_allclose(p1["w"], p4["w"], rtol=1e-5)


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                    min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                 rel=1e-5)


def test_opt_state_logical_mirrors_params():
    logical = {"w": ("embed", "ff"), "b": ("ff",)}
    adamw = opt_state_logical(logical, OptConfig(kind="adamw"))
    assert adamw["mu"] == logical
    fac = opt_state_logical(logical, OptConfig(kind="adafactor"))
    assert fac["v"]["w"] == {"vr": ("embed",), "vc": ("ff",)}


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state(seed):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16)},
        "opt": {"step": jnp.int32(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    state = _state(0)
    save_checkpoint(d, 7, state, extra={"mesh": [1, 1]})
    template = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, step, extra = restore_checkpoint(d, template)
    assert step == 7 and extra == {"mesh": [1, 1]}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored),
                    strict=True):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rotation_and_latest(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, _state(s), keep=2)
    assert latest_step(d) == 5
    kept = sorted(x for x in os.listdir(d) if x.startswith("step-"))
    assert len(kept) == 2


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, _state(1))
    assert not [x for x in os.listdir(d) if x.startswith("tmp-")]


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(d, keep=2)
    for s in (10, 20):
        ck.save(s, _state(s), extra={"s": s})
    ck.wait()
    assert latest_step(d) == 20
    restored, step, extra = restore_checkpoint(d, _state(0))
    assert extra["s"] == 20


def test_restore_missing_leaf_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        restore_checkpoint(d, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_elastic_restore_resharding(tmp_path):
    """Checkpoints are mesh-agnostic: restore re-places leaves onto the
    current device set (pod count can change between runs)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    d = str(tmp_path / "ckpt")
    state = _state(3)
    save_checkpoint(d, 1, state)
    mesh = make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), state)
    restored, _, _ = restore_checkpoint(d, state, shardings=sh)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding.mesh.shape == mesh.shape
