"""Subprocess smoke for the runnable examples (ISSUE 7 CI satellite).

The quickstart scripts are the first thing a new user runs; importing
them is not enough (both build datasets and drive full mines under
``__main__``), so each is executed as a real subprocess exactly the way
a user would.  They insert ``src`` into ``sys.path`` themselves and the
distributed example sets up its own 8-device XLA host, so no special
environment is needed beyond the repo checkout.
"""

import pathlib
import subprocess
import sys

import pytest

_EXAMPLES = pathlib.Path(__file__).resolve().parent.parent / "examples"


@pytest.mark.parametrize("script", ["quickstart.py",
                                    "distributed_mining.py"])
def test_example_runs_clean(script):
    proc = subprocess.run(
        [sys.executable, str(_EXAMPLES / script)],
        capture_output=True, text=True, timeout=600,
        cwd=str(_EXAMPLES.parent))
    assert proc.returncode == 0, (
        f"{script} failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    # Both scripts end on a correctness line; a silent truncated run
    # (e.g. an import-time crash swallowed by a bare except) must fail.
    marker = "saved" if script == "quickstart.py" else "OK"
    assert marker in proc.stdout, proc.stdout
