"""The beyond-paper optimized implementations must be semantically
equivalent to their paper-faithful baselines (EXPERIMENTS.md §Perf)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.compat import make_mesh


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def test_mining_round_v2_matches_v1():
    """Precomputed-suffix + shared-a round == baseline round (bounds may
    only get TIGHTER-or-equal never looser; counts identical)."""
    from repro.core.distributed import (make_mining_round,
                                        make_mining_round_v2)
    from repro.core.bitmap import popcount32_np

    mesh = _mesh11()
    rng = np.random.default_rng(0)
    rows, nb, bw = 16, 4, 8
    store = rng.integers(0, 2 ** 32, (rows, nb, bw),
                         dtype=np.uint64).astype(np.uint32)
    # shared-'a' chunks of 8 pairs
    n = 16
    a = np.repeat(rng.integers(0, rows, 2), 8).astype(np.int32)
    b = rng.integers(0, rows, n).astype(np.int32)
    pairs = np.stack([a, b], 1)
    rho = np.zeros(n, np.int32)

    v1 = jax.jit(make_mining_round(mesh, pair_chunk=8))
    bound1, count1 = v1(store, pairs, rho)

    # shard-local suffix mass (1 shard here): popcount of blocks 1..
    suffix1 = popcount32_np(store[:, 1:]).reshape(rows, -1).sum(1)
    suffix1 = suffix1.astype(np.int32)[:, None]
    v2 = jax.jit(make_mining_round_v2(mesh, pair_chunk=8))
    bound2, count2 = v2(store, suffix1, pairs, rho)

    assert np.array_equal(np.asarray(count1), np.asarray(count2))
    assert np.array_equal(np.asarray(bound1), np.asarray(bound2))
    # soundness: bounds dominate the true counts
    true = popcount32_np(store[pairs[:, 0]] & store[pairs[:, 1]]
                         ).reshape(n, -1).sum(1)
    assert (np.asarray(bound2) >= true).all()


def test_screened_retrieval_matches_full():
    """bf16-screen + fp32-rescore returns the same top-k as the full
    fp32 scan (the screen shortlist is far larger than k)."""
    from repro.models import recsys as R

    cfg = R.TwoTowerConfig(n_users=200, n_items=5000, n_user_hist=10,
                           embed_dim=32, tower_mlp=(64, 32))
    params, _ = R.twotower_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    uid = jnp.asarray(rng.integers(0, 200, (1,)), jnp.int32)
    hist = jnp.asarray(rng.integers(0, 5000, (1, 10)), jnp.int32)
    mask = jnp.ones((1, 10), bool)
    cand = jnp.arange(5000, dtype=jnp.int32)

    _, ref_idx = R.retrieval_scores(params, cfg, uid, hist, mask, cand,
                                    topk=20)
    _, got_idx = R.retrieval_scores_screened(params, cfg, uid, hist, mask,
                                             cand, topk=20, shortlist=512)
    ref_set = set(np.asarray(ref_idx)[0].tolist())
    got_set = set(np.asarray(got_idx)[0].tolist())
    # bf16 screen can perturb near-ties at the tail; demand >=90% overlap
    # and exact agreement on the top-5
    assert len(ref_set & got_set) >= 18
    assert np.array_equal(np.asarray(ref_idx)[0][:5],
                          np.asarray(got_idx)[0][:5])


def test_prefix_screen_exact_topk():
    """The certified prefix-dot screen (benchmarks/bench_retrieval.py)
    returns the EXACT top-k — the Cauchy-Schwarz suffix bound makes it
    lossless, exactly like the paper's ES criterion."""
    from benchmarks.bench_retrieval import (full_scan, make_candidates,
                                            build_index, screened_scan)
    rng = np.random.default_rng(2)
    cand = make_candidates(20_000, 64, seed=2, spectrum=1.0)
    scales = (np.arange(1, 65, dtype=np.float32) ** -1.0)
    q = rng.normal(size=(64,)).astype(np.float32) * scales
    q /= np.linalg.norm(q)
    ref = full_scan(q, cand, 50)
    cr, cr_p, rot, tails = build_index(cand, prefix=16)
    got, frac = screened_scan(rot.T @ q, cr, cr_p, tails, 16, 50)
    assert set(ref.tolist()) == set(got.tolist())
    assert frac < 0.6   # the screen actually prunes


def test_sharded_gnn_loss_matches_reference():
    """shard_map locality-partitioned GNN == plain forward_full on a
    1x1 mesh (same math, different movement)."""
    from repro.models import gnn as G
    from repro.data.graph_data import gen_powerlaw_graph

    mesh = _mesh11()
    F_pad = 16
    cfg = G.SAGEConfig(name="t", d_feat=F_pad, d_hidden=8, n_classes=4,
                       dtype="float32")
    g = gen_powerlaw_graph(64, 4.0, F_pad, 4, seed=0)
    params, _ = G.init_params(jax.random.PRNGKey(0), cfg)

    # one shard => edge_dst_local == edge_dst; suffix of partitioning holds
    loss_sharded = G.make_sharded_loss(mesh, cfg, 64, F_pad,
                                       node_axes=("data",),
                                       feat_axis="model")
    l1 = jax.jit(loss_sharded)(params, jnp.asarray(g.x),
                               jnp.asarray(g.edge_src),
                               jnp.asarray(g.edge_dst),
                               jnp.asarray(g.labels),
                               jnp.ones(64, bool))
    l2, _ = G.loss_full(params, cfg, jnp.asarray(g.x),
                        jnp.asarray(g.edge_src), jnp.asarray(g.edge_dst),
                        jnp.asarray(g.labels), jnp.ones(64, bool))
    assert float(jnp.abs(l1 - l2)) < 1e-5
    # and it is differentiable (the train-step path)
    grads = jax.grad(lambda p: loss_sharded(
        p, jnp.asarray(g.x), jnp.asarray(g.edge_src),
        jnp.asarray(g.edge_dst), jnp.asarray(g.labels),
        jnp.ones(64, bool)))(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(grads))
