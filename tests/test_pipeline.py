"""Dispatch-pipeline edge cases (ISSUE 7).

Covers the in-flight ring in ``core.frontier.FrontierScheduler``:
leaf-only drain groups, compaction landing while groups are in flight
(remap must reach pending handles, and only pending ones), the
deterministic-order guard (pipelined vs serial ``inflight=1`` emit the
same itemsets with identical order-invariant accounting), the reserve
invariant generalised over pending groups, the occupancy metric, and
per-bucket chunk-width autotuning (same results, fewer device calls,
bucketed dispatch widths only).
"""

import random

import numpy as np
import pytest

import repro.core.eclat as eclat_mod
from repro.core.bitmap import (NL_PAIR_CHUNK_BUCKETS, PAIR_CHUNK_BUCKETS,
                               chunk_width_for)
from repro.core.eclat import BitmapMiner, mine_bitmap
from repro.core.frontier import ClassNode, FrontierScheduler
from repro.core.oracle import mine_bruteforce
from repro.core.prepost import mine_prepost_device
from repro.data.transactions import gen_powerlaw_baskets


def _random_db(seed, n_items=12, n_trans=80, p=0.35):
    rng = random.Random(seed)
    db = [[i for i in range(n_items) if rng.random() < p]
          for _ in range(n_trans)]
    return [t for t in db if t]


# Counters that are invariant to drain-group composition (each pair's
# device work is independent of which chunk it rides in); the
# composition-dependent ones — device_calls, grows, compactions,
# peak_live — may legitimately differ between pipelined and serial runs.
_BITMAP_INVARIANT = ("candidates", "nodes", "word_ops", "word_ops_full",
                     "screened_out", "kernel_aborts", "child_scatters",
                     "scatter_words")
_NLIST_INVARIANT = ("candidates", "nodes", "comparisons", "es_checks",
                    "es_aborts", "child_scatters", "scatter_words")


# ---------------------------------------------------------------------------
# deterministic-order guard: pipelined == serial results + accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["eclat", "declat", "adaptive"])
def test_pipelined_matches_serial_bitmap(scheme):
    """inflight=3 vs inflight=1 on chunk sizes small enough to force
    real overlap: identical itemsets (== brute force) and identical
    order-invariant counters; occupancy is the discriminator (0.0
    serial, > 0 pipelined)."""
    kw = dict(diff_density=0.3) if scheme == "adaptive" else {}
    for seed in (0, 1):
        db = _random_db(seed)
        ms = 4
        expected = mine_bruteforce(db, ms)
        out1, st1 = mine_bitmap(db, ms, scheme=scheme, block_words=1,
                                pair_chunk=8, inflight=1, **kw)
        out3, st3 = mine_bitmap(db, ms, scheme=scheme, block_words=1,
                                pair_chunk=8, inflight=3, **kw)
        assert out1 == expected and out3 == expected, (scheme, seed)
        for f in _BITMAP_INVARIANT:
            assert getattr(st1, f) == getattr(st3, f), (scheme, seed, f)
        assert st1.device_occupancy == 0.0
        assert st3.device_occupancy > 0.0
        assert st1.inflight_groups == 1 and st3.inflight_groups == 3


def test_pipelined_matches_serial_prepost():
    for seed in (0, 1):
        db = _random_db(seed)
        ms = 4
        expected = mine_bruteforce(db, ms)
        out1, st1 = mine_prepost_device(db, ms, pair_chunk=4, inflight=1)
        out3, st3 = mine_prepost_device(db, ms, pair_chunk=4, inflight=3)
        assert out1 == expected and out3 == expected, seed
        for f in _NLIST_INVARIANT:
            assert getattr(st1, f) == getattr(st3, f), (seed, f)
        assert st1.device_occupancy == 0.0
        assert st3.device_occupancy > 0.0


def test_pipelined_traversal_is_deterministic():
    """Two identical pipelined runs emit the same itemsets in the same
    order with the same full accounting dict (timing fields aside) —
    the ring changes batching, never determinism."""
    db = _random_db(2)
    ms = 4
    runs = []
    for _ in range(2):
        out, st = mine_bitmap(db, ms, block_words=1, pair_chunk=8,
                              inflight=3)
        d = st.as_dict()
        for timing in ("runtime_s", "assemble_s", "resolve_s"):
            d.pop(timing, None)
        runs.append((list(out.items()), d))
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# leaf-only drain groups
# ---------------------------------------------------------------------------

class _LeafClient:
    """Minimal client: records releases; evaluate_pairs must never run."""

    def __init__(self):
        self.released = []
        self.evaluated = 0

    def release(self, klass):
        self.released.append(klass.itemsets)

    def evaluate_pairs(self, cols):
        self.evaluated += 1
        return []

    def pair_columns(self, klass, ia, ib):
        return {"x": np.zeros(ia.size, np.int32)}

    def make_class(self, parent, children):
        raise AssertionError("no children expected")

    def emit(self, itemset, support):
        raise AssertionError("nothing to emit")

    def maybe_compact(self, reserve):
        return None


def test_leaf_only_drain_groups_terminate_cleanly():
    """A frontier of only leaf classes (< 2 members) produces empty
    drain groups: the pipelined loop must release every leaf and
    terminate without dispatching or hanging the ring."""
    client = _LeafClient()
    sched = FrontierScheduler(client, pair_chunk=4, inflight=2)
    for k in range(5):
        sched.push(ClassNode(itemsets=[(k,)],
                             rows=np.asarray([k], np.int32),
                             supports=np.asarray([1], np.int32)))
    root = ClassNode(itemsets=[(99,)], rows=np.asarray([99], np.int32),
                     supports=np.asarray([1], np.int32))
    sched.run(root)
    assert client.evaluated == 0
    assert len(client.released) == 6
    assert sched.groups_dispatched == 0
    assert sched.device_occupancy == 0.0


def test_leaf_groups_interleaved_with_real_groups():
    """Leaves interleaved in the stack are released inline during the
    refill loop while real groups pipeline around them — results still
    exact."""
    db = _random_db(3, n_items=10, p=0.3)
    ms = 3
    out, st = mine_bitmap(db, ms, block_words=1, pair_chunk=4, inflight=3)
    assert out == mine_bruteforce(db, ms)
    assert st.device_occupancy > 0.0


# ---------------------------------------------------------------------------
# compaction while groups are in flight
# ---------------------------------------------------------------------------

def test_compaction_remaps_pending_handles_only(monkeypatch):
    """Forced compaction (threshold 1.0) with a deep ring: the old->new
    slot mapping must rewrite the pending result handles of in-flight
    groups (their child slots move) and the mined output must stay
    exact.  Retired handles are popped before the next compaction point,
    so remap never touches one — asserted via remap call bookkeeping."""
    remap_calls = {"pending": 0, "resolved": 0}
    real_remap = eclat_mod.PendingPairResult.remap
    real_resolve = eclat_mod.PendingPairResult.resolve

    def remap_spy(self, mapping):
        if getattr(self, "_resolved", False):
            remap_calls["resolved"] += 1
        else:
            remap_calls["pending"] += 1
        return real_remap(self, mapping)

    def resolve_spy(self):
        self._resolved = True
        return real_resolve(self)

    monkeypatch.setattr(eclat_mod.PendingPairResult, "remap", remap_spy)
    monkeypatch.setattr(eclat_mod.PendingPairResult, "resolve", resolve_spy)
    # __slots__ on the handle has no _resolved; widen via a subclass.
    class _Handle(eclat_mod.PendingPairResult):
        _resolved = False
    monkeypatch.setattr(eclat_mod, "PendingPairResult", _Handle)

    db = gen_powerlaw_baskets(n_trans=120, n_items=60, avg_trans_len=5,
                              seed=0)
    ms = 3
    out, st = BitmapMiner(scheme="eclat", early_stop=True, block_words=2,
                          pair_chunk=16, compact_occupancy=1.0,
                          inflight=3).mine(db, ms)
    assert out == mine_bruteforce(db, ms)
    assert st.compactions > 0
    assert remap_calls["pending"] > 0      # a compaction crossed the ring
    assert remap_calls["resolved"] == 0    # never a retired handle


def test_forced_compaction_pipelined_all_small_chunks():
    """Compaction landing mid-pipeline on every engine: exact results."""
    db = _random_db(4, n_items=10, p=0.35)
    ms = 3
    expected = mine_bruteforce(db, ms)
    out, _ = mine_bitmap(db, ms, scheme="adaptive", diff_density=0.3,
                         block_words=1, pair_chunk=8, inflight=3,
                         compact_occupancy=1.0)
    assert out == expected
    out, _ = mine_prepost_device(db, ms, pair_chunk=4, inflight=3,
                                 compact_occupancy=1.0)
    assert out == expected


def test_pipelined_reserve_covers_pending_groups(monkeypatch):
    """ISSUE 5's reserve invariant, generalised: with groups in flight
    the reserve passed to ``maybe_compact`` must cover the new group's
    pairs PLUS every pending group's, so a fired compaction never
    forces a grow before the group's own chunks finish allocating."""
    events = []
    real_eval = BitmapMiner.evaluate_pairs
    real_comp = BitmapMiner.maybe_compact

    def eval_spy(self, cols):
        r = real_eval(self, cols)
        events.append(("eval", self._store.grows, int(cols["ua"].size)))
        return r

    def comp_spy(self, reserve):
        m = real_comp(self, reserve)
        events.append(("compact", self._store.grows, m is not None,
                       int(reserve)))
        return m

    monkeypatch.setattr(BitmapMiner, "evaluate_pairs", eval_spy)
    monkeypatch.setattr(BitmapMiner, "maybe_compact", comp_spy)

    db = gen_powerlaw_baskets(n_trans=120, n_items=60, avg_trans_len=5,
                              seed=0)
    out, stats = BitmapMiner(
        scheme="eclat", early_stop=True, block_words=2, pair_chunk=64,
        compact_occupancy=1.0, inflight=2).mine(db, 3)
    assert out == mine_bruteforce(db, 3)
    assert stats.compactions > 0

    groups, cur = [], None
    for ev in events:
        if ev[0] == "compact":
            if cur is not None:
                groups.append(cur)
            cur = {"grows": ev[1], "fired": ev[2], "reserve": ev[3],
                   "pairs": 0, "grows_after": ev[1]}
        else:
            cur["pairs"] += ev[2]
            cur["grows_after"] = ev[1]
    groups.append(cur)
    for g in groups:
        assert g["reserve"] >= g["pairs"], g   # >= : pending groups add
        if g["fired"]:
            assert g["grows_after"] == g["grows"], g


# ---------------------------------------------------------------------------
# chunk-width autotuning
# ---------------------------------------------------------------------------

def test_chunk_width_for_properties():
    # reference-size operands keep the base width (snapped to a bucket)
    assert chunk_width_for(1024, 1024, PAIR_CHUNK_BUCKETS, 1024) == 1024
    # operands 16x smaller than reference widen 16x
    assert chunk_width_for(64, 1024, PAIR_CHUNK_BUCKETS, 1024) == 16384
    # bigger-than-reference operands never narrow below base
    assert chunk_width_for(4096, 1024, PAIR_CHUNK_BUCKETS, 1024) == 1024
    # widths are monotone non-increasing in operand size
    widths = [chunk_width_for(w, 256, NL_PAIR_CHUNK_BUCKETS, 384)
              for w in (24, 96, 384, 1536, 6144)]
    assert widths == sorted(widths, reverse=True)
    # and always members of the bucket table (or the base floor)
    for w in widths:
        assert w in NL_PAIR_CHUNK_BUCKETS or w == 256
    # capped at the table maximum
    assert (chunk_width_for(1, 262144, PAIR_CHUNK_BUCKETS, 1024)
            == PAIR_CHUNK_BUCKETS[-1])


def test_autotune_same_results_fewer_dispatches():
    """Autotuning widens small-operand chunks: device_calls drop while
    the per-pair work counters (word_ops / comparisons / scatter_words)
    are unchanged — grouping moves padding, never work."""
    db = _random_db(0)
    ms = 4
    expected = mine_bruteforce(db, ms)

    out_off, st_off = mine_bitmap(db, ms, block_words=1, pair_chunk=8,
                                  autotune_chunk=False)
    out_on, st_on = mine_bitmap(db, ms, block_words=1, pair_chunk=8,
                                autotune_chunk=True)
    assert out_off == expected and out_on == expected
    assert st_on.device_calls < st_off.device_calls
    assert st_on.word_ops == st_off.word_ops
    assert st_on.scatter_words == st_off.scatter_words

    p_off, sp_off = mine_prepost_device(db, ms, pair_chunk=4,
                                        autotune_chunk=False)
    p_on, sp_on = mine_prepost_device(db, ms, pair_chunk=4,
                                      autotune_chunk=True)
    assert p_off == expected and p_on == expected
    assert sp_on.device_calls < sp_off.device_calls
    assert sp_on.comparisons == sp_off.comparisons
    assert sp_on.scatter_words == sp_off.scatter_words


def test_scheduler_chunk_slices_respect_width_caps():
    """The greedy slicer never builds a chunk bigger than the width cap
    of any member (caps are non-increasing post-sort)."""
    sched = FrontierScheduler(object(), pair_chunk=64)
    widths = np.asarray([8] * 10 + [4] * 7 + [2] * 5)
    slices = sched._chunk_slices(widths.size, widths)
    covered = []
    for _lo, sl in slices:
        size = sl.stop - sl.start
        assert size <= int(widths[sl.start:sl.stop].min())
        covered.extend(range(sl.start, sl.stop))
    assert covered == list(range(widths.size))


def test_autotuned_dispatch_widths_stay_bucketed(monkeypatch):
    """With autotuning on, every fused bitmap dispatch still receives a
    width from PAIR_CHUNK_BUCKETS — the compile cache stays bounded."""
    from repro.kernels import ops

    seen = set()
    real = ops.screen_and_intersect

    def spy(rows, suffix, ua, *a, **k):
        seen.add(int(ua.size))
        return real(rows, suffix, ua, *a, **k)

    monkeypatch.setattr(ops, "screen_and_intersect", spy)
    db = _random_db(1)
    out, _ = mine_bitmap(db, 4, block_words=1, pair_chunk=8,
                         autotune_chunk=True)
    assert out == mine_bruteforce(db, 4)
    assert seen and seen <= set(PAIR_CHUNK_BUCKETS)


# ---------------------------------------------------------------------------
# occupancy metric semantics
# ---------------------------------------------------------------------------

def test_occupancy_zero_iff_serial():
    db = _random_db(5)
    ms = 4
    _, st1 = mine_bitmap(db, ms, block_words=1, pair_chunk=8, inflight=1)
    _, st2 = mine_bitmap(db, ms, block_words=1, pair_chunk=8, inflight=2)
    assert st1.device_occupancy == 0.0
    assert 0.0 < st2.device_occupancy <= 1.0
    d = st2.as_dict()
    assert d["inflight_groups"] == 2
    assert d["device_occupancy"] == round(st2.device_occupancy, 4)
    assert "assemble_s" in d and "resolve_s" in d
