"""devicelint fixture tests (ISSUE 10): every rule fires on a known-bad
snippet, annotation suppression works at both grammars (comment and
``host_sync`` escape), the baseline ratchet fails on new AND stale
entries, and the runtime guard half (``core/guards.py``) arms/disarms
the JAX d2h transfer guard exactly where the annotations say.
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from tools.devicelint.engine import (  # noqa: E402
    REPO, diff_baseline, lint_paths, lint_source, load_baseline,
    save_baseline,
)

CORE = "src/repro/core/snippet.py"


def codes(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# DL001 — host-sync
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    "import numpy as np\nx = np.asarray(rows)\n",
    "import numpy as np\nx = np.array(rows)\n",
    "import jax\nx = jax.device_get(rows)\n",
    "y = rows.block_until_ready()\n",
    "n = counts.item()\n",
    "import jax.numpy as jnp\nn = int(jnp.sum(x))\n",
    "import jax.numpy as jnp\nn = float(jnp.max(x))\n",
    "import jax.numpy as jnp\nif jnp.any(x):\n    pass\n",
    "import jax.numpy as jnp\nwhile jnp.all(x):\n    pass\n",
])
def test_dl001_fires_on_known_bad(bad):
    assert "DL001" in codes(lint_source(bad, rel=CORE, only={"DL001"}))


@pytest.mark.parametrize("ok", [
    # annotated on the line above
    "import numpy as np\n# host-sync: pack-time host list\n"
    "x = np.asarray(rows)\n",
    # annotated on the same line
    "import numpy as np\nx = np.asarray(rows)  # host-sync: host list\n",
    # annotation covers a multi-line statement
    "import numpy as np\n# host-sync: host metadata\n"
    "x = np.concatenate([np.asarray(a)\n                    for a in r])\n",
    # PR 7 grammar still counts
    "import numpy as np\n# HOST-SYNC (load-bearing): audited readback\n"
    "x = np.asarray(rows)\n",
    # the runtime escape doubles as the annotation
    "import numpy as np\nwith host_sync('accounting readback'):\n"
    "    x = np.asarray(rows)\n",
    # int()/float() on plain python values is not a sync
    "n = int(len(rows))\n",
    # branching on host values is fine
    "if len(rows) > 2:\n    pass\n",
])
def test_dl001_suppression_and_negatives(ok):
    assert lint_source(ok, rel=CORE, only={"DL001"}) == []


def test_dl001_annotation_requires_a_why():
    bad = "import numpy as np\n# host-sync:\nx = np.asarray(rows)\n"
    assert "DL001" in codes(lint_source(bad, rel=CORE, only={"DL001"}))


def test_dl001_scope_is_core_and_kernels_only():
    bad = "import numpy as np\nx = np.asarray(rows)\n"
    for exempt in ("src/repro/launch/serve.py", "tests/test_x.py",
                   "src/repro/core/oracle.py", "src/repro/core/cli.py"):
        assert lint_source(bad, rel=exempt, only={"DL001"}) == []


def test_removing_an_annotation_fails_devicelint():
    """Regression (ISSUE 10 satellite): strip one real `# host-sync:`
    annotation from core/rowstore.py and the file must stop linting
    clean."""
    path = REPO / "src/repro/core/rowstore.py"
    text = path.read_text(encoding="utf-8")
    rel = "src/repro/core/rowstore.py"
    assert lint_source(text, rel=rel, only={"DL001"}) == []
    lines = [ln for ln in text.splitlines(keepends=True)
             if "host-sync: host extent-table lookup" not in ln]
    assert len(lines) < len(text.splitlines())  # the annotation exists
    broken = lint_source("".join(lines), rel=rel, only={"DL001"})
    assert "DL001" in codes(broken)


# ---------------------------------------------------------------------------
# DL002 — ref-pinning (cross-file fixtures)
# ---------------------------------------------------------------------------

OPS_REL = "src/repro/kernels/ops.py"
REF_REL = "src/repro/kernels/ref.py"


def lint_ops(ops_src, ref_src, test_src=None):
    extra = {REF_REL: ref_src}
    if test_src is not None:
        extra["tests/test_x.py"] = test_src
    return lint_source(ops_src, rel=OPS_REL, only={"DL002"}, extra=extra)


def test_dl002_missing_twin_fires():
    out = lint_ops("def my_op(x):\n    return x\n", "def other_ref(x):\n    return x\n")
    assert codes(out) == ["DL002"] and "my_op" in out[0].message


def test_dl002_missing_test_reference_fires():
    out = lint_ops("def my_op(x):\n    return x\n",
                   "def my_op_ref(x):\n    return x\n",
                   "def test_nothing():\n    pass\n")
    assert codes(out) == ["DL002"] and "unverified" in out[0].message


def test_dl002_clean_when_pinned_and_tested():
    out = lint_ops("def my_op(x):\n    return x\n",
                   "def my_op_ref(x):\n    return x\n",
                   "from ops import my_op\nfrom ref import my_op_ref\n")
    assert out == []


def test_dl002_factory_and_docstring_resolution():
    ops_src = (
        "def make_my_op(mesh):\n    return None\n\n"
        "def oddly_named(x):\n    '''Pinned by ``special_ref``.'''\n"
        "    return x\n")
    ref_src = ("def my_op_ref(x):\n    return x\n\n"
               "def special_ref(x):\n    return x\n")
    test_src = ("make_my_op my_op_ref oddly_named special_ref\n")
    assert lint_ops(ops_src, ref_src, test_src) == []


def test_dl002_private_defs_ignored():
    assert lint_ops("def _impl(x):\n    return x\n", "") == []


# ---------------------------------------------------------------------------
# DL003 — retrace hazards
# ---------------------------------------------------------------------------

def test_dl003_jit_in_loop_fires():
    bad = ("import jax\nfor i in range(3):\n"
           "    f = jax.jit(lambda x: x)\n")
    assert "DL003" in codes(lint_source(bad, rel="src/repro/m.py",
                                        only={"DL003"}))


def test_dl003_jit_in_uncached_function_fires():
    bad = ("import jax\ndef build():\n"
           "    return jax.jit(lambda x: x)\n")
    assert "DL003" in codes(lint_source(bad, rel="src/repro/m.py",
                                        only={"DL003"}))


def test_dl003_lru_cached_factory_is_clean():
    ok = ("import functools, jax\n"
          "@functools.lru_cache(maxsize=None)\n"
          "def build():\n    return jax.jit(lambda x: x)\n")
    assert lint_source(ok, rel="src/repro/m.py", only={"DL003"}) == []


def test_dl003_module_level_jit_is_clean():
    ok = "import jax\nf = jax.jit(lambda x: x)\n"
    assert lint_source(ok, rel="src/repro/m.py", only={"DL003"}) == []


def test_dl003_static_argnames_typo_fires():
    bad = ("import functools, jax\n"
           "@functools.partial(jax.jit, static_argnames=('mode',))\n"
           "def f(x, *, mod='and'):\n    return x\n")
    out = lint_source(bad, rel="src/repro/m.py", only={"DL003"})
    assert "DL003" in codes(out) and "mode" in out[0].message


def test_dl003_unhashable_static_default_fires():
    bad = ("import functools, jax\n"
           "@functools.partial(jax.jit, static_argnames=('cfg',))\n"
           "def f(x, *, cfg=[1, 2]):\n    return x\n")
    assert "DL003" in codes(lint_source(bad, rel="src/repro/m.py",
                                        only={"DL003"}))


def test_dl003_per_call_varying_static_fires():
    bad = ("import functools, jax\n"
           "@functools.partial(jax.jit, static_argnames=('minsup',))\n"
           "def f(x, *, minsup=0):\n    return x\n\n"
           "def g(x, threshold):\n"
           "    return f(x, minsup=int(threshold))\n")
    out = lint_source(bad, rel="src/repro/m.py", only={"DL003"})
    assert "DL003" in codes(out)
    assert any("per-call-varying" in f.message for f in out)


def test_dl003_bounded_static_call_is_clean():
    ok = ("import functools, jax\n"
          "@functools.partial(jax.jit, static_argnames=('mode',))\n"
          "def f(x, *, mode='and'):\n    return x\n\n"
          "def g(x):\n    return f(x, mode='andnot')\n")
    assert lint_source(ok, rel="src/repro/m.py", only={"DL003"}) == []


# ---------------------------------------------------------------------------
# DL004 — mesh-axis discipline
# ---------------------------------------------------------------------------

def test_dl004_psum_over_cls_literal_fires():
    bad = ("import jax\nfrom jax.sharding import PartitionSpec as P\n"
           "spec = P('block', 'cls')\n"
           "def body(x):\n    return jax.lax.psum(x, 'cls')\n")
    out = lint_source(bad, rel="src/repro/m.py", only={"DL004"})
    assert codes(out) == ["DL004"] and "cls" in out[0].message


def test_dl004_psum_over_cls_axes_name_fires():
    bad = ("import jax\ncls_axes = ('cls',)\n"
           "def body(x):\n    return jax.lax.psum(x, cls_axes)\n")
    assert "DL004" in codes(lint_source(bad, rel="src/repro/m.py",
                                        only={"DL004"}))


def test_dl004_all_gather_along_cls_is_sanctioned():
    ok = ("import jax\nfrom jax.sharding import PartitionSpec as P\n"
          "spec = P('cls')\n"
          "def body(x):\n"
          "    return jax.lax.all_gather(x, 'cls', axis=0, tiled=True)\n")
    assert lint_source(ok, rel="src/repro/m.py", only={"DL004"}) == []


def test_dl004_undeclared_literal_axis_fires():
    bad = ("import jax\nfrom jax.sharding import PartitionSpec as P\n"
           "spec = P('block')\n"
           "def body(x):\n    return jax.lax.psum(x, 'pod')\n")
    out = lint_source(bad, rel="src/repro/m.py", only={"DL004"})
    assert codes(out) == ["DL004"] and "undeclared" in out[0].message


def test_dl004_declared_literal_axis_is_clean():
    ok = ("import jax\nfrom jax.sharding import PartitionSpec as P\n"
          "spec = P('block')\n"
          "def body(x):\n    return jax.lax.psum(x, 'block')\n")
    assert lint_source(ok, rel="src/repro/m.py", only={"DL004"}) == []


def test_dl004_variable_axes_are_not_guessed():
    ok = ("import jax\n"
          "def body(x, tid_axes):\n"
          "    return jax.lax.psum(x, tid_axes)\n")
    assert lint_source(ok, rel="src/repro/m.py", only={"DL004"}) == []


# ---------------------------------------------------------------------------
# baseline ratchet + the real repo
# ---------------------------------------------------------------------------

def test_baseline_ratchet_new_and_stale(tmp_path):
    src = "import numpy as np\nx = np.asarray(rows)\n"
    findings = lint_source(src, rel=CORE, only={"DL001"})
    assert len(findings) == 1
    bl = tmp_path / "baseline.json"
    save_baseline(findings, bl)
    baseline = load_baseline(bl)
    # same findings -> clean
    new, stale = diff_baseline(findings, baseline)
    assert new == [] and stale == []
    # a second, different finding -> NEW
    two = lint_source(src + "y = np.asarray(cols)\n", rel=CORE,
                      only={"DL001"})
    new, stale = diff_baseline(two, baseline)
    assert len(new) == 1 and stale == []
    # finding fixed -> STALE entry must fail until the baseline shrinks
    new, stale = diff_baseline([], baseline)
    assert new == [] and len(stale) == 1


def test_baseline_fingerprint_survives_line_drift():
    src = "import numpy as np\nx = np.asarray(rows)\n"
    drifted = "import numpy as np\n\n\n# moved\nx = np.asarray(rows)\n"
    a = lint_source(src, rel=CORE, only={"DL001"})
    b = lint_source(drifted, rel=CORE, only={"DL001"})
    assert a[0].line != b[0].line
    assert a[0].fingerprint == b[0].fingerprint


def test_repo_lints_clean_against_committed_baseline():
    """The CI contract: `python -m tools.devicelint src tests benchmarks`
    passes, and core/ + kernels/ carry ZERO baseline entries (ISSUE 10
    acceptance)."""
    findings = lint_paths(["src", "tests", "benchmarks"])
    baseline = load_baseline()
    new, stale = diff_baseline(findings, baseline)
    assert new == [], [str(f) for f in new]
    assert stale == [], stale
    assert [e for e in baseline
            if e["path"].startswith(("src/repro/core/",
                                     "src/repro/kernels/"))] == []


# ---------------------------------------------------------------------------
# runtime guard (core/guards.py) — the other half of DL001
# ---------------------------------------------------------------------------

def test_guard_arms_jax_transfer_guard():
    import jax
    from repro.core.guards import device_purity_guard, host_sync, \
        purity_guard_active

    assert not purity_guard_active()
    with device_purity_guard():
        assert purity_guard_active()
        assert (jax.config.jax_transfer_guard_device_to_host
                == "disallow")
        with host_sync("test escape"):
            # escape: syncs allowed, and the activity flag reflects it
            assert not purity_guard_active()
            assert (jax.config.jax_transfer_guard_device_to_host
                    == "allow")
        assert purity_guard_active()
    assert not purity_guard_active()


def test_host_sync_requires_justification():
    from repro.core.guards import host_sync
    with pytest.raises(AssertionError):
        with host_sync(""):
            pass


def test_guarded_mine_matches_unguarded():
    """FrontierScheduler.run() is guard-wrapped internally; a full mine
    under an OUTER guard as well must still resolve its accounting
    through the annotated escapes only."""
    import random
    from repro.core.eclat import mine_bitmap
    from repro.core.guards import device_purity_guard
    from repro.core.oracle import mine_bruteforce

    rng = random.Random(3)
    db = [sorted(set(rng.choices(range(7), k=rng.randint(1, 4))))
          for _ in range(25)]
    expected = mine_bruteforce(db, 3)
    with device_purity_guard():
        out, _ = mine_bitmap(db, 3, scheme="eclat", early_stop=True,
                             block_words=4)
    assert out == expected
