import os
import sys

# Tests must see the real device set (1 CPU device) — the 512-device flag
# belongs to the dry-run process only (launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
