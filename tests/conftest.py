import os
import sys
import types

import pytest

# Tests must see the real device set (1 CPU device) — the 512-device flag
# belongs to the dry-run process only (launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# hypothesis shim: property tests skip cleanly when the package is absent.
#
# `hypothesis` is an optional test dependency (declared in pyproject's
# [test] extra).  When it is not installed we register a minimal stub so
# test modules still *import* (example-based tests in the same files keep
# running) while every @given test reports SKIPPED instead of erroring at
# collection.
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
except ImportError:
    class _AnyStrategy:
        """Stands in for strategy objects and strategy factories."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    _any = _AnyStrategy()

    def _given(*_args, **_kwargs):
        def deco(fn):
            # No functools.wraps: pytest must see a zero-arg signature,
            # not the strategy-driven parameters of the wrapped test.
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _any
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
