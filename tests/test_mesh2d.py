"""2-D (block x cls) mesh sharding (ISSUE 9).

In-process tests cover the pieces that don't need multiple devices: the
sharded ref's cls-slice invariance, the autotune budget division, the
frontier's chunk-quantum alignment, the mining-mesh builder and the
CPU dry-run bootstrap.  The real multi-device equivalence sweep —
frequent sets, supports and every gated EngineAccounting counter
identical across 1x1 / 8x1 / 1x8 / 4x2 meshes for all schemes, ES
on/off, serial and pipelined — runs in a subprocess with 8 forced host
devices (``repro.launch.forcedevices``), like tests/test_distributed.py.
"""

import subprocess
import sys
import textwrap
from types import SimpleNamespace

import numpy as np
import pytest

import jax
import jax.numpy as jnp


# -- sharded ref: cls slicing is a pure reshuffle ---------------------------

@pytest.mark.parametrize("mode", ["and", "andnot"])
@pytest.mark.parametrize("early_stop", [False, True])
def test_sharded_ref_cls_slicing_invariant(early_stop, mode):
    """``n_cls > 1`` evaluates disjoint contiguous pair slices and
    concatenates — bit-identical outputs to ``n_cls=1`` by construction
    (this is the contract that makes 2-D meshes bit-identical to
    serial)."""
    from repro.core.bitmap import popcount32_np, suffix_popcounts_np
    from repro.kernels import ref

    r = np.random.default_rng(5)
    cap, nb, bw = 24, 4, 4
    rows = np.zeros((cap, nb, bw), np.uint32)
    rows[:16] = r.integers(0, 2 ** 32, (16, nb, bw), dtype=np.uint64
                           ).astype(np.uint32)
    suffix = suffix_popcounts_np(rows)
    n = 12
    ua = r.integers(0, 16, n).astype(np.int32)
    vb = r.integers(0, 16, n).astype(np.int32)
    slots = np.arange(16, 16 + n, dtype=np.int32)
    if mode == "and":
        rho = r.integers(0, 100, n).astype(np.int32)
    else:
        rho = popcount32_np(rows).reshape(cap, -1).sum(1).astype(
            np.int32)[ua]
    for minsup in (0, 8, 40):
        base = ref.screen_and_intersect_sharded_ref(
            rows, suffix, ua, vb, slots, rho, jnp.int32(minsup),
            n_shards=1, n_cls=1, mode=mode, early_stop=early_stop)
        for n_cls in (2, 4, 6):
            got = ref.screen_and_intersect_sharded_ref(
                rows, suffix, ua, vb, slots, rho, jnp.int32(minsup),
                n_shards=1, n_cls=n_cls, mode=mode,
                early_stop=early_stop)
            for b, g in zip(base, got):
                assert np.array_equal(np.asarray(b), np.asarray(g)), (
                    mode, early_stop, minsup, n_cls)


def test_sharded_ref_cls_must_divide_pairs():
    from repro.kernels import ref

    rows = np.zeros((4, 1, 2), np.uint32)
    suffix = np.zeros((4, 2), np.int32)
    v = np.zeros(3, np.int32)
    with pytest.raises(ValueError, match="n_cls"):
        ref.screen_and_intersect_sharded_ref(
            rows, suffix, v, v, v, v, jnp.int32(1), n_shards=1, n_cls=2)


# -- autotune budget: per-device words divide by the cls count --------------

def test_autotune_words_per_pair_divides_by_cls():
    """Satellite 6: ``chunk_width_for``'s VMEM budget is per DEVICE; a
    cls-shard gathers 1/n_cls of the chunk, so the distributed miner's
    words-per-pair divides (ceil) by n_cls and the autotuned chunk can
    widen at equal footprint."""
    from repro.core.bitmap import (BITMAP_REF_ROW_WORDS,
                                   PAIR_CHUNK_BUCKETS, chunk_width_for)
    from repro.core.distributed import DistributedMiner
    from repro.core.eclat import BitmapMiner

    bdb = SimpleNamespace(n_blocks=5, block_words=128)
    base = BitmapMiner._autotune_words_per_pair(
        SimpleNamespace(block_words=128), bdb)
    assert base == 5 * 128
    for n_cls in (1, 2, 8):
        fake = SimpleNamespace(block_words=128, n_cls=n_cls)
        wpp = DistributedMiner._autotune_words_per_pair(fake, bdb)
        assert wpp == -(-base // n_cls)
        w = chunk_width_for(wpp, 64, PAIR_CHUNK_BUCKETS,
                            BITMAP_REF_ROW_WORDS)
        assert w >= chunk_width_for(base, 64, PAIR_CHUNK_BUCKETS,
                                    BITMAP_REF_ROW_WORDS)
    # strictly wider once the division crosses a bucket boundary
    w1 = chunk_width_for(base, 64, PAIR_CHUNK_BUCKETS,
                         BITMAP_REF_ROW_WORDS)
    w8 = chunk_width_for(-(-base // 8), 64, PAIR_CHUNK_BUCKETS,
                         BITMAP_REF_ROW_WORDS)
    assert w8 > w1


# -- frontier: chunk boundaries align to the cls count ----------------------

def _slices(client, total, widths=None, pair_chunk=100):
    from repro.core.frontier import FrontierScheduler

    return FrontierScheduler(client, pair_chunk)._chunk_slices(
        total, widths)


def test_chunk_slices_quantum_alignment():
    """Satellite 6 regression: non-final chunk boundaries land on
    multiples of the client's ``chunk_quantum`` so every cls-shard's
    slice covers real pairs; the final chunk keeps the remainder (the
    dispatch pads it)."""
    q8 = SimpleNamespace(chunk_quantum=8)
    for lo, sl in _slices(q8, 1000, pair_chunk=100)[:-1]:
        assert (sl.stop - sl.start) % 8 == 0, (lo, sl)
    # widths-driven slicing: caps are respected AND boundaries aligned
    widths = np.full(1000, 70, np.int64)
    cuts = _slices(q8, 1000, widths=widths)
    assert cuts[-1][1].stop == 1000
    for i, (lo, sl) in enumerate(cuts):
        n = sl.stop - sl.start
        assert n <= 70
        if i < len(cuts) - 1:
            assert n % 8 == 0, (i, sl)
    # quantum 1 (every single-device client) is exactly the old slicing
    q1 = SimpleNamespace(chunk_quantum=1)
    assert _slices(q1, 1000, widths=widths) != []
    assert [s for s in _slices(q1, 250, pair_chunk=100)] == [
        (0, slice(0, 100)), (100, slice(100, 200)), (200, slice(200, 250))]
    # a width cap below the quantum still makes progress (degenerate
    # chunk, padded at dispatch rather than rounded to zero)
    tiny = np.full(40, 3, np.int64)
    cuts = _slices(q8, 40, widths=tiny)
    assert sum(s.stop - s.start for _lo, s in cuts) == 40
    assert all(s.stop - s.start >= 1 for _lo, s in cuts)


def test_chunk_quantum_defaults():
    from repro.core.eclat import BitmapMiner

    assert BitmapMiner.chunk_quantum == 1


# -- launch layer -----------------------------------------------------------

def test_make_mining_mesh_single_device():
    from repro.launch.mesh import make_mining_mesh

    mesh = make_mining_mesh()
    assert tuple(mesh.axis_names) == ("block", "cls")
    assert mesh.shape["cls"] == 1
    assert mesh.shape["block"] == jax.device_count()
    with pytest.raises(ValueError, match="cls"):
        make_mining_mesh(cls=jax.device_count() + 1)


def test_mining_mesh_auto_cls_detection():
    """DistributedMiner picks up the ``cls`` axis by name and keeps the
    TID axes disjoint from it (trivial sizes on one device, but the
    wiring is what's under test — the 8-device version runs in the
    subprocess sweep)."""
    from repro.core.distributed import DistributedMiner
    from repro.core.eclat import BitmapMiner
    from repro.launch.mesh import make_mining_mesh

    mesh = make_mining_mesh()
    m = DistributedMiner(mesh, block_words=2)
    assert m.cls_axes == ("cls",)
    assert m.tid_axes == ("block",)
    assert m.n_cls == 1 and m.chunk_quantum == 1
    db = [[0, 1, 2], [0, 1], [1, 2], [0, 2], [0, 1, 2]]
    out, _ = m.mine(db, 2)
    ref_out, _ = BitmapMiner(block_words=2).mine(db, 2)
    assert out == ref_out
    with pytest.raises(ValueError, match="overlap"):
        DistributedMiner(mesh, tid_axes=("block", "cls"),
                         cls_axes=("cls",))


def test_force_host_device_count_sets_flag(monkeypatch):
    from repro.launch import forcedevices

    monkeypatch.delitem(sys.modules, "jax", raising=False)
    monkeypatch.setenv(
        "XLA_FLAGS",
        "--foo=1 --xla_force_host_platform_device_count=2")
    forcedevices.force_host_device_count(8)
    import os

    assert os.environ["XLA_FLAGS"].split() == [
        "--foo=1", "--xla_force_host_platform_device_count=8"]
    with pytest.raises(ValueError):
        forcedevices.force_host_device_count(0)


def test_force_host_device_count_after_backend_init_raises():
    from repro.launch.forcedevices import force_host_device_count

    jax.devices()           # make sure the backend is up
    with pytest.raises(RuntimeError, match="backend init"):
        force_host_device_count(8)


# -- the multi-device equivalence sweep -------------------------------------

MESH2D_SCRIPT = textwrap.dedent("""
    import sys
    sys.path.insert(0, "src")
    from repro.launch.forcedevices import force_host_device_count
    force_host_device_count(8)

    import random
    import numpy as np
    import jax
    assert jax.device_count() == 8

    from repro.core.eclat import BitmapMiner
    from repro.core.distributed import DistributedMiner
    from repro.core.oracle import mine_bruteforce
    from repro.launch.mesh import make_mining_mesh

    SHAPES = [(1, 1), (8, 1), (1, 8), (4, 2)]
    meshes = {s: make_mining_mesh(block=s[0], cls=s[1]) for s in SHAPES}
    for s in SHAPES:
        assert dict(meshes[s].shape) == {"block": s[0], "cls": s[1]}, s

    def counters(st):
        d = st.as_dict()
        for k in ("runtime_s", "assemble_s", "resolve_s"):
            d.pop(k)
        return d

    # --- sweep 1: full counter identity, serial engine included.
    # Single-real-block DBs (block_words=2 => 64 TIDs/block, <= 60
    # transactions): the shard-local ES thresholds then see zero slack
    # on every mesh, so EVERY gated counter — word_ops, screened_out /
    # kernel_aborts (the bitmap engine's es_checks analogues),
    # scatter_words, candidates, nodes, device_calls, peak_rows,
    # compactions — is identical across all four mesh shapes AND the
    # single-device BitmapMiner.
    rng = random.Random(11)
    for trial in range(2):
        ni = rng.randint(5, 8)
        nt = rng.randint(20, 60)
        db = [[i for i in range(ni) if rng.random() < 0.5]
              for _ in range(nt)]
        db = [t for t in db if t]
        ms = rng.randint(2, max(2, len(db) // 3))
        bf = mine_bruteforce(db, ms)
        for scheme, dd in (("eclat", None), ("declat", None),
                           ("adaptive", 0.3)):
            for es in (False, True):
                for inflight in (1, 2):
                    _, st0 = BitmapMiner(
                        scheme=scheme, early_stop=es, block_words=2,
                        inflight=inflight, diff_density=dd).mine(db, ms)
                    want = counters(st0)
                    for shape in SHAPES:
                        m = DistributedMiner(
                            meshes[shape], scheme=scheme, early_stop=es,
                            capacity=256, block_words=2,
                            inflight=inflight, diff_density=dd)
                        out, st = m.mine(db, ms)
                        key = (trial, scheme, es, inflight, shape)
                        assert out == bf, key
                        assert counters(st) == want, (
                            key, counters(st), want)
    print("SWEEP1_OK")

    # --- sweep 2: multi-block DB.  Block-sharding legitimately changes
    # ES-on word_ops (shard-local thresholds), but the cls axis NEVER
    # does: 1x1 vs 1x8 (same block sharding, cls 1 vs 8) must agree on
    # every counter, for every scheme, ES on/off, serial and pipelined.
    rng2 = np.random.default_rng(2)
    db2 = [list(np.flatnonzero(rng2.random(30) < 0.35))
           for _ in range(300)]
    ms2 = 18
    ref2, _ = BitmapMiner(scheme="eclat", block_words=2).mine(db2, ms2)
    for scheme, dd in (("eclat", None), ("declat", None),
                       ("adaptive", 0.3)):
        for es in (False, True):
            for inflight in (1, 2):
                kw = dict(scheme=scheme, early_stop=es, capacity=512,
                          block_words=2, inflight=inflight,
                          diff_density=dd)
                out_a, st_a = DistributedMiner(
                    meshes[(1, 1)], **kw).mine(db2, ms2)
                out_b, st_b = DistributedMiner(
                    meshes[(1, 8)], **kw).mine(db2, ms2)
                key = (scheme, es, inflight)
                assert out_a == out_b, key
                if scheme == "eclat":
                    assert out_a == ref2, key
                assert counters(st_a) == counters(st_b), (
                    key, counters(st_a), counters(st_b))
    print("SWEEP2_OK")

    # --- satellite 6a: the autotune budget divides by n_cls, so the
    # cls-sharded run tunes a strictly wider chunk at equal per-device
    # footprint, at identical per-pair work and never more dispatches.
    kw = dict(scheme="eclat", early_stop=True, block_words=2,
              pair_chunk=64, autotune_chunk=True)
    m11 = DistributedMiner(meshes[(1, 1)], **kw)
    m18 = DistributedMiner(meshes[(1, 8)], **kw)
    out11, s11 = m11.mine(db2, ms2)
    out18, s18 = m18.mine(db2, ms2)
    assert out11 == out18 == ref2
    assert m18._chunk_width > m11._chunk_width, (
        m11._chunk_width, m18._chunk_width)
    assert s18.word_ops == s11.word_ops
    assert s18.scatter_words == s11.scatter_words
    assert s18.device_calls <= s11.device_calls
    print("SWEEP3_OK")

    # --- satellite 6b: compaction reserve under 2-D inflight.  Force
    # aggressive compaction on the 4x2 mesh with a pipelined ring: if
    # the reserve missed any cls-shard's pending handles the remapped
    # scatter slots would go out of bounds and children would be
    # silently dropped — result equality is the regression gate.
    m = DistributedMiner(meshes[(4, 2)], scheme="eclat",
                         early_stop=True, capacity=64, block_words=2,
                         inflight=2, compact_occupancy=0.9)
    out_c, st_c = m.mine(db2, ms2)
    assert out_c == ref2
    assert st_c.compactions > 0, "compaction never fired; gate is vacuous"
    print("SWEEP4_OK")

    print("MESH2D_OK")
""")


@pytest.mark.slow
def test_mesh2d_equivalence_sweep():
    proc = subprocess.run([sys.executable, "-c", MESH2D_SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          cwd=".")
    assert "MESH2D_OK" in proc.stdout, (proc.stdout[-2000:],
                                        proc.stderr[-3000:])
