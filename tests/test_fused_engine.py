"""Device-resident frontier engine: end-to-end and dispatch-count tests.

The fused-path contract (ISSUE 1):
  * `BitmapMiner.mine` issues exactly ONE device dispatch per pair chunk
    (`ops.screen_and_intersect`) — no separate screen call, no full
    intersect call, no host U/V row materialisation between levels;
  * output `(itemset, support)` equals `oracle.mine` for eclat and
    declat, ES on and off;
  * the row store recycles slots (peak live rows stays bounded).
"""

import random

import numpy as np
import pytest

from repro.core.eclat import BitmapMiner, mine_bitmap
from repro.core.oracle import mine
from repro.core.rowstore import DeviceRowStore
from repro.kernels import ops


def _random_db(seed, n_items=(3, 9), n_trans=(4, 30)):
    rng = random.Random(seed)
    ni = rng.randint(*n_items)
    nt = rng.randint(*n_trans)
    dens = rng.choice([0.2, 0.4, 0.6])
    db = [[i for i in range(ni) if rng.random() < dens] for _ in range(nt)]
    db = [t for t in db if t] or [[0]]
    minsup = rng.randint(1, max(1, len(db) // 2))
    return db, minsup


@pytest.mark.parametrize("scheme", ["eclat", "declat"])
@pytest.mark.parametrize("es", [False, True])
def test_device_resident_engine_matches_oracle(scheme, es):
    for seed in range(12):
        db, minsup = _random_db(seed)
        expected, _ = mine(db, minsup, scheme, early_stop=es)
        out, _ = mine_bitmap(db, minsup, scheme=scheme, early_stop=es,
                             block_words=4)
        assert out == expected, (scheme, es, seed, minsup)


@pytest.mark.parametrize("scheme", ["eclat", "declat"])
@pytest.mark.parametrize("es", [False, True])
def test_multiblock_engine_matches_oracle(scheme, es):
    """Cross-block ES (freeze/alive past block 0) against the oracle:
    block_words=1 gives 32 TIDs per block, so 150 transactions span 5
    blocks and the blocked scan actually crosses block boundaries."""
    for seed in range(4):
        db, minsup = _random_db(100 + seed, n_items=(6, 9),
                                n_trans=(140, 160))
        minsup = max(minsup, 3)
        expected, _ = mine(db, minsup, scheme, early_stop=es)
        out, stats = mine_bitmap(db, minsup, scheme=scheme, early_stop=es,
                                 block_words=1)
        assert out == expected, (scheme, es, seed, minsup)
        if es and seed == 0:
            assert stats.word_ops <= stats.word_ops_full


@pytest.mark.parametrize("scheme", ["eclat", "declat"])
def test_one_device_dispatch_per_pair_chunk(monkeypatch, scheme):
    """Every chunk is one fused dispatch; the legacy two-dispatch ops are
    never called by the miner."""
    calls = {"fused": 0, "legacy": 0}
    real = ops.screen_and_intersect
    real_diff = ops.screen_and_diff

    def counting_fused(*a, **k):
        calls["fused"] += 1
        return real(*a, **k)

    def counting_diff(*a, **k):
        calls["fused"] += 1
        return real_diff(*a, **k)

    def forbidden(*a, **k):
        calls["legacy"] += 1
        raise AssertionError("legacy two-dispatch path used")

    monkeypatch.setattr(ops, "screen_and_intersect", counting_fused)
    monkeypatch.setattr(ops, "screen_and_diff", counting_diff)
    monkeypatch.setattr(ops, "screen_pairs", forbidden)
    monkeypatch.setattr(ops, "bitmap_intersect_es", forbidden)
    monkeypatch.setattr(ops, "bitmap_intersect_full", forbidden)

    db, minsup = _random_db(3, n_items=(8, 8), n_trans=(25, 30))
    out, stats = mine_bitmap(db, minsup, scheme=scheme, early_stop=True,
                             block_words=1, pair_chunk=4)
    assert calls["legacy"] == 0
    assert calls["fused"] == stats.device_calls
    # small pair_chunk forces several chunks; each was one dispatch
    assert stats.device_calls >= 2
    expected, _ = mine(db, minsup, scheme, early_stop=True)
    assert out == expected


def _dead_candidates(out, stats):
    """With eclat + ES, a pair is ES-dead iff it is infrequent, so the
    dead count is candidates - frequent children."""
    singles = sum(1 for s in out if len(s) == 1)
    return stats.candidates - (stats.nodes - singles)


def test_es_death_attribution_single_block():
    """nb == 1: every ES death IS a screen death (the pre-ISSUE-2 code
    skipped attribution entirely when n_blocks == 1, leaving
    screened_out == 0)."""
    for seed in range(8):
        db, minsup = _random_db(seed, n_items=(6, 9), n_trans=(15, 30))
        # default block_words=128 -> one block for these tiny DBs
        out, stats = mine_bitmap(db, minsup, "eclat", early_stop=True)
        dead = _dead_candidates(out, stats)
        assert stats.screened_out == dead, seed
        assert stats.kernel_aborts == 0, seed
        if dead:
            return
    raise AssertionError("no seed produced a dead candidate")


def test_es_death_attribution_accounts_every_dead_pair():
    """Multi-block: screen deaths + kernel aborts partition the dead set —
    including pairs that die on the FINAL block (blocks == nb), which the
    pre-ISSUE-2 code dropped from both buckets."""
    for seed in range(4):
        db, minsup = _random_db(200 + seed, n_items=(6, 9),
                                n_trans=(140, 160))
        minsup = max(minsup, 3)
        out, stats = mine_bitmap(db, minsup, "eclat", early_stop=True,
                                 block_words=1)
        dead = _dead_candidates(out, stats)
        assert stats.screened_out + stats.kernel_aborts == dead, seed


def test_row_store_alloc_free_grow():
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 2**32, (3, 2, 4), dtype=np.uint64).astype(
        np.uint32)
    store = DeviceRowStore(rows, capacity=4)
    cap0 = store.capacity
    assert store.n_live == 3
    assert np.array_equal(np.asarray(store.rows[:3]), rows)
    # suffix slab matches the host mirror
    from repro.core.bitmap import suffix_popcounts_np
    assert np.array_equal(np.asarray(store.suffix[:3]),
                          suffix_popcounts_np(rows))
    slots = store.alloc(2)
    assert len(set(slots.tolist())) == 2
    assert all(s >= 3 for s in slots)
    store.free(slots)
    assert store.n_live == 3
    # exhaust -> grow (device slab reallocation, contents preserved)
    big = store.alloc(cap0)
    assert store.capacity > cap0
    assert store.grows == 1
    assert np.array_equal(np.asarray(store.rows[:3]), rows)
    store.free(big)


def test_store_slots_recycled_end_to_end():
    """Expanded classes return their slots: peak live rows stays far below
    total node count on a DFS with many levels.  Serial (``inflight=1``)
    keeps the tight one-chunk bound; the pipelined default may hold one
    extra group's candidate slots plus its unreleased operands in
    flight, so its bound widens by one drain group per ring slot."""
    db, minsup = _random_db(5, n_items=(9, 9), n_trans=(28, 30))
    expected, _ = mine(db, minsup, "eclat", early_stop=True)
    miner = BitmapMiner(scheme="eclat", early_stop=True, block_words=1,
                        pair_chunk=8, inflight=1)
    out, stats = miner.mine(db, minsup)
    assert stats.peak_rows <= stats.nodes + 8  # + one in-flight chunk
    assert out == expected

    miner = BitmapMiner(scheme="eclat", early_stop=True, block_words=1,
                        pair_chunk=8)                  # pipelined default
    out, stats = miner.mine(db, minsup)
    assert out == expected
    bound = stats.nodes + 8 * (2 * miner.inflight + 1)
    assert stats.peak_rows <= bound, (stats.peak_rows, bound)
