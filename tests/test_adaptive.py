"""Density-adaptive tidset/diffset representation switching (ISSUE 6).

The hysteresis unit tests pin the satellite's two required properties:
a class straddling the density threshold does not flip back and forth
across consecutive drain groups (the flip only fires above
``diff_density + diff_hysteresis`` and is one-way), and the
representation tag survives allocator compaction remaps (the mapping
renumbers row handles only — the tag rides the ``ClassNode``).
"""

import random

import numpy as np
import pytest

from repro.core.eclat import BitmapMiner, mine_bitmap, DEFAULT_DIFF_DENSITY
from repro.core.frontier import ClassNode, FrontierScheduler
from repro.core.oracle import mine_bruteforce


def _dense_db(seed=0, n_items=6, n_trans=40, dens=0.85):
    rng = random.Random(seed)
    db = [[i for i in range(n_items) if rng.random() < dens]
          for _ in range(n_trans)]
    return [t for t in db if t]


def _sparse_db(seed=0, n_items=9, n_trans=40, dens=0.15):
    rng = random.Random(seed)
    db = [[i for i in range(n_items) if rng.random() < dens]
          for _ in range(n_trans)]
    return [t for t in db if t] or [[0]]


# ---------------------------------------------------------------------------
# constructor / knob validation
# ---------------------------------------------------------------------------

def test_scheme_validation():
    with pytest.raises(ValueError):
        BitmapMiner(scheme="fpgrowth")
    # diff_density is an adaptive-only knob
    with pytest.raises(ValueError):
        BitmapMiner(scheme="eclat", diff_density=0.5)
    with pytest.raises(ValueError):
        BitmapMiner(scheme="declat", diff_density=0.5)
    assert BitmapMiner(scheme="adaptive").diff_density == \
        DEFAULT_DIFF_DENSITY
    assert BitmapMiner(scheme="adaptive", diff_density=0.3
                       ).diff_density == 0.3


# ---------------------------------------------------------------------------
# hysteresis band semantics of the per-class flip rule
# ---------------------------------------------------------------------------

def test_child_representation_hysteresis_band():
    """The flip fires only ABOVE diff_density + diff_hysteresis; a class
    sitting anywhere inside the band (including exactly at the bare
    threshold) keeps its tidsets."""
    m = BitmapMiner(scheme="adaptive", diff_density=0.5,
                    diff_hysteresis=0.1)
    m._n_trans = 100
    sup = lambda *v: np.asarray(v, np.int32)
    assert m._child_representation("tidset", sup(20, 30)) == "tidset"
    # density 0.50: at the bare threshold, inside the band -> no flip
    assert m._child_representation("tidset", sup(50, 50)) == "tidset"
    # density 0.55: still inside the band
    assert m._child_representation("tidset", sup(55, 55)) == "tidset"
    # density 0.60 == threshold + hysteresis: flips
    assert m._child_representation("tidset", sup(60, 60)) == "diffset"
    assert m._child_representation("tidset", sup(90, 95)) == "diffset"
    # empty classes never flip
    assert m._child_representation("tidset", sup()) == "tidset"


def test_child_representation_flip_is_one_way():
    """A diffset subtree never reverts to tidsets, whatever the density
    of the subclass (its parent tidset rows are long gone)."""
    m = BitmapMiner(scheme="adaptive", diff_density=0.5,
                    diff_hysteresis=0.1)
    m._n_trans = 100
    for sups in ([1, 2], [50, 55], [99, 99], []):
        assert m._child_representation(
            "diffset", np.asarray(sups, np.int32)) == "diffset"


def test_child_representation_pure_schemes():
    e = BitmapMiner(scheme="eclat")
    e._n_trans = 10
    d = BitmapMiner(scheme="declat")
    d._n_trans = 10
    sup = np.asarray([10, 10], np.int32)       # density 1.0
    assert e._child_representation("tidset", sup) == "tidset"
    assert d._child_representation("tidset", sup) == "diffset"


def test_no_flip_flop_across_drain_groups():
    """End-to-end: record every (member rep -> child rep) transition the
    miner commits across the whole DFS.  One-way means diffset->tidset
    never appears; with the threshold parked right at the root density
    (straddling classes everywhere) the result is still exact and no
    class oscillates."""
    db = _dense_db(seed=3)
    n_trans = len(db)
    root_density = np.mean([len(t) for t in db]) / 6  # ~mean item density
    for dd in (0.3, float(root_density), 0.95):
        m = BitmapMiner(scheme="adaptive", diff_density=dd,
                        diff_hysteresis=0.05, block_words=2, pair_chunk=8)
        transitions = []
        real = BitmapMiner.make_class

        def spy(self, parent, children, _t=transitions, _r=real):
            node = _r(self, parent, children)
            _t.append((node.representation, node.payload))
            return node

        m.make_class = spy.__get__(m)
        out, _ = m.mine(db, 2)
        assert out == mine_bruteforce(db, 2), dd
        assert ("diffset", "tidset") not in transitions, dd
        # a class whose members are tidsets may flip its children or
        # not, but the SAME policy inputs give the same answer — the
        # recorded payload is a function of (rep, density), so a flip
        # threshold above every density yields no flips at all
        if dd == 0.95:
            assert all(p == "tidset" for _, p in transitions), transitions
    # sanity: the low threshold actually produced diffset classes
    m = BitmapMiner(scheme="adaptive", diff_density=0.3,
                    diff_hysteresis=0.05, block_words=2)
    reps = []
    real = BitmapMiner.make_class

    def spy(self, parent, children, _r=real):
        node = _r(self, parent, children)
        reps.append(node.representation)
        return node

    m.make_class = spy.__get__(m)
    out, _ = m.mine(db, 2)
    assert out == mine_bruteforce(db, 2)
    assert "diffset" in reps


# ---------------------------------------------------------------------------
# the representation tag survives compaction remaps
# ---------------------------------------------------------------------------

def test_representation_tag_survives_scheduler_remap():
    """FrontierScheduler.remap renumbers ``rows`` through the allocator
    mapping and touches nothing else — the tag (and payload) ride
    along unchanged."""
    class _NullClient:
        def release(self, klass):
            pass

    sched = FrontierScheduler(_NullClient(), pair_chunk=4)
    k1 = ClassNode(itemsets=[(0,), (1,)], rows=np.asarray([3, 5], np.int32),
                   supports=np.asarray([4, 4], np.int32),
                   representation="diffset", payload="diffset")
    k2 = ClassNode(itemsets=[(2,), (3,)], rows=np.asarray([0, 7], np.int32),
                   supports=np.asarray([4, 4], np.int32),
                   representation="tidset", payload="tidset")
    sched.push(k1)
    mapping = np.asarray([2, -1, -1, 0, -1, 1, -1, 3], np.int32)
    sched.remap(mapping, drained=[k2])
    assert k1.rows.tolist() == [0, 1] and k1.representation == "diffset"
    assert k1.payload == "diffset"
    assert k2.rows.tolist() == [2, 3] and k2.representation == "tidset"


def test_adaptive_forced_compaction_matches_bruteforce():
    """Compaction forced at every drain-group boundary (threshold 1.0)
    with diffset classes live on the frontier: results stay exact, so
    diffset row handles were remapped exactly like tidset ones."""
    db = _dense_db(seed=1, n_items=12, n_trans=80, dens=0.6)
    expected = mine_bruteforce(db, 8)
    m = BitmapMiner(scheme="adaptive", diff_density=0.3,
                    diff_hysteresis=0.1, block_words=1, pair_chunk=4,
                    compact_occupancy=1.0)
    diffset_classes = []
    real = BitmapMiner.make_class

    def spy(self, parent, children, _r=real):
        node = _r(self, parent, children)
        if node.representation == "diffset":
            diffset_classes.append(node)
        return node

    m.make_class = spy.__get__(m)
    out, stats = m.mine(db, 8)
    assert out == expected
    assert stats.compactions > 0         # forcing actually fired
    assert diffset_classes               # diffset rows crossed a remap


# ---------------------------------------------------------------------------
# mixed-mode drain groups: one fused dispatch per representation present
# ---------------------------------------------------------------------------

def test_mixed_mode_dispatch_accounting(monkeypatch):
    """device_calls == tidset dispatches + diffset dispatches and both
    modes actually occur under adaptive switching.  Density is NOT
    monotone down the tree in aggregate — a dense item cluster's
    subtree sits above the threshold while the sparse tail keeps the
    root mean below it — so a mixed DB exercises tidset root dispatches
    AND diffset subtree dispatches in one run."""
    from repro.kernels import ops

    calls = {"and": 0, "diff": 0}
    real_and, real_diff = ops.screen_and_intersect, ops.screen_and_diff

    def count_and(*a, **k):
        calls["and"] += 1
        return real_and(*a, **k)

    def count_diff(*a, **k):
        calls["diff"] += 1
        return real_diff(*a, **k)

    monkeypatch.setattr(ops, "screen_and_intersect", count_and)
    monkeypatch.setattr(ops, "screen_and_diff", count_diff)

    rng = random.Random(0)
    db = []
    for _ in range(60):                  # 4 dense items + 5 sparse items
        t = [i for i in range(4) if rng.random() < 0.9]
        t += [4 + j for j in range(5) if rng.random() < 0.15]
        if t:
            db.append(t)
    out, stats = mine_bitmap(db, 3, scheme="adaptive", diff_density=0.55,
                             diff_hysteresis=0.05, block_words=2,
                             pair_chunk=8)
    assert out == mine_bruteforce(db, 3)
    assert calls["and"] >= 1 and calls["diff"] >= 1
    assert calls["and"] + calls["diff"] == stats.device_calls


def test_sparse_adaptive_never_flips():
    """Below the band nothing flips: the adaptive miner runs the exact
    tidset ("and") dispatch sequence of plain eclat."""
    db = _sparse_db(seed=4)
    out_a, st_a = mine_bitmap(db, 2, scheme="adaptive", diff_density=0.9,
                              diff_hysteresis=0.05, block_words=2)
    out_e, st_e = mine_bitmap(db, 2, scheme="eclat", block_words=2)
    assert out_a == out_e == mine_bruteforce(db, 2)
    assert st_a.device_calls == st_e.device_calls
    assert st_a.word_ops == st_e.word_ops
