"""Beyond-paper: the Early-Stopping idea transferred to retrieval scoring.

``retrieval_cand`` scores 1M candidates for one query and keeps top-k.
The paper's insight — process a PREFIX of each list and bound what the
SUFFIX can still contribute; abort when the bound can't reach the
threshold — maps exactly onto prefix-dot screening:

  index build (offline, like the suffix-popcount tables):
      rotate candidates into their PCA basis (energy concentrates in the
      leading dims) and precompute per-candidate tail norms ||c[p:]||;
  phase 1 (screen): s_prefix = C[:, :p] @ q[:p]; the suffix contribution
      is certified by Cauchy-Schwarz: |s - s_prefix| <= ||c[p:]||*||q[p:]||
      — the exact analogue of `count_so_far + suffix_bound < minSup`;
  phase 2 (exact): full dots only for candidates whose upper bound clears
      the running k-th-best lower bound.

Exactness: the bound guarantees the true top-k is contained in the
survivor set, like ES guarantees no frequent itemset is pruned.
Reported: full-scan vs screened time, survivor fraction, and top-k
agreement (must be 1.0).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np


def _topk(scores: np.ndarray, k: int) -> np.ndarray:
    idx = np.argpartition(-scores, k)[:k]
    return idx[np.argsort(-scores[idx])]


def make_candidates(C: int, D: int, seed: int = 0,
                    spectrum: float = 0.7) -> np.ndarray:
    """Unit-norm embeddings with power-law per-dim energy (realistic:
    learned embedding spectra decay; pure isotropic noise is the
    no-structure worst case where NO certified screen can prune)."""
    rng = np.random.default_rng(seed)
    scales = (np.arange(1, D + 1, dtype=np.float32) ** -spectrum)
    cand = rng.normal(size=(C, D)).astype(np.float32) * scales
    cand /= np.linalg.norm(cand, axis=1, keepdims=True)
    return cand


def build_index(cand: np.ndarray, prefix: int,
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """PCA-rotate + precompute tail norms (the 'suffix tables')."""
    # PCA via covariance eigendecomposition (offline cost, not timed)
    cov = (cand.T @ cand) / cand.shape[0]
    _, vecs = np.linalg.eigh(cov)
    rot = vecs[:, ::-1]                      # descending eigenvalue order
    cr = cand @ rot
    tail_norms = np.linalg.norm(cr[:, prefix:], axis=1)
    # store the prefix block CONTIGUOUSLY: a row-major column slice still
    # drags whole rows through memory — the screen must own its layout
    # (same reason the bitmap engine owns its block layout)
    cr_prefix = np.ascontiguousarray(cr[:, :prefix])
    return cr, cr_prefix, rot, tail_norms


def full_scan(q: np.ndarray, cand: np.ndarray, k: int) -> np.ndarray:
    return _topk(cand @ q, k)


def screened_scan(q_rot: np.ndarray, cr: np.ndarray, cr_prefix: np.ndarray,
                  tail_norms: np.ndarray, prefix: int, k: int,
                  ) -> Tuple[np.ndarray, float]:
    s_prefix = cr_prefix @ q_rot[:prefix]
    tail_bound = tail_norms * np.linalg.norm(q_rot[prefix:])
    upper = s_prefix + tail_bound
    lower = s_prefix - tail_bound
    kth = -np.partition(-lower, k)[k]        # certified k-th-best lower bd
    alive = upper >= kth
    idx = np.nonzero(alive)[0]
    exact = cr[idx] @ q_rot
    top = idx[_topk(exact, k)]
    return top, alive.mean()


def run(C: int = 1_000_000, D: int = 256, k: int = 100, prefix: int = 32,
        seed: int = 0, spectrum: float = 1.0) -> List[str]:
    cand = make_candidates(C, D, seed, spectrum)
    rng = np.random.default_rng(seed + 1)
    # the query comes from the same learned embedding space (user-tower
    # outputs share the item spectrum); an isotropic query would be the
    # no-structure worst case where no certified screen can prune
    scales = (np.arange(1, D + 1, dtype=np.float32) ** -spectrum)
    q = rng.normal(size=(D,)).astype(np.float32) * scales
    q /= np.linalg.norm(q)

    t0 = time.perf_counter()
    ref = full_scan(q, cand, k)
    t_full = time.perf_counter() - t0

    cr, cr_prefix, rot, tail_norms = build_index(cand, prefix)  # offline
    q_rot = rot.T @ q
    t0 = time.perf_counter()
    got, survivor_frac = screened_scan(q_rot, cr, cr_prefix, tail_norms,
                                       prefix, k)
    t_scr = time.perf_counter() - t0

    same = len(set(ref.tolist()) & set(got.tolist())) / k
    return [
        f"retrieval/full_scan/C{C}D{D},{t_full*1e6:.0f},topk=exact",
        f"retrieval/screened_p{prefix}/C{C}D{D},{t_scr*1e6:.0f},"
        f"survivors={survivor_frac:.3%};topk_agree={same:.3f};"
        f"speedup={t_full/t_scr:.2f}x",
    ]
