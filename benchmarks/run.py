"""Benchmark harness: one section per paper table/figure + kernel micro.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` limits the paper
sweep to the two largest minsups per dataset (the full ladder is the
``--full`` mode used for EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 4 minsup levels on all 9 datasets")
    ap.add_argument("--datasets", default="",
                    help="comma-separated replica names (default: all)")
    ap.add_argument("--sections", default="paper,kernels,retrieval")
    ap.add_argument("--retrieval-c", type=int, default=250_000)
    args = ap.parse_args()
    sections = set(args.sections.split(","))

    print("name,us_per_call,derived")
    lines = []

    if "paper" in sections:
        from benchmarks.bench_paper import run_dataset, csv_rows, \
            table_iv, figures
        from repro.data import make_dataset, DATASET_REPLICAS
        names = (args.datasets.split(",") if args.datasets
                 else list(DATASET_REPLICAS))
        all_rows = []
        for name in names:
            _, minsups = make_dataset(name)
            levels = minsups[1:] if args.full else minsups[2:]
            rows = run_dataset(name, levels)
            all_rows.extend(rows)
            for line in csv_rows(rows):
                print(line)
        print("\n# Table IV analogue", file=sys.stderr)
        print(table_iv(all_rows), file=sys.stderr)
        print("\n# Figures 7-15 analogue", file=sys.stderr)
        print(figures(all_rows), file=sys.stderr)

    if "kernels" in sections:
        from benchmarks.bench_kernels import (bench_bitmap, bench_attention,
                                              bench_embedding_bag,
                                              bench_nlist)
        for line in (bench_bitmap() + bench_attention()
                     + bench_embedding_bag() + bench_nlist()):
            print(line)

    if "retrieval" in sections:
        from benchmarks.bench_retrieval import run as bench_retrieval
        for line in bench_retrieval(C=args.retrieval_c):
            print(line)

    for line in lines:
        print(line)


if __name__ == "__main__":
    main()
