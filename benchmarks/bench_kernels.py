"""Kernel micro-benchmarks (jnp backends on CPU; the Pallas kernels are
TPU-target and validated in interpret mode, which is not a timing mode).

Reports us_per_call and derived throughput so regressions in the
hot-path ops are visible run over run.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.bitmap import suffix_popcounts_np, popcount32_np
from repro.kernels import ops


def _timeit(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_bitmap(n_pairs=4096, n_blocks=8, bw=128) -> List[str]:
    rng = np.random.default_rng(0)
    U = rng.integers(0, 2 ** 32, (n_pairs, n_blocks, bw),
                     dtype=np.uint64).astype(np.uint32)
    V = (U & rng.integers(0, 2 ** 32, U.shape, dtype=np.uint64)
         .astype(np.uint32))
    su = jnp.asarray(suffix_popcounts_np(U))
    sv = jnp.asarray(suffix_popcounts_np(V))
    rho = jnp.asarray(popcount32_np(U).reshape(n_pairs, -1)
                      .sum(1).astype(np.int32))
    Uj, Vj = jnp.asarray(U), jnp.asarray(V)
    words = n_pairs * n_blocks * bw

    out = []
    dt = _timeit(lambda: ops.bitmap_intersect_full(Uj, Vj)[1])
    out.append(f"kernels/bitmap_full/{n_pairs}x{n_blocks}x{bw},"
               f"{dt*1e6:.0f},Gword_s={words/dt/1e9:.2f}")
    dt = _timeit(lambda: ops.bitmap_intersect_es(
        Uj, Vj, su, sv, rho, jnp.int32(64), mode="and")[1])
    out.append(f"kernels/bitmap_es_metrics/{n_pairs}x{n_blocks}x{bw},"
               f"{dt*1e6:.0f},Gword_s={words/dt/1e9:.2f}")
    dt = _timeit(lambda: ops.screen_pairs(
        Uj[:, 0], Vj[:, 0], su[:, 1], sv[:, 1], rho, jnp.int32(64))[0])
    out.append(f"kernels/bitmap_screen/{n_pairs}x{bw},"
               f"{dt*1e6:.0f},Gword_s={n_pairs*bw/dt/1e9:.2f}")
    out.extend(bench_fused_store(n_pairs=n_pairs, n_blocks=n_blocks, bw=bw))
    return out


def bench_fused_store(n_pairs=4096, n_blocks=8, bw=128) -> List[str]:
    """The mining hot path: one fused gather+screen+intersect+scatter
    dispatch against a device-resident row store.  Donation means fresh
    operand slabs per call, so this times the full chunk round-trip the
    miner actually pays (minus the tiny count/alive readback)."""
    from repro.core.rowstore import DeviceRowStore
    rng = np.random.default_rng(4)
    cap = 2 * n_pairs
    rows = rng.integers(0, 2 ** 32, (n_pairs, n_blocks, bw),
                        dtype=np.uint64).astype(np.uint32)
    ua = rng.integers(0, n_pairs, n_pairs).astype(np.int32)
    vb = rng.integers(0, n_pairs, n_pairs).astype(np.int32)
    slots = np.arange(n_pairs, 2 * n_pairs, dtype=np.int32)
    words = n_pairs * n_blocks * bw

    def run():
        store = DeviceRowStore(rows, capacity=cap)
        rho = np.asarray(store.suffix[ua, 0], np.int32)
        t0 = time.perf_counter()
        r = ops.screen_and_intersect(store.rows, store.suffix, ua, vb,
                                     slots, rho, jnp.int32(64), mode="and")
        jax.block_until_ready(r)
        return time.perf_counter() - t0

    run()                      # compile
    dt = min(run() for _ in range(5))
    return [f"kernels/fused_screen_intersect/{n_pairs}x{n_blocks}x{bw},"
            f"{dt*1e6:.0f},Gword_s={words/dt/1e9:.2f}"]


def bench_attention(B=2, S=1024, H=8, KH=2, D=64) -> List[str]:
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    flops = 4.0 * B * S * S * H * D / 2  # causal

    from repro.models.layers import chunked_attention
    f = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True,
                                                  chunk=256))
    dt = _timeit(f, q, k, v)
    out = [f"kernels/chunked_attention/B{B}S{S}H{H},"
           f"{dt*1e6:.0f},GFLOP_s={flops/dt/1e9:.1f}"]
    dt = _timeit(jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, backend='jnp')), q, k, v)
    out.append(f"kernels/attention_ref/B{B}S{S}H{H},"
               f"{dt*1e6:.0f},GFLOP_s={flops/dt/1e9:.1f}")
    return out


def bench_embedding_bag(V=100_000, D=64, B=4096, L=50) -> List[str]:
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, V, (B, L)), jnp.int32)
    mask = jnp.asarray(rng.random((B, L)) < 0.9)
    f = jax.jit(lambda t, i, m: ops.embedding_bag(t, i, m, backend="jnp"))
    dt = _timeit(f, table, ids, mask)
    return [f"kernels/embedding_bag/V{V}D{D}B{B}L{L},"
            f"{dt*1e6:.0f},Mlookup_s={B*L/dt/1e6:.1f}"]


def bench_nlist(n_pairs=2048, lu=64, lv=64) -> List[str]:
    rng = np.random.default_rng(3)
    def mk(n, L):
        pre = np.sort(rng.integers(0, 10_000, (n, L)).astype(np.int32), 1)
        post = rng.integers(0, 10_000, (n, L)).astype(np.int32)
        freq = rng.integers(1, 50, (n, L)).astype(np.int32)
        return pre, post, freq
    up, upo, uf = mk(n_pairs, lu)
    vp, vpo, vf = mk(n_pairs, lv)
    ul = np.full(n_pairs, lu, np.int32)
    vl = np.full(n_pairs, lv, np.int32)
    rho = vf.sum(1).astype(np.int32)
    f = jax.jit(lambda *a: ops.nlist_intersect(*a, early_stop=True)[1])
    dt = _timeit(f, up, upo, uf, vp, vpo, vf, ul, vl, rho, jnp.int32(100))
    return [f"kernels/nlist_intersect/{n_pairs}x{lu},"
            f"{dt*1e6:.0f},Mpair_s={n_pairs/dt/1e6:.2f}"]
