"""Paper reproduction benchmarks.

* Table IV analogue: #Cands / #Nodes / Ratio per dataset x minsup.
* Figures 7-15 analogue: #comparisons and runtime for the six schemes
  (Eclat, dEclat, PrePost+ each with/without Early Stopping) on the nine
  dataset replicas, plus the device bitmap engine's word-op metric.

Replicas are statistical stand-ins for the FIMI/KONECT sets (offline
container); the paper's qualitative claims under test:
  C1 ES reduces comparisons on every dataset (guaranteed);
  C2 reductions are large on high-ratio (sparse) data, negligible on
     dense low-ratio data;
  C3 #cands/#nodes are identical across schemes at a given minsup.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core.oracle import mine
from repro.core.eclat import mine_bitmap
from repro.data import make_dataset

SCHEMES = ("eclat", "declat", "prepost")


def run_dataset(name: str, minsup_levels: List[int], runs: int = 1,
                ) -> List[Dict]:
    db, _ = make_dataset(name)
    rows: List[Dict] = []
    for li, ms in enumerate(minsup_levels):
        base: Dict[str, Dict] = {}
        for scheme in SCHEMES:
            for es in (False, True):
                t0 = time.perf_counter()
                for _ in range(runs):
                    out, st = mine(db, ms, scheme, early_stop=es)
                dt = (time.perf_counter() - t0) / runs
                base[f"{scheme}{'-ES' if es else ''}"] = {
                    "comparisons": st.comparisons,
                    "runtime_s": dt,
                    "cands": st.candidates,
                    "nodes": st.nodes,
                    "aborts": st.es_aborts,
                    "F": len(out),
                }
        # device engine (word-op metric)
        for es in (False, True):
            t0 = time.perf_counter()
            out_b, st_b = mine_bitmap(db, ms, "eclat", early_stop=es,
                                      block_words=8)
            base[f"bitmap-eclat{'-ES' if es else ''}"] = {
                "comparisons": st_b.word_ops,
                "runtime_s": time.perf_counter() - t0,
                "cands": st_b.candidates,
                "nodes": st_b.nodes,
                "aborts": st_b.kernel_aborts + st_b.screened_out,
                "F": len(out_b),
            }
        rows.append({"dataset": name, "minsup_level": li + 1,
                     "minsup": ms, "schemes": base})
    return rows


def table_iv(rows: List[Dict]) -> str:
    """#Cands / #Nodes / Ratio (identical across schemes — checked)."""
    out = ["| dataset | minSup | #Cands | #Nodes | Ratio |",
           "|---|---|---|---|---|"]
    for r in rows:
        s = r["schemes"]["eclat"]
        for other in ("declat", "prepost"):
            # PrePost+ proposes the same candidate count modulo the
            # final-singleton classes; nodes must match exactly.
            assert r["schemes"][other]["nodes"] == s["nodes"], r["dataset"]
        ratio = s["cands"] / max(s["nodes"], 1)
        out.append(f"| {r['dataset']} | {r['minsup']} | {s['cands']:.3g} "
                   f"| {s['nodes']:.3g} | {ratio:.2f} |")
    return "\n".join(out)


def figures(rows: List[Dict]) -> str:
    """Comparisons + runtime per scheme (the Figures 7-15 content)."""
    out = ["| dataset | minSup | scheme | comparisons | saved | "
           "runtime_s | aborts |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        for scheme in ("eclat", "declat", "prepost", "bitmap-eclat"):
            std = r["schemes"][scheme]
            es = r["schemes"][scheme + "-ES"]
            saved = 1 - es["comparisons"] / max(std["comparisons"], 1)
            out.append(
                f"| {r['dataset']} | {r['minsup']} | {scheme} "
                f"| {std['comparisons']:.4g} -> {es['comparisons']:.4g} "
                f"| {saved:.1%} | {std['runtime_s']:.3f} -> "
                f"{es['runtime_s']:.3f} | {es['aborts']} |")
    return "\n".join(out)


def csv_rows(rows: List[Dict]) -> List[str]:
    """name,us_per_call,derived lines for benchmarks.run."""
    out = []
    for r in rows:
        for scheme, v in r["schemes"].items():
            us = v["runtime_s"] * 1e6
            out.append(
                f"paper/{r['dataset']}/ms{r['minsup_level']}/{scheme},"
                f"{us:.0f},comparisons={v['comparisons']};F={v['F']}")
    return out
