"""Paper reproduction benchmarks.

* Table IV analogue: #Cands / #Nodes / Ratio per dataset x minsup.
* Figures 7-15 analogue: #comparisons and runtime for the six schemes
  (Eclat, dEclat, PrePost+ each with/without Early Stopping) on the nine
  dataset replicas, plus the device bitmap engine's word-op metric.

Replicas are statistical stand-ins for the FIMI/KONECT sets (offline
container); the paper's qualitative claims under test:
  C1 ES reduces comparisons on every dataset (guaranteed);
  C2 reductions are large on high-ratio (sparse) data, negligible on
     dense low-ratio data;
  C3 #cands/#nodes are identical across schemes at a given minsup.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

from repro.core.oracle import mine
from repro.core.eclat import mine_bitmap
from repro.data import make_dataset

SCHEMES = ("eclat", "declat", "prepost")


def run_dataset(name: str, minsup_levels: List[int], runs: int = 1,
                ) -> List[Dict]:
    db, _ = make_dataset(name)
    rows: List[Dict] = []
    for li, ms in enumerate(minsup_levels):
        base: Dict[str, Dict] = {}
        for scheme in SCHEMES:
            for es in (False, True):
                t0 = time.perf_counter()
                for _ in range(runs):
                    out, st = mine(db, ms, scheme, early_stop=es)
                dt = (time.perf_counter() - t0) / runs
                base[f"{scheme}{'-ES' if es else ''}"] = {
                    "comparisons": st.comparisons,
                    "runtime_s": dt,
                    "cands": st.candidates,
                    "nodes": st.nodes,
                    "aborts": st.es_aborts,
                    "F": len(out),
                }
        # device engine (word-op metric)
        for es in (False, True):
            t0 = time.perf_counter()
            out_b, st_b = mine_bitmap(db, ms, "eclat", early_stop=es,
                                      block_words=8)
            base[f"bitmap-eclat{'-ES' if es else ''}"] = {
                "comparisons": st_b.word_ops,
                "runtime_s": time.perf_counter() - t0,
                "cands": st_b.candidates,
                "nodes": st_b.nodes,
                "aborts": st_b.kernel_aborts + st_b.screened_out,
                "F": len(out_b),
            }
        rows.append({"dataset": name, "minsup_level": li + 1,
                     "minsup": ms, "schemes": base})
    return rows


def table_iv(rows: List[Dict]) -> str:
    """#Cands / #Nodes / Ratio (identical across schemes — checked)."""
    out = ["| dataset | minSup | #Cands | #Nodes | Ratio |",
           "|---|---|---|---|---|"]
    for r in rows:
        s = r["schemes"]["eclat"]
        for other in ("declat", "prepost"):
            # PrePost+ proposes the same candidate count modulo the
            # final-singleton classes; nodes must match exactly.
            assert r["schemes"][other]["nodes"] == s["nodes"], r["dataset"]
        ratio = s["cands"] / max(s["nodes"], 1)
        out.append(f"| {r['dataset']} | {r['minsup']} | {s['cands']:.3g} "
                   f"| {s['nodes']:.3g} | {ratio:.2f} |")
    return "\n".join(out)


def figures(rows: List[Dict]) -> str:
    """Comparisons + runtime per scheme (the Figures 7-15 content)."""
    out = ["| dataset | minSup | scheme | comparisons | saved | "
           "runtime_s | aborts |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        for scheme in ("eclat", "declat", "prepost", "bitmap-eclat"):
            std = r["schemes"][scheme]
            es = r["schemes"][scheme + "-ES"]
            saved = 1 - es["comparisons"] / max(std["comparisons"], 1)
            out.append(
                f"| {r['dataset']} | {r['minsup']} | {scheme} "
                f"| {std['comparisons']:.4g} -> {es['comparisons']:.4g} "
                f"| {saved:.1%} | {std['runtime_s']:.3f} -> "
                f"{es['runtime_s']:.3f} | {es['aborts']} |")
    return "\n".join(out)


def run_smoke(out_path: str = "BENCH_smoke.json") -> Dict:
    """CI benchmark smoke: tiny sparse synthetic DB through the
    device-resident engine, ES vs full.

    Hard-asserts the paper's headline effect (``word_ops_saved_frac > 0``
    for the ES engine vs the non-ES full run, identical result sets) and
    writes the stats JSON so every CI run leaves a bench artifact.
    """
    from repro.data.transactions import gen_powerlaw_baskets

    db = gen_powerlaw_baskets(n_trans=800, n_items=400, avg_trans_len=8,
                              seed=0)
    minsup = max(2, int(round(0.004 * len(db))))
    t0 = time.perf_counter()
    out_es, st_es = mine_bitmap(db, minsup, "eclat", early_stop=True,
                                block_words=8)
    t_es = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_no, st_no = mine_bitmap(db, minsup, "eclat", early_stop=False,
                                block_words=8)
    t_no = time.perf_counter() - t0

    assert out_es == out_no, "ES changed the result set"
    assert st_es.word_ops_saved_frac > 0, (
        f"ES saved no word ops: {st_es.as_dict()}")
    assert st_es.word_ops < st_no.word_ops

    report = {
        "dataset": {"family": "powerlaw", "n_trans": len(db),
                    "n_items": 400, "minsup": minsup},
        "frequent_itemsets": len(out_es),
        "es": {**st_es.as_dict(), "wall_s": round(t_es, 3)},
        "full": {**st_no.as_dict(), "wall_s": round(t_no, 3)},
        "word_ops_saved_frac": st_es.word_ops_saved_frac,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"smoke ok: word_ops_saved_frac="
          f"{st_es.word_ops_saved_frac:.3f}, "
          f"device_calls={st_es.device_calls}, F={len(out_es)} "
          f"-> {out_path}", file=sys.stderr)
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic dataset; assert ES word-op "
                         "savings and write a BENCH_*.json artifact")
    ap.add_argument("--out", default="BENCH_smoke.json",
                    help="smoke-mode JSON output path")
    args = ap.parse_args()
    if args.smoke:
        run_smoke(args.out)
        return
    print("full paper sweep lives in benchmarks/run.py "
          "(python -m benchmarks.run --sections paper); "
          "use --smoke for the CI smoke bench", file=sys.stderr)
    sys.exit(2)


def csv_rows(rows: List[Dict]) -> List[str]:
    """name,us_per_call,derived lines for benchmarks.run."""
    out = []
    for r in rows:
        for scheme, v in r["schemes"].items():
            us = v["runtime_s"] * 1e6
            out.append(
                f"paper/{r['dataset']}/ms{r['minsup_level']}/{scheme},"
                f"{us:.0f},comparisons={v['comparisons']};F={v['F']}")
    return out


if __name__ == "__main__":
    main()
