"""Paper reproduction benchmarks.

* Table IV analogue: #Cands / #Nodes / Ratio per dataset x minsup.
* Figures 7-15 analogue: #comparisons and runtime for the six schemes
  (Eclat, dEclat, PrePost+ each with/without Early Stopping) on the nine
  dataset replicas, plus the device bitmap engine's word-op metric.

Replicas are statistical stand-ins for the FIMI/KONECT sets (offline
container); the paper's qualitative claims under test:
  C1 ES reduces comparisons on every dataset (guaranteed);
  C2 reductions are large on high-ratio (sparse) data, negligible on
     dense low-ratio data;
  C3 #cands/#nodes are identical across schemes at a given minsup.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

from repro.core.oracle import mine
from repro.core.eclat import mine_bitmap
from repro.data import make_dataset

SCHEMES = ("eclat", "declat", "prepost")


def run_dataset(name: str, minsup_levels: List[int], runs: int = 1,
                ) -> List[Dict]:
    db, _ = make_dataset(name)
    rows: List[Dict] = []
    for li, ms in enumerate(minsup_levels):
        base: Dict[str, Dict] = {}
        for scheme in SCHEMES:
            for es in (False, True):
                t0 = time.perf_counter()
                for _ in range(runs):
                    out, st = mine(db, ms, scheme, early_stop=es)
                dt = (time.perf_counter() - t0) / runs
                base[f"{scheme}{'-ES' if es else ''}"] = {
                    "comparisons": st.comparisons,
                    "runtime_s": dt,
                    "cands": st.candidates,
                    "nodes": st.nodes,
                    "aborts": st.es_aborts,
                    "F": len(out),
                }
        # device engine (word-op metric)
        for es in (False, True):
            t0 = time.perf_counter()
            out_b, st_b = mine_bitmap(db, ms, "eclat", early_stop=es,
                                      block_words=8)
            base[f"bitmap-eclat{'-ES' if es else ''}"] = {
                "comparisons": st_b.word_ops,
                "runtime_s": time.perf_counter() - t0,
                "cands": st_b.candidates,
                "nodes": st_b.nodes,
                "aborts": st_b.kernel_aborts + st_b.screened_out,
                "F": len(out_b),
            }
        rows.append({"dataset": name, "minsup_level": li + 1,
                     "minsup": ms, "schemes": base})
    return rows


def table_iv(rows: List[Dict]) -> str:
    """#Cands / #Nodes / Ratio (identical across schemes — checked)."""
    out = ["| dataset | minSup | #Cands | #Nodes | Ratio |",
           "|---|---|---|---|---|"]
    for r in rows:
        s = r["schemes"]["eclat"]
        for other in ("declat", "prepost"):
            # PrePost+ proposes the same candidate count modulo the
            # final-singleton classes; nodes must match exactly.
            assert r["schemes"][other]["nodes"] == s["nodes"], r["dataset"]
        ratio = s["cands"] / max(s["nodes"], 1)
        out.append(f"| {r['dataset']} | {r['minsup']} | {s['cands']:.3g} "
                   f"| {s['nodes']:.3g} | {ratio:.2f} |")
    return "\n".join(out)


def figures(rows: List[Dict]) -> str:
    """Comparisons + runtime per scheme (the Figures 7-15 content)."""
    out = ["| dataset | minSup | scheme | comparisons | saved | "
           "runtime_s | aborts |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        for scheme in ("eclat", "declat", "prepost", "bitmap-eclat"):
            std = r["schemes"][scheme]
            es = r["schemes"][scheme + "-ES"]
            saved = 1 - es["comparisons"] / max(std["comparisons"], 1)
            out.append(
                f"| {r['dataset']} | {r['minsup']} | {scheme} "
                f"| {std['comparisons']:.4g} -> {es['comparisons']:.4g} "
                f"| {saved:.1%} | {std['runtime_s']:.3f} -> "
                f"{es['runtime_s']:.3f} | {es['aborts']} |")
    return "\n".join(out)


def _smoke_datasets() -> Dict[str, tuple]:
    """The CI smoke matrix (ROADMAP "widen the smoke dataset set"):

    * ``powerlaw`` — sparse retail-like, high candidate/node ratio: the
      regime where bitmap-engine ES word-op savings are large;
    * ``dense``    — correlated tabular (chess-like), ratio ~ 1;
    * ``longpat``  — highly correlated tabular with long frequent
      patterns (maxlen ~ n_cols): the dense/long-pattern regime where
      N-list schemes (PrePost+) are the interesting engine.
    """
    from repro.data.transactions import (gen_dense_tabular,
                                         gen_powerlaw_baskets)

    return {
        "powerlaw": (gen_powerlaw_baskets(n_trans=300, n_items=200,
                                          avg_trans_len=6, seed=0), 3),
        "dense": (gen_dense_tabular(n_trans=500, n_cols=9,
                                    vals_per_col=4, seed=0), 175),
        "longpat": (gen_dense_tabular(n_trans=400, n_cols=10,
                                      vals_per_col=3, correlation=0.95,
                                      n_classes=2, seed=1), 120),
    }


# Frozen PR 3 reference: DevicePrePost issued one dispatch per class
# member's sibling window, which cost 1021 fused calls on the longpat
# smoke regime.  The shared frontier scheduler (ISSUE 4) must beat it.
_PR3_LONGPAT_PREPOST_DEVICE_CALLS = 1021


def run_smoke(out_path: str = "BENCH_smoke.json") -> Dict:
    """CI benchmark smoke: the three-regime dataset matrix through both
    device engines (bitmap Eclat and PrePost+), ES vs full.

    Hard-asserts the paper's headline effect where it is guaranteed
    (identical result sets everywhere; ``word_ops_saved_frac > 0`` and
    PrePost+ comparison savings on the sparse powerlaw replica; ES never
    increases PrePost+ comparisons anywhere) plus the ISSUE 4 frontier
    acceptance (PrePost+ ``device_calls`` on longpat strictly below the
    PR 3 per-member-dispatch baseline), and writes the stats JSON so
    every CI run leaves a bench artifact — including the allocator
    telemetry (``peak_rows`` / ``peak_codes``, ``compactions``,
    post-compaction occupancy) that
    benchmarks/check_bench_regression.py diffs vs the committed
    baseline.
    """
    from repro.core.prepost import mine_prepost_device

    report: Dict = {"datasets": {}}
    for name, (db, minsup) in _smoke_datasets().items():
        t0 = time.perf_counter()
        out_es, st_es = mine_bitmap(db, minsup, "eclat", early_stop=True,
                                    block_words=8)
        t_es = time.perf_counter() - t0
        t0 = time.perf_counter()
        out_no, st_no = mine_bitmap(db, minsup, "eclat", early_stop=False,
                                    block_words=8)
        t_no = time.perf_counter() - t0
        t0 = time.perf_counter()
        out_pes, st_pes = mine_prepost_device(db, minsup, early_stop=True)
        t_pes = time.perf_counter() - t0
        t0 = time.perf_counter()
        out_pno, st_pno = mine_prepost_device(db, minsup, early_stop=False)
        t_pno = time.perf_counter() - t0

        # Density-adaptive diffset representation (ISSUE 6): the same DB
        # through the adaptive engine at 1-word blocks — the 300-500
        # transaction replicas then span 10-16 blocks, which gives the
        # diffset scan's zero-mass block skip something to skip — vs the
        # tidset engine at the SAME granularity (the fair word_ops
        # reference the dense acceptance gate compares against).
        akw = dict(block_words=1, diff_density=0.3, diff_hysteresis=0.05)
        t0 = time.perf_counter()
        out_aes, st_aes = mine_bitmap(db, minsup, "adaptive",
                                      early_stop=True, **akw)
        t_aes = time.perf_counter() - t0
        t0 = time.perf_counter()
        out_ano, st_ano = mine_bitmap(db, minsup, "adaptive",
                                      early_stop=False, **akw)
        t_ano = time.perf_counter() - t0
        _, st_tes = mine_bitmap(db, minsup, "eclat", early_stop=True,
                                block_words=1)

        assert out_es == out_no == out_pes == out_pno == out_aes == out_ano, (
            f"{name}: engines disagree")
        assert st_pes.comparisons <= st_pno.comparisons, (
            f"{name}: ES increased PrePost+ comparisons")
        cmp_saved = 1.0 - st_pes.comparisons / max(st_pno.comparisons, 1)
        report["datasets"][name] = {
            "dataset": {"n_trans": len(db), "minsup": minsup},
            "frequent_itemsets": len(out_es),
            "frequent_children": sum(1 for s in out_es if len(s) >= 2),
            "es": {**st_es.as_dict(), "wall_s": round(t_es, 3)},
            "full": {**st_no.as_dict(), "wall_s": round(t_no, 3)},
            "word_ops_saved_frac": st_es.word_ops_saved_frac,
            "prepost": {
                "es": {**st_pes.as_dict(), "wall_s": round(t_pes, 3)},
                "full": {**st_pno.as_dict(), "wall_s": round(t_pno, 3)},
                "comparisons_saved_frac": round(cmp_saved, 4),
            },
            "adaptive": {
                "knobs": akw,
                "es": {**st_aes.as_dict(), "wall_s": round(t_aes, 3)},
                "full": {**st_ano.as_dict(), "wall_s": round(t_ano, 3)},
                # tidset engine at the same 1-word block granularity:
                # the apples-to-apples reference for the representation
                # saving (word_ops_full is already granularity-shared)
                "tidset_es_word_ops": st_tes.word_ops,
            },
        }
        print(f"smoke {name}: F={len(out_es)}, "
              f"word_ops_saved_frac={st_es.word_ops_saved_frac:.3f}, "
              f"prepost_cmp_saved={cmp_saved:.3f}, "
              f"device_calls={st_es.device_calls}+"
              f"{st_pes.device_calls}, "
              f"compactions={st_es.compactions}+{st_pes.compactions}, "
              f"peak={st_es.peak_rows}r/{st_pes.peak_codes}c, "
              f"scatters={st_es.child_scatters}/{st_es.candidates}cand "
              f"({st_es.scatter_words}+{st_pes.scatter_words}w), "
              f"adaptive_word_ops={st_aes.word_ops} "
              f"(tidset@bw1={st_tes.word_ops})",
              file=sys.stderr)

    # Dispatch-pipeline demo (ISSUE 7): at the default pair_chunk every
    # DFS wave drains into one group (nothing to overlap), so the
    # occupancy demo runs the powerlaw regime at a small chunk where
    # each wave splits into several groups and the double-buffered ring
    # actually interleaves host assembly with device execution.  The
    # occupancy metric is deterministic (ring state at dispatch, not
    # timing), so it is assert-able in CI; assemble_s/resolve_s are the
    # informational assembly-vs-device time split
    # (check_bench_regression.py ignores fields it does not know).
    pl_db, pl_ms = _smoke_datasets()["powerlaw"]
    pipe_chunk = 1024
    _, st_ser = mine_bitmap(pl_db, pl_ms, "eclat", early_stop=True,
                            block_words=8, pair_chunk=pipe_chunk,
                            inflight=1)
    _, st_pipe = mine_bitmap(pl_db, pl_ms, "eclat", early_stop=True,
                             block_words=8, pair_chunk=pipe_chunk,
                             inflight=2)
    report["pipeline"] = {
        "regime": "powerlaw", "pair_chunk": pipe_chunk,
        "serial": {"device_occupancy": st_ser.device_occupancy,
                   "assemble_s": round(st_ser.assemble_s, 6),
                   "resolve_s": round(st_ser.resolve_s, 6)},
        "pipelined": {"device_occupancy": st_pipe.device_occupancy,
                      "assemble_s": round(st_pipe.assemble_s, 6),
                      "resolve_s": round(st_pipe.resolve_s, 6)},
    }

    # Per-bucket chunk-width autotuning (ISSUE 7): at a deliberately
    # small base pair_chunk the width table widens every chunk (smoke
    # operands are far below the reference operand size), collapsing
    # device_calls at bit-identical per-pair work.
    auto_chunk = 64
    auto = {"regime": "powerlaw", "base_pair_chunk": auto_chunk}
    _, st_boff = mine_bitmap(pl_db, pl_ms, "eclat", early_stop=True,
                             block_words=8, pair_chunk=auto_chunk,
                             autotune_chunk=False)
    _, st_bon = mine_bitmap(pl_db, pl_ms, "eclat", early_stop=True,
                            block_words=8, pair_chunk=auto_chunk,
                            autotune_chunk=True)
    auto["bitmap"] = {
        "device_calls": {"off": st_boff.device_calls,
                         "on": st_bon.device_calls},
        "word_ops": {"off": st_boff.word_ops, "on": st_bon.word_ops},
        "scatter_words": {"off": st_boff.scatter_words,
                          "on": st_bon.scatter_words},
    }
    _, st_poff = mine_prepost_device(pl_db, pl_ms, early_stop=True,
                                     pair_chunk=auto_chunk,
                                     autotune_chunk=False)
    _, st_pon = mine_prepost_device(pl_db, pl_ms, early_stop=True,
                                    pair_chunk=auto_chunk,
                                    autotune_chunk=True)
    auto["prepost"] = {
        "device_calls": {"off": st_poff.device_calls,
                         "on": st_pon.device_calls},
        "comparisons": {"off": st_poff.comparisons,
                        "on": st_pon.comparisons},
        "scatter_words": {"off": st_poff.scatter_words,
                          "on": st_pon.scatter_words},
    }
    report["autotune"] = auto
    print(f"smoke pipeline: occupancy {st_ser.device_occupancy:.2f} -> "
          f"{st_pipe.device_occupancy:.2f} @chunk={pipe_chunk}; "
          f"autotune device_calls bitmap "
          f"{st_boff.device_calls}->{st_bon.device_calls}, prepost "
          f"{st_poff.device_calls}->{st_pon.device_calls} "
          f"@chunk={auto_chunk}", file=sys.stderr)

    # Write the artifact BEFORE the acceptance asserts: when a gate
    # trips, CI must still upload the telemetry needed to debug it.
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    # Survivor-only materialization (ISSUE 5): every engine's child
    # scatter count equals the frequent children, never the candidate
    # count, ES on or off.
    for name, ds in report["datasets"].items():
        n_children = ds["frequent_children"]
        for run in (ds["es"], ds["full"],
                    ds["prepost"]["es"], ds["prepost"]["full"]):
            assert run["child_scatters"] == n_children, (
                f"{name}: scattered {run['child_scatters']} children, "
                f"{n_children} are frequent")
    pl = report["datasets"]["powerlaw"]
    assert pl["word_ops_saved_frac"] > 0, "ES saved no word ops (powerlaw)"
    assert pl["prepost"]["comparisons_saved_frac"] > 0, (
        "ES saved no PrePost+ comparisons (powerlaw)")
    lp_calls = report["datasets"]["longpat"]["prepost"]["es"]["device_calls"]
    assert lp_calls < _PR3_LONGPAT_PREPOST_DEVICE_CALLS, (
        f"frontier batching regressed: longpat PrePost+ device_calls "
        f"{lp_calls} >= PR 3's {_PR3_LONGPAT_PREPOST_DEVICE_CALLS}")
    # ISSUE 6 acceptance: on the dense regime the density-adaptive
    # tidset->diffset switch must strictly beat the tidset engine's
    # word_ops at the same block granularity (the diffset rows of the
    # high-support subtrees go mostly zero-mass, and the skip-aware
    # work counter stops charging those blocks).
    da = report["datasets"]["dense"]["adaptive"]
    assert da["es"]["word_ops"] < da["tidset_es_word_ops"], (
        f"adaptive switching saved nothing on dense: word_ops "
        f"{da['es']['word_ops']} >= tidset {da['tidset_es_word_ops']}")
    # ISSUE 7 acceptance: the pipelined run overlaps drain groups on the
    # powerlaw regime (occupancy strictly above the serial baseline,
    # which is 0.0 by construction) ...
    pp = report["pipeline"]
    assert (pp["pipelined"]["device_occupancy"]
            > pp["serial"]["device_occupancy"]), (
        f"pipelining overlapped nothing: occupancy "
        f"{pp['pipelined']['device_occupancy']} <= serial "
        f"{pp['serial']['device_occupancy']}")
    # ... and per-bucket widths reduce device_calls at unchanged
    # per-pair work (word_ops / comparisons / scatter_words).
    at = report["autotune"]
    for eng, work_key in (("bitmap", "word_ops"),
                          ("prepost", "comparisons")):
        calls, work = at[eng]["device_calls"], at[eng][work_key]
        scat = at[eng]["scatter_words"]
        assert calls["on"] < calls["off"], (
            f"autotune reduced no {eng} device_calls: {calls}")
        assert work["on"] == work["off"], (
            f"autotune changed {eng} {work_key}: {work}")
        assert scat["on"] == scat["off"], (
            f"autotune changed {eng} scatter_words: {scat}")
    print(f"smoke ok -> {out_path}", file=sys.stderr)
    return report


def run_full(out_path: str = "BENCH_full.json", *, scale: float = 1.0,
             datasets: List[str] = None, block: int = None, cls: int = 1,
             seed: int = 0) -> Dict:
    """Paper-scale tier (ISSUE 9): the kosarak/accidents/pumsb replicas
    at (scaled) paper row counts, streamed into the sharded row store
    and mined by ``DistributedMiner`` on a 2-D ``(block, cls)`` mesh.

    Records a per-dataset minsup-ladder *trajectory* — wall clock,
    ``word_ops``/``word_ops_full``, ``device_calls`` and the per-host
    peak device words of the slab — into ``BENCH_full.json`` (schema in
    benchmarks/README.md) next to the smoke baseline.  Counters are
    deterministic integer math over seeded streams; wall times are
    informational (check_bench_regression.py gates only the counters).

    ``scale`` multiplies every replica's transaction count (CI runs
    ``--full --scale 0.1`` on one CPU device so the path cannot rot
    between hardware runs); minsups are relative, so the mined regime
    is scale-invariant.  The packing happens once per dataset at the
    smallest ladder rung; each rung mines the rows still frequent at
    its own threshold (the BitmapDB row order is support-ascending, so
    that is a suffix slice — no repacking).
    """
    import jax
    import numpy as np

    from repro.core.bitmap import BitmapDB
    from repro.core.distributed import DistributedMiner
    from repro.data.transactions import PAPER_REPLICAS, stream_paper_dataset
    from repro.launch.mesh import make_mining_mesh

    names = datasets or list(PAPER_REPLICAS)
    mesh = make_mining_mesh(block=block, cls=cls)
    report: Dict = {
        "tier": "full",
        "scale": scale,
        "seed": seed,
        "mesh": {"block": int(mesh.shape["block"]),
                 "cls": int(mesh.shape["cls"]),
                 "devices": jax.device_count(),
                 "hosts": jax.process_count()},
        "datasets": {},
    }
    hosts = max(1, jax.process_count())
    for name in names:
        t0 = time.perf_counter()
        bdb, minsups = stream_paper_dataset(name, scale=scale, seed=seed)
        pack_s = time.perf_counter() - t0
        miner = DistributedMiner(mesh, scheme="eclat", early_stop=True,
                                 inflight=2, autotune_chunk=True)
        traj = []
        # Largest rung first: coarse runs are cheap and fail fast.
        for ms in sorted(minsups, reverse=True):
            keep = np.flatnonzero(bdb.supports >= ms)
            sub = BitmapDB(items=[bdb.items[i] for i in keep],
                           bitmaps=bdb.bitmaps[keep],
                           supports=bdb.supports[keep],
                           n_trans=bdb.n_trans, minsup=ms,
                           block_words=bdb.block_words)
            t0 = time.perf_counter()
            out, st = miner.mine_packed(sub, ms)
            wall = time.perf_counter() - t0
            traj.append({
                "minsup": int(ms),
                "wall_s": round(wall, 3),
                "word_ops": st.word_ops,
                "word_ops_full": st.word_ops_full,
                "word_ops_saved_frac": round(st.word_ops_saved_frac, 4),
                "device_calls": st.device_calls,
                "peak_device_words_per_host":
                    -(-st.peak_device_words // hosts),
                "frequent_itemsets": len(out),
            })
            print(f"full {name} minsup={ms}: F={len(out)} "
                  f"wall={wall:.2f}s word_ops={st.word_ops} "
                  f"calls={st.device_calls} "
                  f"peak_words/host={traj[-1]['peak_device_words_per_host']}",
                  file=sys.stderr)
        report["datasets"][name] = {
            "dataset": {"n_trans": bdb.n_trans, "n_items_frequent":
                        bdb.n_items, "n_blocks": bdb.n_blocks,
                        "block_words": bdb.block_words,
                        "pack_s": round(pack_s, 3)},
            "trajectory": traj,
        }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"full tier ok -> {out_path}", file=sys.stderr)
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic dataset; assert ES word-op "
                         "savings and write a BENCH_*.json artifact")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale replica tier on the 2-D mining "
                         "mesh; writes a BENCH_full.json trajectory")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="full tier: transaction-count multiplier "
                         "(CI uses 0.1)")
    ap.add_argument("--datasets", nargs="*", default=None,
                    help="full tier: subset of paper replicas to run")
    ap.add_argument("--mesh-block", type=int, default=None,
                    help="full tier: block-axis size (default: all "
                         "devices / cls)")
    ap.add_argument("--mesh-cls", type=int, default=1,
                    help="full tier: cls-axis size")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default BENCH_smoke.json / "
                         "BENCH_full.json)")
    args = ap.parse_args()
    if args.smoke:
        run_smoke(args.out or "BENCH_smoke.json")
        return
    if args.full:
        run_full(args.out or "BENCH_full.json", scale=args.scale,
                 datasets=args.datasets, block=args.mesh_block,
                 cls=args.mesh_cls)
        return
    print("full paper sweep lives in benchmarks/run.py "
          "(python -m benchmarks.run --sections paper); "
          "use --smoke for the CI smoke bench or --full for the "
          "paper-scale tier", file=sys.stderr)
    sys.exit(2)


def csv_rows(rows: List[Dict]) -> List[str]:
    """name,us_per_call,derived lines for benchmarks.run."""
    out = []
    for r in rows:
        for scheme, v in r["schemes"].items():
            us = v["runtime_s"] * 1e6
            out.append(
                f"paper/{r['dataset']}/ms{r['minsup_level']}/{scheme},"
                f"{us:.0f},comparisons={v['comparisons']};F={v['F']}")
    return out


if __name__ == "__main__":
    main()
