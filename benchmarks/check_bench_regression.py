"""Diff a smoke-bench BENCH_*.json against the committed baseline.

CI runs ``bench_paper.py --smoke`` on every commit and then this script;
a regression vs ``benchmarks/baselines/BENCH_smoke.json`` fails the
build (ROADMAP "CI trajectory" item).  Per smoke dataset:

* bitmap engine: ``word_ops`` (small tolerance), ``device_calls`` and
  ``word_ops_saved_frac`` must not regress;
* PrePost+ engine: ``comparisons`` must not increase (they are pinned
  to the oracle's exact counters — invariant I4 — so any increase is an
  engine bug, not noise) and ``device_calls`` must not increase;
* allocator memory: ``peak_rows`` (bitmap) and ``peak_codes``
  (PrePost+) must not regress beyond ``--peak-tol`` (default 10% — the
  build fails if the frontier/compaction layer starts holding
  meaningfully more live mass than the committed baseline);
* scatter traffic (ISSUE 5): ``scatter_words`` — the device words
  written by child materialization — must not regress beyond
  ``--peak-tol`` for either engine; survivor-only scatter makes this a
  deterministic function of the frequent children, so an increase
  means dead candidates started being materialised again;
* density-adaptive engine (ISSUE 6): the ``adaptive`` runs' ``word_ops``
  (small tolerance), ``device_calls``, ``peak_rows`` and
  ``scatter_words`` (``--peak-tol``) must not regress either, and the
  dense regime's adaptive ES ``word_ops`` must stay strictly below its
  recorded same-granularity tidset reference (``tidset_es_word_ops``) —
  losing that gap means the representation switch stopped paying for
  itself.

The artifact's ``pipeline`` and ``autotune`` sections (ISSUE 7) and the
per-run ``wall_s`` / ``assemble_s`` / ``resolve_s`` fields are
*informational* and deliberately ignored here: they capture wall-clock
and overlap behaviour, which varies with host load, so gating on them
would make CI flaky.  Their acceptance checks (occupancy > serial,
autotune cuts device_calls at equal work) run inside ``bench_paper.py``
itself, where the comparison is within a single process on one host.

All gated metrics are deterministic functions of the engines (integer
math over seeded synthetic datasets).  A legitimate engine change that
shifts them should update the committed baseline in the same PR:

    python benchmarks/bench_paper.py --smoke \
        --out benchmarks/baselines/BENCH_smoke.json
"""

from __future__ import annotations

import argparse
import json
import sys

RUNS = ("es", "full")


def compare_dataset(name: str, current: dict, baseline: dict,
                    word_ops_tol: float, peak_tol: float) -> list:
    failures = []
    for run in RUNS:
        cur, base = current[run], baseline[run]
        if cur["device_calls"] > base["device_calls"]:
            failures.append(
                f"{name}/{run}: device_calls regressed "
                f"{base['device_calls']} -> {cur['device_calls']}")
        limit = base["word_ops"] * (1.0 + word_ops_tol)
        if cur["word_ops"] > limit:
            failures.append(
                f"{name}/{run}: word_ops regressed {base['word_ops']} -> "
                f"{cur['word_ops']} (limit {limit:.0f})")
        peak_limit = base["peak_rows"] * (1.0 + peak_tol)
        if cur["peak_rows"] > peak_limit:
            failures.append(
                f"{name}/{run}: peak_rows regressed {base['peak_rows']} "
                f"-> {cur['peak_rows']} (limit {peak_limit:.0f})")
        scatter_limit = base["scatter_words"] * (1.0 + peak_tol)
        if cur["scatter_words"] > scatter_limit:
            failures.append(
                f"{name}/{run}: scatter_words regressed "
                f"{base['scatter_words']} -> {cur['scatter_words']} "
                f"(limit {scatter_limit:.0f})")
        pcur, pbase = current["prepost"][run], baseline["prepost"][run]
        if pcur["comparisons"] > pbase["comparisons"]:
            failures.append(
                f"{name}/{run}: prepost comparisons regressed "
                f"{pbase['comparisons']} -> {pcur['comparisons']}")
        if pcur["device_calls"] > pbase["device_calls"]:
            failures.append(
                f"{name}/{run}: prepost device_calls regressed "
                f"{pbase['device_calls']} -> {pcur['device_calls']}")
        peak_limit = pbase["peak_codes"] * (1.0 + peak_tol)
        if pcur["peak_codes"] > peak_limit:
            failures.append(
                f"{name}/{run}: prepost peak_codes regressed "
                f"{pbase['peak_codes']} -> {pcur['peak_codes']} "
                f"(limit {peak_limit:.0f})")
        scatter_limit = pbase["scatter_words"] * (1.0 + peak_tol)
        if pcur["scatter_words"] > scatter_limit:
            failures.append(
                f"{name}/{run}: prepost scatter_words regressed "
                f"{pbase['scatter_words']} -> {pcur['scatter_words']} "
                f"(limit {scatter_limit:.0f})")
    for run in RUNS:
        acur, abase = current["adaptive"][run], baseline["adaptive"][run]
        if acur["device_calls"] > abase["device_calls"]:
            failures.append(
                f"{name}/adaptive/{run}: device_calls regressed "
                f"{abase['device_calls']} -> {acur['device_calls']}")
        limit = abase["word_ops"] * (1.0 + word_ops_tol)
        if acur["word_ops"] > limit:
            failures.append(
                f"{name}/adaptive/{run}: word_ops regressed "
                f"{abase['word_ops']} -> {acur['word_ops']} "
                f"(limit {limit:.0f})")
        peak_limit = abase["peak_rows"] * (1.0 + peak_tol)
        if acur["peak_rows"] > peak_limit:
            failures.append(
                f"{name}/adaptive/{run}: peak_rows regressed "
                f"{abase['peak_rows']} -> {acur['peak_rows']} "
                f"(limit {peak_limit:.0f})")
        scatter_limit = abase["scatter_words"] * (1.0 + peak_tol)
        if acur["scatter_words"] > scatter_limit:
            failures.append(
                f"{name}/adaptive/{run}: scatter_words regressed "
                f"{abase['scatter_words']} -> {acur['scatter_words']} "
                f"(limit {scatter_limit:.0f})")
    if name == "dense":
        acur = current["adaptive"]
        if acur["es"]["word_ops"] >= acur["tidset_es_word_ops"]:
            failures.append(
                f"{name}: adaptive ES word_ops "
                f"{acur['es']['word_ops']} no longer below the "
                f"same-granularity tidset reference "
                f"{acur['tidset_es_word_ops']}")
    cur_saved = current["word_ops_saved_frac"]
    base_saved = baseline["word_ops_saved_frac"]
    if cur_saved < base_saved - word_ops_tol:
        failures.append(
            f"{name}: word_ops_saved_frac regressed {base_saved:.4f} -> "
            f"{cur_saved:.4f}")
    return failures


def compare(current: dict, baseline: dict, word_ops_tol: float,
            peak_tol: float) -> list:
    failures = []
    for name, base_ds in baseline["datasets"].items():
        cur_ds = current["datasets"].get(name)
        if cur_ds is None:
            failures.append(f"{name}: dataset missing from current run")
            continue
        failures.extend(
            compare_dataset(name, cur_ds, base_ds, word_ops_tol, peak_tol))
    return failures


def compare_full(current: dict, baseline: dict, word_ops_tol: float,
                 peak_tol: float) -> list:
    """Full-tier (ISSUE 9) baseline shape: per dataset, per minsup rung
    of the trajectory — ``frequent_itemsets`` must match EXACTLY (the
    streams are seeded and the counters integer math, so any drift is a
    correctness bug, not noise), ``word_ops`` within tolerance,
    ``device_calls`` and ``word_ops_saved_frac`` must not regress, and
    ``peak_device_words_per_host`` within ``--peak-tol``.  ``wall_s`` /
    ``pack_s`` are informational, same policy as the smoke tier."""
    failures = []
    if current.get("scale") != baseline.get("scale"):
        failures.append(f"full: scale mismatch {baseline.get('scale')} "
                        f"vs {current.get('scale')} — not comparable")
        return failures
    for name, base_ds in baseline["datasets"].items():
        cur_ds = current["datasets"].get(name)
        if cur_ds is None:
            failures.append(f"{name}: dataset missing from current run")
            continue
        base_traj = {r["minsup"]: r for r in base_ds["trajectory"]}
        cur_traj = {r["minsup"]: r for r in cur_ds["trajectory"]}
        for ms, base_r in base_traj.items():
            cur_r = cur_traj.get(ms)
            if cur_r is None:
                failures.append(f"{name}@{ms}: rung missing from current run")
                continue
            tag = f"{name}@{ms}"
            if cur_r["frequent_itemsets"] != base_r["frequent_itemsets"]:
                failures.append(
                    f"{tag}: frequent_itemsets changed "
                    f"{base_r['frequent_itemsets']} -> "
                    f"{cur_r['frequent_itemsets']}")
            if cur_r["device_calls"] > base_r["device_calls"]:
                failures.append(
                    f"{tag}: device_calls regressed "
                    f"{base_r['device_calls']} -> {cur_r['device_calls']}")
            limit = base_r["word_ops"] * (1.0 + word_ops_tol)
            if cur_r["word_ops"] > limit:
                failures.append(
                    f"{tag}: word_ops regressed {base_r['word_ops']} -> "
                    f"{cur_r['word_ops']} (limit {limit:.0f})")
            if (cur_r["word_ops_saved_frac"]
                    < base_r["word_ops_saved_frac"] - word_ops_tol):
                failures.append(
                    f"{tag}: word_ops_saved_frac regressed "
                    f"{base_r['word_ops_saved_frac']:.4f} -> "
                    f"{cur_r['word_ops_saved_frac']:.4f}")
            peak_limit = (base_r["peak_device_words_per_host"]
                          * (1.0 + peak_tol))
            if cur_r["peak_device_words_per_host"] > peak_limit:
                failures.append(
                    f"{tag}: peak_device_words_per_host regressed "
                    f"{base_r['peak_device_words_per_host']} -> "
                    f"{cur_r['peak_device_words_per_host']} "
                    f"(limit {peak_limit:.0f})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="BENCH_*.json from this run")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--word-ops-tol", type=float, default=0.02,
                    help="allowed fractional word_ops increase (default 2%%)")
    ap.add_argument("--peak-tol", type=float, default=0.10,
                    help="allowed fractional peak_rows / peak_codes "
                         "increase (default 10%%)")
    args = ap.parse_args()
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    if current.get("tier") == "full" or baseline.get("tier") == "full":
        if current.get("tier") != baseline.get("tier"):
            print("BENCH REGRESSION:\n  tier mismatch: current "
                  f"{current.get('tier')!r} vs baseline "
                  f"{baseline.get('tier')!r}", file=sys.stderr)
            sys.exit(1)
        failures = compare_full(current, baseline, args.word_ops_tol,
                                args.peak_tol)
        for name, base_ds in baseline["datasets"].items():
            cur_ds = current["datasets"].get(name)
            if cur_ds is None:
                continue
            cur_traj = {r["minsup"]: r for r in cur_ds["trajectory"]}
            for base_r in base_ds["trajectory"]:
                cur_r = cur_traj.get(base_r["minsup"])
                if cur_r is None:
                    continue
                print(f"{name}@{base_r['minsup']}: F "
                      f"{base_r['frequent_itemsets']} -> "
                      f"{cur_r['frequent_itemsets']}, word_ops "
                      f"{base_r['word_ops']} -> {cur_r['word_ops']}, "
                      f"calls {base_r['device_calls']} -> "
                      f"{cur_r['device_calls']}, peak_words/host "
                      f"{base_r['peak_device_words_per_host']} -> "
                      f"{cur_r['peak_device_words_per_host']}, wall "
                      f"{base_r['wall_s']} -> {cur_r['wall_s']}s",
                      file=sys.stderr)
        if failures:
            print("BENCH REGRESSION:\n  " + "\n  ".join(failures),
                  file=sys.stderr)
            sys.exit(1)
        print("full-tier bench diff ok (no frequent_itemsets/word_ops/"
              "device_calls/peak_device_words regression)", file=sys.stderr)
        return

    failures = compare(current, baseline, args.word_ops_tol, args.peak_tol)
    for name, base_ds in baseline["datasets"].items():
        cur_ds = current["datasets"].get(name)
        if cur_ds is None:
            continue
        for run in RUNS:
            print(f"{name}/{run}: word_ops "
                  f"{base_ds[run]['word_ops']} -> "
                  f"{cur_ds[run]['word_ops']}, peak_rows "
                  f"{base_ds[run]['peak_rows']} -> "
                  f"{cur_ds[run]['peak_rows']}, scatter_words "
                  f"{base_ds[run]['scatter_words']} -> "
                  f"{cur_ds[run]['scatter_words']}, prepost comparisons "
                  f"{base_ds['prepost'][run]['comparisons']} -> "
                  f"{cur_ds['prepost'][run]['comparisons']}, peak_codes "
                  f"{base_ds['prepost'][run]['peak_codes']} -> "
                  f"{cur_ds['prepost'][run]['peak_codes']}, "
                  f"prepost scatter_words "
                  f"{base_ds['prepost'][run]['scatter_words']} -> "
                  f"{cur_ds['prepost'][run]['scatter_words']}, "
                  f"adaptive word_ops "
                  f"{base_ds['adaptive'][run]['word_ops']} -> "
                  f"{cur_ds['adaptive'][run]['word_ops']}",
                  file=sys.stderr)
    if failures:
        print("BENCH REGRESSION:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        sys.exit(1)
    print("bench diff ok (no word_ops/device_calls/comparisons/"
          "peak_rows/peak_codes/scatter_words regression)", file=sys.stderr)


if __name__ == "__main__":
    main()
