"""Diff a smoke-bench BENCH_*.json against the committed baseline.

CI runs ``bench_paper.py --smoke`` on every commit and then this script;
a ``word_ops`` or ``device_calls`` regression vs
``benchmarks/baselines/BENCH_smoke.json`` fails the build (ROADMAP "CI
trajectory" item).  Both metrics are deterministic functions of the
engine (integer popcount math over a seeded synthetic dataset), so the
default tolerance for ``word_ops`` is a small guard against counting
tweaks and ``device_calls`` must not increase at all.

A legitimate engine change that shifts the metrics should update the
committed baseline in the same PR:

    python benchmarks/bench_paper.py --smoke \
        --out benchmarks/baselines/BENCH_smoke.json
"""

from __future__ import annotations

import argparse
import json
import sys

RUNS = ("es", "full")


def compare(current: dict, baseline: dict, word_ops_tol: float) -> list:
    failures = []
    for run in RUNS:
        cur, base = current[run], baseline[run]
        if cur["device_calls"] > base["device_calls"]:
            failures.append(
                f"{run}: device_calls regressed "
                f"{base['device_calls']} -> {cur['device_calls']}")
        limit = base["word_ops"] * (1.0 + word_ops_tol)
        if cur["word_ops"] > limit:
            failures.append(
                f"{run}: word_ops regressed {base['word_ops']} -> "
                f"{cur['word_ops']} (limit {limit:.0f})")
    cur_saved = current["word_ops_saved_frac"]
    base_saved = baseline["word_ops_saved_frac"]
    if cur_saved < base_saved - word_ops_tol:
        failures.append(
            f"word_ops_saved_frac regressed {base_saved:.4f} -> "
            f"{cur_saved:.4f}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="BENCH_*.json from this run")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--word-ops-tol", type=float, default=0.02,
                    help="allowed fractional word_ops increase (default 2%%)")
    args = ap.parse_args()
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = compare(current, baseline, args.word_ops_tol)
    for run in RUNS:
        cur, base = current[run], baseline[run]
        print(f"{run}: word_ops {base['word_ops']} -> {cur['word_ops']}, "
              f"device_calls {base['device_calls']} -> "
              f"{cur['device_calls']}", file=sys.stderr)
    if failures:
        print("BENCH REGRESSION:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        sys.exit(1)
    print("bench diff ok (no word_ops/device_calls regression)",
          file=sys.stderr)


if __name__ == "__main__":
    main()
