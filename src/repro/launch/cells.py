"""Cell builders: (arch x shape) -> a lowerable SPMD program.

``build_cell(arch_id, shape_id, mesh)`` returns everything the dry-run /
roofline harness needs:

  * ``step_fn``       pure function over abstract args,
  * ``abstract_args`` pytrees of ShapeDtypeStruct (weak-type-correct, no
                      allocation),
  * ``in_shardings``  NamedShardings resolved through the logical rules
                      (arch overrides + shape overrides applied),
  * bookkeeping for the roofline (model param counts, family, kind).

Training cells lower the FULL train_step (fwd + bwd + optimizer update);
decode cells lower serve_step; the FIM cells lower one distributed
mining round (shard_map).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch, get_shape, ArchSpec, ShapeDef
from repro.distributed.sharding import (
    use_rules, logical_spec, make_param_shardings, active_mesh)
from repro.train.optimizer import (
    OptConfig, opt_init, opt_state_logical)
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class BuiltCell:
    arch_id: str
    shape_id: str
    kind: str
    step_fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]
    rules: Dict[str, Any]
    model_params: int = 0
    active_params: int = 0
    skip_reason: Optional[str] = None
    notes: str = ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _shard_tree(mesh: Mesh, logical_tree):
    return make_param_shardings(mesh, logical_tree)


def _leaf_is_axes(x):
    return isinstance(x, tuple) and all(
        n is None or isinstance(n, str) for n in x)


def _opt_cfg_for(arch_id: str) -> OptConfig:
    # Adafactor for the >=100B models (moment memory), AdamW elsewhere.
    if arch_id in ("command-r-plus-104b", "deepseek-v2-236b",
                   "mixtral-8x22b"):
        return OptConfig(kind="adafactor", lr=1e-4)
    return OptConfig(kind="adamw", lr=3e-4)


# ---------------------------------------------------------------------------
# family builders
# ---------------------------------------------------------------------------

def _build_lm(spec: ArchSpec, shape: ShapeDef, mesh: Mesh,
              rules: Dict[str, Any]) -> BuiltCell:
    from repro.models import transformer as T

    cfg = spec.config_fn(shape.shape_id)
    params_a, logical = _abstract_init(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = _shard_tree(mesh, logical)
    n_params = _count(params_a)
    n_active = _active_count(cfg, n_params)
    dims = shape.dims

    if shape.kind == "train":
        opt_cfg = _opt_cfg_for(spec.arch_id)
        opt_a, opt_logical = _abstract_opt(params_a, logical, opt_cfg)
        o_sh = _shard_tree(mesh, opt_logical)
        B, S = dims["global_batch"], dims["seq"]
        batch_a = {"tokens": _sds((B, S), "int32"),
                   "labels": _sds((B, S), "int32")}
        b_sh = {"tokens": NamedSharding(mesh, logical_spec(("batch", None), mesh)),
                "labels": NamedSharding(mesh, logical_spec(("batch", None), mesh))}

        def loss_fn(p, b):
            return T.loss_fn(p, cfg, b["tokens"], b["labels"])

        step = make_train_step(loss_fn, opt_cfg,
                               n_microbatches=dims["n_microbatches"])
        return BuiltCell(spec.arch_id, shape.shape_id, shape.kind, step,
                         (params_a, opt_a, batch_a), (p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1), rules=rules,
                         model_params=n_params, active_params=n_active)

    if shape.kind == "prefill":
        B, S = dims["batch"], dims["seq"]
        tokens_a = _sds((B, S), "int32")
        t_sh = NamedSharding(mesh, logical_spec(("batch", None), mesh))

        def step(p, tokens):
            return T.prefill(p, cfg, tokens)

        return BuiltCell(spec.arch_id, shape.shape_id, shape.kind, step,
                         (params_a, tokens_a), (p_sh, t_sh),
                         donate_argnums=(), rules=rules,
                         model_params=n_params, active_params=n_active)

    if shape.kind == "decode":
        B, KV = dims["batch"], dims["kv_len"]
        cache_a = jax.eval_shape(
            functools.partial(T.init_cache, cfg, B, KV))
        c_logical = T.cache_logical(cfg)
        c_logical = {k: (c_logical[k] if k != "len" else ("batch",))
                     for k in cache_a}
        c_sh = jax.tree.map(
            lambda names: NamedSharding(mesh, logical_spec(names, mesh)),
            c_logical, is_leaf=_leaf_is_axes)
        token_a = _sds((B,), "int32")
        tok_sh = NamedSharding(mesh, logical_spec(("batch",), mesh))

        def step(p, token, cache):
            return T.decode_step(p, cfg, token, cache)

        return BuiltCell(spec.arch_id, shape.shape_id, shape.kind, step,
                         (params_a, token_a, cache_a),
                         (p_sh, tok_sh, c_sh),
                         donate_argnums=(2,), rules=rules,
                         model_params=n_params, active_params=n_active)

    raise ValueError(shape.kind)


def _build_gnn(spec: ArchSpec, shape: ShapeDef, mesh: Mesh,
               rules: Dict[str, Any]) -> BuiltCell:
    from repro.models import gnn as G

    cfg = spec.config_fn(shape.shape_id)
    params_a, logical = _abstract_init(
        lambda: G.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = _shard_tree(mesh, logical)
    n_params = _count(params_a)
    opt_cfg = _opt_cfg_for(spec.arch_id)
    opt_a, opt_logical = _abstract_opt(params_a, logical, opt_cfg)
    o_sh = _shard_tree(mesh, opt_logical)
    d = shape.dims

    if shape.kind == "train_full":
        N, E, F = d["n_nodes"], d["n_edges"], d["d_feat"]
        batch_a = {
            "x": _sds((N, F), "float32"),
            "edge_src": _sds((E,), "int32"),
            "edge_dst": _sds((E,), "int32"),
            "labels": _sds((N,), "int32"),
            "mask": _sds((N,), "bool"),
        }
        b_log = {"x": ("nodes", "feat"), "edge_src": ("edges",),
                 "edge_dst": ("edges",), "labels": ("nodes",),
                 "mask": ("nodes",)}

        def loss_fn(p, b):
            return G.loss_full(p, cfg, b["x"], b["edge_src"],
                               b["edge_dst"], b["labels"], b["mask"])

    elif shape.kind == "train_sampled":
        B, (f1, f2), F = d["batch_nodes"], d["fanouts"], d["d_feat"]
        batch_a = {
            "x_root": _sds((B, F), "float32"),
            "x_h1": _sds((B, f1, F), "float32"),
            "x_h2": _sds((B, f1, f2, F), "float32"),
            "m1": _sds((B, f1), "bool"),
            "m2": _sds((B, f1, f2), "bool"),
            "labels": _sds((B,), "int32"),
        }
        b_log = {"x_root": ("nodes", "feat"),
                 "x_h1": ("nodes", None, "feat"),
                 "x_h2": ("nodes", None, None, "feat"),
                 "m1": ("nodes", None), "m2": ("nodes", None, None),
                 "labels": ("nodes",)}

        def loss_fn(p, b):
            return G.loss_sampled(p, cfg, (b["x_root"], b["x_h1"], b["x_h2"]),
                                  (b["m1"], b["m2"]), b["labels"])

    else:
        raise ValueError(shape.kind)

    b_sh = jax.tree.map(
        lambda names: NamedSharding(mesh, logical_spec(names, mesh)),
        b_log, is_leaf=_leaf_is_axes)
    step = make_train_step(loss_fn, opt_cfg, n_microbatches=1)
    return BuiltCell(spec.arch_id, shape.shape_id, shape.kind, step,
                     (params_a, opt_a, batch_a), (p_sh, o_sh, b_sh),
                     donate_argnums=(0, 1), rules=rules,
                     model_params=n_params, active_params=n_params)


def _build_recsys(spec: ArchSpec, shape: ShapeDef, mesh: Mesh,
                  rules: Dict[str, Any]) -> BuiltCell:
    from repro.models import recsys as R

    cfg = spec.config_fn(shape.shape_id)
    arch = spec.arch_id
    d = shape.dims
    init_map = {
        "sasrec": R.sasrec_init, "din": R.din_init,
        "xdeepfm": R.xdeepfm_init, "two-tower-retrieval": R.twotower_init,
    }
    params_a, logical = _abstract_init(
        lambda: init_map[arch](jax.random.PRNGKey(0), cfg))
    p_sh = _shard_tree(mesh, logical)
    n_params = _count(params_a)

    def named(names):
        return NamedSharding(mesh, logical_spec(names, mesh))

    if shape.kind == "train":
        B = d["batch"]
        opt_cfg = _opt_cfg_for(arch)
        opt_a, opt_logical = _abstract_opt(params_a, logical, opt_cfg)
        o_sh = _shard_tree(mesh, opt_logical)
        if arch == "sasrec":
            batch_a = {"seq_ids": _sds((B, cfg.seq_len), "int32"),
                       "pos_ids": _sds((B, cfg.seq_len), "int32"),
                       "neg_ids": _sds((B, cfg.seq_len, cfg.n_negatives),
                                       "int32")}
            b_log = {"seq_ids": ("batch", None), "pos_ids": ("batch", None),
                     "neg_ids": ("batch", None, None)}
            loss_fn = lambda p, b: R.sasrec_loss(  # noqa: E731
                p, cfg, b["seq_ids"], b["pos_ids"], b["neg_ids"])
        elif arch == "din":
            batch_a = {"hist_ids": _sds((B, cfg.seq_len), "int32"),
                       "target_id": _sds((B,), "int32"),
                       "ctx_ids": _sds((B, cfg.n_context_fields), "int32"),
                       "labels": _sds((B,), "float32")}
            b_log = {"hist_ids": ("batch", None), "target_id": ("batch",),
                     "ctx_ids": ("batch", None), "labels": ("batch",)}
            loss_fn = lambda p, b: R.din_loss(  # noqa: E731
                p, cfg, b["hist_ids"], b["target_id"], b["ctx_ids"],
                b["labels"])
        elif arch == "xdeepfm":
            batch_a = {"field_ids": _sds((B, cfg.n_fields), "int32"),
                       "labels": _sds((B,), "float32")}
            b_log = {"field_ids": ("batch", None), "labels": ("batch",)}
            loss_fn = lambda p, b: R.xdeepfm_loss(  # noqa: E731
                p, cfg, b["field_ids"], b["labels"])
        else:
            batch_a = {"user_id": _sds((B,), "int32"),
                       "hist_ids": _sds((B, cfg.n_user_hist), "int32"),
                       "hist_mask": _sds((B, cfg.n_user_hist), "bool"),
                       "pos_item": _sds((B,), "int32"),
                       "item_logq": _sds((B,), "float32")}
            b_log = {k: ("batch",) + (None,) * (len(v.shape) - 1)
                     for k, v in batch_a.items()}
            loss_fn = lambda p, b: R.twotower_loss(  # noqa: E731
                p, cfg, b["user_id"], b["hist_ids"], b["hist_mask"],
                b["pos_item"], b["item_logq"])
        b_sh = jax.tree.map(named, b_log, is_leaf=_leaf_is_axes)
        step = make_train_step(loss_fn, opt_cfg,
                               n_microbatches=d.get("n_microbatches", 1))
        return BuiltCell(arch, shape.shape_id, shape.kind, step,
                         (params_a, opt_a, batch_a), (p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1), rules=rules,
                         model_params=n_params, active_params=n_params)

    if shape.kind == "serve":
        B = d["batch"]
        if arch == "sasrec":
            batch_a = {"seq_ids": _sds((B, cfg.seq_len), "int32"),
                       "cand": _sds((B, 200), "int32")}
            b_log = {"seq_ids": ("batch", None), "cand": ("batch", None)}
            step = lambda p, b: R.sasrec_score(  # noqa: E731
                p, cfg, b["seq_ids"], b["cand"])
        elif arch == "din":
            batch_a = {"hist_ids": _sds((B, cfg.seq_len), "int32"),
                       "target_id": _sds((B,), "int32"),
                       "ctx_ids": _sds((B, cfg.n_context_fields), "int32")}
            b_log = {"hist_ids": ("batch", None), "target_id": ("batch",),
                     "ctx_ids": ("batch", None)}
            step = lambda p, b: R.din_forward(  # noqa: E731
                p, cfg, b["hist_ids"], b["target_id"], b["ctx_ids"])
        elif arch == "xdeepfm":
            batch_a = {"field_ids": _sds((B, cfg.n_fields), "int32")}
            b_log = {"field_ids": ("batch", None)}
            step = lambda p, b: R.xdeepfm_forward(  # noqa: E731
                p, cfg, b["field_ids"])
        else:
            batch_a = {"user_id": _sds((B,), "int32"),
                       "hist_ids": _sds((B, cfg.n_user_hist), "int32"),
                       "hist_mask": _sds((B, cfg.n_user_hist), "bool"),
                       "item_id": _sds((B,), "int32")}
            b_log = {k: ("batch",) + (None,) * (len(v.shape) - 1)
                     for k, v in batch_a.items()}

            def step(p, b):
                u = R.user_embed(p, cfg, b["user_id"], b["hist_ids"],
                                 b["hist_mask"])
                it = R.item_embed(p, cfg, b["item_id"])
                return (u * it).sum(-1)
        b_sh = jax.tree.map(named, b_log, is_leaf=_leaf_is_axes)
        return BuiltCell(arch, shape.shape_id, shape.kind, step,
                         (params_a, batch_a), (p_sh, b_sh),
                         donate_argnums=(), rules=rules,
                         model_params=n_params, active_params=n_params)

    if shape.kind == "retrieval":
        C = d["n_candidates"]
        if arch == "sasrec":
            batch_a = {"seq_ids": _sds((1, cfg.seq_len), "int32")}
            b_log = {"seq_ids": (None, None)}
            step = lambda p, b: jax.lax.top_k(  # noqa: E731
                R.sasrec_score(p, cfg, b["seq_ids"]), 100)
        elif arch == "din":
            batch_a = {"hist_ids": _sds((1, cfg.seq_len), "int32"),
                       "ctx_ids": _sds((1, cfg.n_context_fields), "int32"),
                       "cand": _sds((C,), "int32")}
            b_log = {"hist_ids": (None, None), "ctx_ids": (None, None),
                     "cand": ("candidates",)}
            step = lambda p, b: jax.lax.top_k(  # noqa: E731
                R.din_score_candidates(p, cfg, b["hist_ids"], b["ctx_ids"],
                                       b["cand"]), 100)
        elif arch == "xdeepfm":
            batch_a = {"field_ids": _sds((C, cfg.n_fields), "int32")}
            b_log = {"field_ids": ("candidates", None)}
            step = lambda p, b: jax.lax.top_k(  # noqa: E731
                R.xdeepfm_forward(p, cfg, b["field_ids"]), 100)
        else:
            batch_a = {"user_id": _sds((1,), "int32"),
                       "hist_ids": _sds((1, cfg.n_user_hist), "int32"),
                       "hist_mask": _sds((1, cfg.n_user_hist), "bool"),
                       "cand": _sds((C,), "int32")}
            b_log = {"user_id": (None,), "hist_ids": (None, None),
                     "hist_mask": (None, None), "cand": ("candidates",)}
            step = lambda p, b: R.retrieval_scores(  # noqa: E731
                p, cfg, b["user_id"], b["hist_ids"], b["hist_mask"],
                b["cand"], topk=100)
        b_sh = jax.tree.map(named, b_log, is_leaf=_leaf_is_axes)
        return BuiltCell(arch, shape.shape_id, shape.kind, step,
                         (params_a, batch_a), (p_sh, b_sh),
                         donate_argnums=(), rules=rules,
                         model_params=n_params, active_params=n_params)

    raise ValueError(shape.kind)


def _build_fim(spec: ArchSpec, shape: ShapeDef, mesh: Mesh,
               rules: Dict[str, Any]) -> BuiltCell:
    from repro.core.distributed import make_mining_round

    d = shape.dims
    round_fn = make_mining_round(mesh)
    store_a = _sds((d["store_rows"], d["n_blocks"], d["block_words"]),
                   "uint32")
    pairs_a = _sds((d["pairs"], 2), "int32")
    rho_a = _sds((d["pairs"],), "int32")
    all_axes = tuple(mesh.axis_names)
    tid_spec = all_axes if len(all_axes) > 1 else all_axes[0]
    shardings = (NamedSharding(mesh, P(None, tid_spec, None)),
                 NamedSharding(mesh, P(None, None)),
                 NamedSharding(mesh, P(None)))
    return BuiltCell(spec.arch_id, shape.shape_id, shape.kind, round_fn,
                     (store_a, pairs_a, rho_a), shardings,
                     donate_argnums=(), rules=rules,
                     model_params=0, active_params=0,
                     notes=f"{d['n_trans']:,} transactions")


# ---------------------------------------------------------------------------
# shared helpers + entry point
# ---------------------------------------------------------------------------

def _abstract_init(init_fn):
    """eval_shape the params WITHOUT allocating; the logical-axes tree is
    plain Python, so it is captured through a side channel while the init
    function is being traced (strings are not valid traced outputs)."""
    box = {}

    def wrapper():
        p, logical = init_fn()
        box["logical"] = logical
        return p

    params_a = jax.eval_shape(wrapper)
    return params_a, box["logical"]


def _abstract_opt(params_a, logical, opt_cfg: OptConfig):
    opt_a = jax.eval_shape(lambda: opt_init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_a),
        opt_cfg))
    return opt_a, opt_state_logical(logical, opt_cfg)


def _count(tree) -> int:
    return int(sum(_prod(x.shape) for x in jax.tree.leaves(tree)))


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def _active_count(cfg, total: int) -> int:
    if not getattr(cfg, "moe", False):
        return total
    f = cfg.moe_d_ff or cfg.d_ff
    n_moe_layers = cfg.n_layers - cfg.first_k_dense
    per_expert = 3 * cfg.d_model * f
    return total - n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert


_FAMILY_BUILDERS = {
    "lm": _build_lm,
    "gnn": _build_gnn,
    "recsys": _build_recsys,
    "fim": _build_fim,
}


# ---------------------------------------------------------------------------
# LM costing variants (roofline exactness)
# ---------------------------------------------------------------------------
#
# cost_analysis() counts a while-loop body exactly once, so the scanned
# full-depth program under-reports FLOPs/bytes/collectives by the trip
# counts.  Costs are therefore measured on small UNROLLED depths and
# reconstructed exactly (layers are identical, so per-layer cost is
# linear):
#
#   train:   total = opt_cost + n_mb * (base + b * L_full)
#            where (base, b) come from grad-only compiles at the real
#            microbatch size with L in {1, 2} (attention folded to one
#            chunk so its inner scan is trip-count-1), and opt_cost from
#            compiling the optimizer update alone;
#   serve:   total = base + b * L_full  from step compiles at L in {1,2}.
#
# DeepSeek's single leading dense layer is pinned (absorbed into base);
# only the MoE stack depth is extrapolated.

def build_lm_costing(arch_id: str, shape_id: str, mesh: Mesh,
                     n_layers: int,
                     cfg_overrides: Optional[Dict[str, Any]] = None,
                     dims_overrides: Optional[Dict[str, Any]] = None,
                     ) -> BuiltCell:
    """A grad-only (train) or step (serve) cell at reduced unrolled depth."""
    spec = get_arch(arch_id)
    shape = get_shape(spec, shape_id)
    if dims_overrides:
        shape = dataclasses.replace(
            shape, dims={**shape.dims, **dims_overrides})
    from repro.models import transformer as T

    cfg0 = spec.config_fn(shape_id)
    if cfg_overrides:
        cfg0 = dataclasses.replace(cfg0, **cfg_overrides)
    extra_dense = cfg0.first_k_dense if cfg0.moe else 0
    # unroll_layers also unrolls the attention chunk walk, so attn_chunk
    # is costed faithfully (a folded single chunk would hide carry traffic)
    cfg = dataclasses.replace(
        cfg0,
        n_layers=n_layers + extra_dense,
        first_k_dense=extra_dense,
        unroll_layers=True,
    )
    rules: Dict[str, Any] = dict(spec.rules_override)
    if shape.dims.get("batch") == 1:
        rules["batch"] = None
    if shape.kind == "decode":
        model_sz = mesh.shape.get("model", 1)
        if cfg0.mla or cfg0.n_kv_heads % model_sz != 0:
            rules["kv_seq"] = "model"
            rules["head_dim"] = "model"

    with use_rules(rules), active_mesh(mesh):
        params_a, logical = _abstract_init(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg))
        p_sh = _shard_tree(mesh, logical)
        dims = shape.dims
        if shape.kind == "train":
            B = dims["global_batch"] // dims["n_microbatches"]
            S = dims["seq"]
            batch_a = {"tokens": _sds((B, S), "int32"),
                       "labels": _sds((B, S), "int32")}
            b_sh = jax.tree.map(
                lambda _: NamedSharding(
                    mesh, logical_spec(("batch", None), mesh)), batch_a)

            def step(p, b):
                def lf(p_):
                    return T.loss_fn(p_, cfg, b["tokens"], b["labels"])[0]
                return jax.grad(lf)(p)

            args, shs = (params_a, batch_a), (p_sh, b_sh)
        elif shape.kind == "prefill":
            B, S = dims["batch"], dims["seq"]
            tokens_a = _sds((B, S), "int32")
            t_sh = NamedSharding(mesh, logical_spec(("batch", None), mesh))
            step = lambda p, t: T.prefill(p, cfg, t)  # noqa: E731
            args, shs = (params_a, tokens_a), (p_sh, t_sh)
        else:  # decode
            B, KV = dims["batch"], dims["kv_len"]
            cache_a = jax.eval_shape(
                functools.partial(T.init_cache, cfg, B, KV))
            c_logical = T.cache_logical(cfg)
            c_sh = jax.tree.map(
                lambda names: NamedSharding(mesh, logical_spec(names, mesh)),
                c_logical, is_leaf=_leaf_is_axes)
            token_a = _sds((B,), "int32")
            tok_sh = NamedSharding(mesh, logical_spec(("batch",), mesh))
            step = lambda p, t, c: T.decode_step(p, cfg, t, c)  # noqa: E731
            args, shs = (params_a, token_a, cache_a), (p_sh, tok_sh, c_sh)
        return BuiltCell(arch_id, shape_id, f"costing-{shape.kind}", step,
                         args, shs, donate_argnums=(), rules=rules)


def build_opt_costing(arch_id: str, shape_id: str, mesh: Mesh) -> BuiltCell:
    """The optimizer update alone, at full parameter shapes."""
    spec = get_arch(arch_id)
    from repro.models import transformer as T
    from repro.train.optimizer import opt_update

    cfg = spec.config_fn(shape_id)
    rules = dict(spec.rules_override)
    with use_rules(rules), active_mesh(mesh):
        params_a, logical = _abstract_init(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg))
        p_sh = _shard_tree(mesh, logical)
        opt_cfg = _opt_cfg_for(arch_id)
        opt_a, opt_logical = _abstract_opt(params_a, logical, opt_cfg)
        o_sh = _shard_tree(mesh, opt_logical)
        grads_a = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_a)
        g_sh = p_sh

        def step(p, g, s):
            return opt_update(p, g, s, opt_cfg)

        return BuiltCell(arch_id, shape_id, "costing-opt", step,
                         (params_a, grads_a, opt_a), (p_sh, g_sh, o_sh),
                         donate_argnums=(), rules=rules)


def build_fim_costing(arch_id: str, shape_id: str, mesh: Mesh,
                      n_chunks: int, pair_chunk: int = 2048) -> BuiltCell:
    """Reduced-pairs mining round for the cost fit (scan counted once)."""
    spec = get_arch(arch_id)
    shape = get_shape(spec, shape_id)
    shape = dataclasses.replace(
        shape, dims={**shape.dims, "pairs": n_chunks * pair_chunk})
    cell = _build_fim(spec, shape, mesh, dict(spec.rules_override))
    cell.kind = "costing-mine"
    return cell


def build_cell(arch_id: str, shape_id: str, mesh: Mesh,
               extra_rules: Optional[Dict[str, Any]] = None,
               cfg_overrides: Optional[Dict[str, Any]] = None,
               dims_overrides: Optional[Dict[str, Any]] = None) -> BuiltCell:
    """``cfg_overrides`` / ``dims_overrides`` / ``extra_rules`` are the
    hillclimb knobs: dataclasses.replace fields on the arch config, shape
    dim tweaks (e.g. n_microbatches), and sharding-rule swaps."""
    spec = get_arch(arch_id)
    shape = get_shape(spec, shape_id)
    if cfg_overrides:
        base_fn = spec.config_fn
        spec = dataclasses.replace(
            spec, config_fn=lambda s=None: dataclasses.replace(
                base_fn(s), **cfg_overrides))
    if dims_overrides:
        shape = dataclasses.replace(
            shape, dims={**shape.dims, **dims_overrides})

    skip = spec.skip_reason(shape_id)
    rules: Dict[str, Any] = dict(spec.rules_override)
    # batch=1 cells cannot shard the batch axis
    if shape.dims.get("batch") == 1 and shape.kind != "retrieval":
        rules["batch"] = None
    # Decode serving: when kv heads cannot cover the model axis (GQA kv=8
    # vs model=16, or MLA's single latent), shard the KV cache's SEQUENCE
    # axis over "model" instead — GSPMD then partitions the softmax like
    # flash-decoding (partial max/sum + tiny all-reduces).  head_dim takes
    # "model" for the kv projection weights so nothing big replicates.
    if spec.family == "lm" and shape.kind == "decode":
        cfg = spec.config_fn(shape_id)
        model_sz = mesh.shape.get("model", 1)
        if cfg.mla or cfg.n_kv_heads % model_sz != 0:
            rules["kv_seq"] = "model"
            rules["head_dim"] = "model"
    if extra_rules:
        rules.update(extra_rules)

    if skip:
        return BuiltCell(arch_id, shape_id, shape.kind, lambda: None,
                         (), (), (), rules, skip_reason=skip)

    with use_rules(rules), active_mesh(mesh):
        return _FAMILY_BUILDERS[spec.family](spec, shape, mesh, rules)


def lower_cell(cell: BuiltCell, mesh: Mesh):
    """jit + lower the cell on its mesh (no compile)."""
    with use_rules(cell.rules), active_mesh(mesh):
        jitted = jax.jit(cell.step_fn,
                         in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate_argnums)
        return jitted.lower(*cell.abstract_args)
