import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

Targets (chosen per the brief from the 40-cell baseline table):
  * deepseek-v2-236b x train_4k   — worst roofline fraction among trains
  * graphsage-reddit x ogb_products — most collective-bound cell
  * two-tower-retrieval x retrieval_cand — most representative of the
    paper's technique (early-stopping screened top-k)
plus the paper's own workload (fim-eclat x mine_1g) as the
paper-faithful-vs-optimised pair.

Each VARIANT is (hypothesis, knobs); the driver re-lowers, re-fits costs
and records the three roofline terms before/after.

    python -m repro.launch.hillclimb --target deepseek
"""

import argparse
import json
import time

import jax
import numpy as np

from repro.launch.mesh import make_production_mesh
from repro.launch.cells import build_cell, lower_cell, BuiltCell
from repro.launch import dryrun as DR
from repro.roofline.analysis import RooflineTerms
from repro.roofline.hlo import estimate_bf16_shadow_bytes


def measure(arch, shape, mesh, mesh_name, *, cfg_overrides=None,
            dims_overrides=None, extra_rules=None, step_builder=None,
            family=None, tokens=0, n_active=0, train=False):
    """Compile a (possibly overridden) cell and return roofline terms."""
    t0 = time.time()
    if step_builder is not None:
        cell = step_builder(mesh)
    else:
        cell = build_cell(arch, shape, mesh, extra_rules=extra_rules,
                          cfg_overrides=cfg_overrides,
                          dims_overrides=dims_overrides)
    compiled = lower_cell(cell, mesh).compile()
    mem = compiled.memory_analysis()
    peak = mem.temp_size_in_bytes + mem.argument_size_in_bytes
    shadow = estimate_bf16_shadow_bytes(compiled.as_text())

    fam = family or DR.REGISTRY[arch].family
    if fam == "lm":
        fit = DR._lm_cost_fit(arch, shape, mesh, cell.kind,
                              cfg_overrides=cfg_overrides,
                              dims_overrides=dims_overrides)
        total = fit["total"]
    else:
        total = DR._metrics(compiled)
    link = sum(v for k, v in total.items() if k.endswith("_link_bytes"))
    chips = int(np.prod(list(mesh.shape.values())))
    terms = RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=total["flops"], bytes_per_chip=total["bytes"],
        link_bytes_per_chip=link,
        model_flops=(6.0 if train else 2.0) * n_active * tokens,
        peak_memory_per_chip=peak)
    d = terms.as_dict()
    d["peak_memory_tpu_estimate"] = max(peak - shadow, 0)
    d["compile_s"] = round(time.time() - t0, 1)
    return d


def log_variant(results, name, hypothesis, d, base=None):
    entry = {"variant": name, "hypothesis": hypothesis, **d}
    if base is not None:
        for t in ("t_compute_s", "t_memory_s", "t_collective_s",
                  "step_time_lb_s"):
            if base[t] > 0:
                entry[f"delta_{t}"] = round(d[t] / base[t] - 1, 4)
    results.append(entry)
    print(f"[{name}] comp={d['t_compute_s']*1e3:.1f}ms "
          f"mem={d['t_memory_s']*1e3:.1f}ms "
          f"coll={d['t_collective_s']*1e3:.1f}ms "
          f"bound={d['bottleneck']} "
          f"peak={d['peak_memory_per_chip']/2**30:.1f}GiB "
          f"frac={d['roofline_fraction']:.4f}", flush=True)
    return entry


def climb_deepseek(mesh, mesh_name, results):
    arch, shape = "deepseek-v2-236b", "train_4k"
    tok = 256 * 4096
    n_act = 28_000_000_000  # ~28B active (computed from config; see record)
    from repro.configs import get_arch
    from repro.models.transformer import LMConfig  # noqa: F401
    cfg = get_arch(arch).config_fn(None)
    from repro.launch.cells import _active_count, _abstract_init
    from repro.models import transformer as T
    pa, _ = _abstract_init(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    from repro.launch.cells import _count
    n_act = _active_count(cfg, _count(pa))

    base = measure(arch, shape, mesh, mesh_name, tokens=tok,
                   n_active=n_act, train=True)
    log_variant(results, "baseline(paper-faithful shardings)",
                "remat=full, n_mb=8, attn_chunk=1024, FSDPxTP", base)

    v = measure(arch, shape, mesh, mesh_name, tokens=tok, n_active=n_act,
                train=True, cfg_overrides={"attn_chunk": 4096})
    log_variant(results, "attn_chunk=4096",
                "one online-softmax chunk: carry (m,l,acc) read/write x4 "
                "fewer -> attention bytes down; predict ~5-10% t_mem",
                v, base)

    v2 = measure(arch, shape, mesh, mesh_name, tokens=tok, n_active=n_act,
                 train=True, dims_overrides={"n_microbatches": 2})
    log_variant(results, "n_microbatches=2",
                "FSDP weight all-gathers + weight re-reads scale with "
                "n_mb: 8->2 cuts collective ~4x; activation memory x4 "
                "(watch peak)", v2, base)

    v3 = measure(arch, shape, mesh, mesh_name, tokens=tok, n_active=n_act,
                 train=True, cfg_overrides={"attn_chunk": 4096},
                 dims_overrides={"n_microbatches": 2})
    log_variant(results, "combined(chunk4096+mb2)",
                "both wins are independent terms; expect ~product", v3,
                base)

    v4 = measure(arch, shape, mesh, mesh_name, tokens=tok, n_active=n_act,
                 train=True, cfg_overrides={"attn_chunk": 4096,
                                            "remat": "dots"},
                 dims_overrides={"n_microbatches": 2})
    log_variant(results, "plus remat=dots",
                "recompute only non-dot ops: backward re-reads drop; "
                "peak memory rises (saved dots) — accept if it fits",
                v4, base)


def climb_gnn(mesh, mesh_name, results):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_arch, get_shape
    from repro.models import gnn as G
    from repro.launch.cells import (_abstract_init, _shard_tree, _sds,
                                    _opt_cfg_for, _abstract_opt)
    from repro.train.train_step import make_train_step
    from repro.distributed.sharding import use_rules, active_mesh

    arch, shape = "graphsage-reddit", "ogb_products"
    base = measure(arch, shape, mesh, mesh_name)
    log_variant(results, "baseline(GSPMD segment_sum)",
                "scatter-add over globally sharded edges all-reduces the "
                "FULL (N, H) node array per layer", base)

    v = measure(arch, shape, mesh, mesh_name,
                cfg_overrides={"dtype": "bfloat16"})
    log_variant(results, "bf16 features",
                "halve every gather/all-reduce byte. NOTE: refutable on "
                "this CPU-pipeline profile — XLA-CPU float-normalises "
                "bf16 through f32 copies, so byte counts may not move "
                "(the TPU pipeline keeps native bf16)", v, base)

    def build_partitioned(mesh):
        spec = get_arch(arch)
        cfg = spec.config_fn(shape)
        d = get_shape(spec, shape).dims
        N, E = d["n_nodes"], d["n_edges"]
        F_pad = 112   # d_feat 100 padded to /16 for feature sharding
        loss_sharded = G.make_sharded_loss(mesh, cfg, N, F_pad,
                                           node_axes=("data",),
                                           feat_axis="model")
        with use_rules({}), active_mesh(mesh):
            import dataclasses as _dc
            cfg_p = _dc.replace(cfg, d_feat=F_pad)
            params_a, logical = _abstract_init(
                lambda: G.init_params(jax.random.PRNGKey(0), cfg_p))
            p_sh = jax.tree.map(
                lambda _: NamedSharding(mesh, P()), params_a)
            opt_cfg = _opt_cfg_for(arch)
            opt_a, _ = _abstract_opt(params_a, logical, opt_cfg)
            o_sh = jax.tree.map(
                lambda _: NamedSharding(mesh, P()), opt_a)
            batch_a = {
                "x": _sds((N, F_pad), "float32"),
                "edge_src": _sds((E,), "int32"),
                "edge_dst_local": _sds((E,), "int32"),
                "labels": _sds((N,), "int32"),
                "mask": _sds((N,), "bool"),
            }
            b_sh = {
                "x": NamedSharding(mesh, P("data", "model")),
                "edge_src": NamedSharding(mesh, P("data")),
                "edge_dst_local": NamedSharding(mesh, P("data")),
                "labels": NamedSharding(mesh, P("data")),
                "mask": NamedSharding(mesh, P("data")),
            }

            def loss_fn(p, b):
                loss = loss_sharded(p, b["x"], b["edge_src"],
                                    b["edge_dst_local"], b["labels"],
                                    b["mask"])
                return loss, {"ce": loss}

            step = make_train_step(loss_fn, opt_cfg, 1)
            return BuiltCell(arch, shape, "train_full_partitioned", step,
                             (params_a, opt_a, batch_a),
                             (p_sh, o_sh, b_sh), (0, 1), {})

    v2 = measure(arch, shape, mesh, mesh_name,
                 step_builder=build_partitioned, family="gnn")
    log_variant(results, "dst-partitioned edges + feature sharding",
                "edges pre-partitioned by destination shard => scatter is "
                "shard-local (no (N,H) all-reduce); features sharded over "
                "model => per-layer all-gather moves (N, F/16); predict "
                "t_coll down ~10x", v2, base)
    return results


def climb_twotower(mesh, mesh_name, results):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_arch
    from repro.models import recsys as R
    from repro.launch.cells import (_abstract_init, _shard_tree, _sds,
                                    _leaf_is_axes)
    from repro.distributed.sharding import (use_rules, active_mesh,
                                            logical_spec)

    arch, shape = "two-tower-retrieval", "retrieval_cand"
    base = measure(arch, shape, mesh, mesh_name)
    log_variant(results, "baseline(fp32 full scan)",
                "item tower fp32 over 1M candidates; memory-bound", base)

    def build_screened(mesh):
        spec = get_arch(arch)
        cfg = spec.config_fn(None)
        with use_rules({}), active_mesh(mesh):
            params_a, logical = _abstract_init(
                lambda: R.twotower_init(jax.random.PRNGKey(0), cfg))
            p_sh = _shard_tree(mesh, logical)
            batch_a = {"user_id": _sds((1,), "int32"),
                       "hist_ids": _sds((1, cfg.n_user_hist), "int32"),
                       "hist_mask": _sds((1, cfg.n_user_hist), "bool"),
                       "cand": _sds((1_000_000,), "int32")}
            b_log = {"user_id": (None,), "hist_ids": (None, None),
                     "hist_mask": (None, None), "cand": ("candidates",)}
            b_sh = jax.tree.map(
                lambda names: NamedSharding(mesh,
                                            logical_spec(names, mesh)),
                b_log, is_leaf=_leaf_is_axes)

            def step(p, b):
                return R.retrieval_scores_screened(
                    p, cfg, b["user_id"], b["hist_ids"], b["hist_mask"],
                    b["cand"], topk=100, shortlist=4096)

            return BuiltCell(arch, shape, "retrieval-screened", step,
                             (params_a, batch_a), (p_sh, b_sh), (), {})

    v = measure(arch, shape, mesh, mesh_name, step_builder=build_screened,
                family="recsys")
    log_variant(results, "ES-screened (bf16 screen + fp32 shortlist)",
                "paper transfer: cheap certified screen over all 1M, "
                "exact rescore on 4096 survivors; predict ~2x bytes down. "
                "NOTE: bf16 wins are invisible on the CPU-pipeline "
                "profile (f32 normalisation)", v, base)

    # --- production restructure: precomputed item index -------------------
    def build_offline_index(mesh, int8: bool):
        spec = get_arch(arch)
        cfg = spec.config_fn(None)
        C = 1_000_000
        with use_rules({}), active_mesh(mesh):
            params_a, logical = _abstract_init(
                lambda: R.twotower_init(jax.random.PRNGKey(0), cfg))
            p_sh = _shard_tree(mesh, logical)
            batch_a = {"user_id": _sds((1,), "int32"),
                       "hist_ids": _sds((1, cfg.n_user_hist), "int32"),
                       "hist_mask": _sds((1, cfg.n_user_hist), "bool")}
            b_sh = jax.tree.map(
                lambda _: NamedSharding(mesh, P()), batch_a)
            cand_spec = NamedSharding(
                mesh, logical_spec(("candidates", None), mesh))
            if int8:
                index_a = (_sds((C, cfg.embed_dim), "int8"),
                           _sds((C,), "float32"))
                idx_sh = (cand_spec,
                          NamedSharding(mesh,
                                        logical_spec(("candidates",),
                                                     mesh)))

                def step(p, b, index):
                    q8, scale = index
                    u = R.user_embed(p, cfg, b["user_id"], b["hist_ids"],
                                     b["hist_mask"])          # (1, D)
                    # phase 1: int8 index scan (1/4 the bytes)
                    approx = (q8.astype(jnp.float32) @ u[0]) * scale
                    _, short = jax.lax.top_k(approx[None], 4096)
                    # phase 2: exact fp32 tower on the shortlist
                    ie = R.item_embed(p, cfg, short[0])
                    exact = u @ ie.T
                    vals, pos = jax.lax.top_k(exact, 100)
                    return vals, jnp.take(short[0], pos[0])[None]

                args = (params_a, batch_a, index_a)
                shs = (p_sh, b_sh, idx_sh)
            else:
                index_a = _sds((C, cfg.embed_dim), "float32")
                idx_sh = cand_spec

                def step(p, b, index):
                    u = R.user_embed(p, cfg, b["user_id"], b["hist_ids"],
                                     b["hist_mask"])
                    scores = u @ index.T
                    return jax.lax.top_k(scores, 100)

                args = (params_a, batch_a, index_a)
                shs = (p_sh, b_sh, idx_sh)
            return BuiltCell(arch, shape,
                             "retrieval-index" + ("-int8" if int8 else ""),
                             step, args, shs, (), {})

    import jax.numpy as jnp  # noqa: F401 (used in closures)
    v2 = measure(arch, shape, mesh, mesh_name,
                 step_builder=lambda m: build_offline_index(m, False),
                 family="recsys")
    log_variant(results, "offline item index (fp32)",
                "the item tower is query-independent: precompute it "
                "offline (standard retrieval practice); per-query work = "
                "one (1M x 256) dot; predict bytes ~8x down", v2, base)

    v3 = measure(arch, shape, mesh, mesh_name,
                 step_builder=lambda m: build_offline_index(m, True),
                 family="recsys")
    log_variant(results, "offline index + int8 ES screen",
                "paper transfer on the index scan: int8 approx pass (1/4 "
                "bytes) + exact fp32 tower on 4096 survivors; predict "
                "another ~3x bytes down", v3, base)
    return results


def climb_fim(mesh, mesh_name, results):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.distributed import make_mining_round_v2
    from repro.launch.cells import _sds
    from repro.configs import get_arch, get_shape

    arch, shape = "fim-eclat", "mine_1g"
    base = measure(arch, shape, mesh, mesh_name)
    log_variant(results, "baseline(paper-faithful round)",
                "screen suffix recomputed per pair from full rows", base)

    def build_v2(mesh):
        d = get_shape(get_arch(arch), shape).dims
        round_fn = make_mining_round_v2(mesh)
        all_axes = tuple(mesh.axis_names)
        tid_spec = all_axes if len(all_axes) > 1 else all_axes[0]
        n_shards = int(np.prod(list(mesh.shape.values())))
        args = (_sds((d["store_rows"], d["n_blocks"], d["block_words"]),
                     "uint32"),
                _sds((d["store_rows"], n_shards), "int32"),
                _sds((d["pairs"], 2), "int32"),
                _sds((d["pairs"],), "int32"))
        shs = (NamedSharding(mesh, P(None, tid_spec, None)),
               NamedSharding(mesh, P(None, tid_spec)),
               NamedSharding(mesh, P(None, None)),
               NamedSharding(mesh, P(None)))
        return BuiltCell(arch, shape, "mine-v2", round_fn, args, shs,
                         (), {})

    v = measure(arch, shape, mesh, mesh_name, step_builder=build_v2,
                family="fim")
    log_variant(results, "v2: precomputed suffix + shared-a chunks",
                "suffix tables are row invariants (stop recomputing); "
                "u-row gathered once per chunk; predict ~2x bytes down",
                v, base)
    return results


TARGETS = {
    "deepseek": climb_deepseek,
    "gnn": climb_gnn,
    "twotower": climb_twotower,
    "fim": climb_fim,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", choices=sorted(TARGETS) + ["all"],
                    default="all")
    ap.add_argument("--outdir", default="results/hillclimb")
    args = ap.parse_args()

    assert len(jax.devices()) == 512
    mesh = make_production_mesh(multi_pod=False)
    mesh_name = "1pod_16x16"

    targets = sorted(TARGETS) if args.target == "all" else [args.target]
    os.makedirs(args.outdir, exist_ok=True)
    for t in targets:
        print(f"=== hillclimb: {t} ===", flush=True)
        results = []
        try:
            TARGETS[t](mesh, mesh_name, results)
        except Exception as e:  # record partial progress
            import traceback
            results.append({"error": str(e),
                            "traceback": traceback.format_exc()[-2000:]})
            print("ERROR:", e)
        with open(os.path.join(args.outdir, f"{t}.json"), "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
