# Launch layer: production mesh, cell builders, dry-run, train/serve drivers.
