"""Multi-host bootstrap for real TPU pods.

On-cluster entry point: every host calls ``init_distributed()`` before
any other jax usage; the coordinator address/process indices come from
the TPU metadata environment (GKE/TPU-VM set these) or explicit flags.
After init, ``jax.devices()`` spans the whole slice and the exact same
``make_production_mesh()`` / cell-builder code used by the CPU dry-run
drives real silicon — that equivalence is the point of the dry-run.

Fault tolerance at this layer (DESIGN.md §6):
  * restartable: training state lives in mesh-agnostic checkpoints; any
    replacement host set re-initialises and restores (elastic pod count);
  * deterministic data: every host regenerates its shard of any global
    batch from (seed, step) — no data-service handoff on failover;
  * straggler detection: a lightweight heartbeat barrier each
    ``--heartbeat-every`` steps; hosts that miss ``--max-missed``
    heartbeats trigger a controlled save-and-exit so the scheduler can
    reschedule the slice (preemption-safe).
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import jax


def init_distributed(coordinator: Optional[str] = None,
                     num_processes: Optional[int] = None,
                     process_id: Optional[int] = None) -> None:
    """Initialise jax.distributed from flags or scheduler environment."""
    coordinator = coordinator or os.environ.get("COORDINATOR_ADDRESS")
    if coordinator is None:
        # single-host run (tests / CPU dry-run): nothing to do
        return
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=int(num_processes
                          or os.environ.get("NUM_PROCESSES", 1)),
        process_id=int(process_id or os.environ.get("PROCESS_ID", 0)))


class Heartbeat:
    """Cross-host liveness barrier: a tiny psum each interval; a timeout
    means a peer is gone or wedged -> save and exit non-zero so the
    scheduler restarts the slice from the latest checkpoint."""

    def __init__(self, interval_steps: int = 100, timeout_s: float = 300.0):
        self.interval = interval_steps
        self.timeout = timeout_s
        self._last = time.time()

    def maybe_beat(self, step: int, on_failure=None) -> None:
        if step % self.interval:
            return
        try:
            # an all-reduce over one scalar doubles as the barrier
            jax.device_get(_psum_one())
            self._last = time.time()
        except Exception:
            if on_failure is not None:
                on_failure()
            raise


def _psum_one():
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    import numpy as np
    devs = np.array(jax.devices())
    mesh = jax.sharding.Mesh(devs, ("i",))
    f = shard_map(lambda x: jax.lax.psum(x, "i"), mesh=mesh,
                  in_specs=P(), out_specs=P(), check_rep=False)
    return f(jnp.ones(()))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="multi-host smoke: init + mesh + one psum barrier")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    args = ap.parse_args()
    init_distributed(args.coordinator, args.num_processes, args.process_id)
    print(f"process {jax.process_index()}/{jax.process_count()} sees "
          f"{jax.device_count()} devices ({jax.local_device_count()} local)")
    print("barrier psum:", float(_psum_one()))


if __name__ == "__main__":
    main()
