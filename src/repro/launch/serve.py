"""Serving driver: batched prefill + decode with a KV cache.

Scaled-down version of the production serving recipe: continuous batched
greedy decoding over the synthetic prompt stream.  Demonstrates the
prefill->decode cache handoff (incl. SWA ring caches and MLA latent
caches) end-to-end on CPU.
"""

from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer as T


def serve_greedy(cfg: T.LMConfig, prompts: np.ndarray, max_new: int = 16,
                 params=None, seed: int = 0, log_fn=print):
    """prompts (B, S) int32 -> generated (B, max_new) int32."""
    if params is None:
        params, _ = T.init_params(jax.random.PRNGKey(seed), cfg)
    B, S = prompts.shape
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t: T.prefill(p, cfg, t, max_len=S + max_new)
    )(params, jnp.asarray(prompts))
    step = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))
    tok = logits.argmax(-1).astype(jnp.int32)
    out: List[jnp.ndarray] = [tok]
    for _ in range(max_new - 1):
        logits, cache = step(params, tok, cache)
        tok = logits.argmax(-1).astype(jnp.int32)
        out.append(tok)
    gen = jnp.stack(out, 1)
    dt = time.time() - t0
    log_fn(f"served {B} seqs x {max_new} new tokens in {dt:.2f}s "
           f"({B * max_new / dt:.1f} tok/s incl. prefill of {S})")
    return np.asarray(gen)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke_config_fn()
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    gen = serve_greedy(cfg, prompts, args.max_new)
    print("generated ids:\n", gen)


if __name__ == "__main__":
    main()
