"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods.

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches see the real single CPU device).

Mesh construction goes through :mod:`repro.compat` — ``axis_types`` /
``jax.sharding.AxisType`` only exist on JAX >= 0.5 and the supported
floor is 0.4.30.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / single-host runs)."""
    return make_mesh(shape, axes)


def make_mining_mesh(*, block: int | None = None, cls: int = 1,
                     multihost: bool = False) -> jax.sharding.Mesh:
    """2-D ``(block, cls)`` mesh for distributed mining (ISSUE 9).

    ``block`` shards the TID-bitmap axis (partial counts psum over it);
    ``cls`` shards the candidate-pair axis of each dispatch chunk (no
    reduction crosses it).  Train scaffolding keeps its ``(data, model)``
    helpers above — mining paths must not reuse those axis names.

    ``block=None`` takes every device not consumed by ``cls``.  With
    ``multihost=True`` the jax.distributed bootstrap runs first (no-op
    off-cluster), so ``jax.device_count()`` spans the whole slice.
    """
    if multihost:
        from repro.launch.multihost import init_distributed
        init_distributed()
    if cls < 1 or jax.device_count() % cls:
        raise ValueError(
            f"cls={cls} must divide device count {jax.device_count()}")
    if block is None:
        block = jax.device_count() // cls
    return make_mesh((block, cls), ("block", "cls"))
