"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods.

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches see the real single CPU device).

Mesh construction goes through :mod:`repro.compat` — ``axis_types`` /
``jax.sharding.AxisType`` only exist on JAX >= 0.5 and the supported
floor is 0.4.30.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / single-host runs)."""
    return make_mesh(shape, axes)
