"""Force a CPU host to present N virtual devices (ISSUE 9, satellite 2).

``launch/dryrun.py`` hard-codes ``XLA_FLAGS`` at module top for its own
512-way sweep; this module is the reusable version for the mining test
harness: subprocess tests call :func:`force_host_device_count` *before*
importing anything that touches a jax backend, then build a real 2-D
``(block, cls)`` mesh over the virtual devices.

Import of this module itself is backend-safe: ``repro``/``repro.launch``
``__init__`` files import nothing, so

    from repro.launch.forcedevices import force_host_device_count
    force_host_device_count(8)
    import jax   # sees 8 CPU devices

works in a fresh interpreter.  Calling it after a backend initialised
raises, because the flag would silently not apply.
"""

from __future__ import annotations

import os
import sys

_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int) -> None:
    """Set ``XLA_FLAGS=--xla_force_host_platform_device_count=n``.

    Must run before jax initialises a backend (first ``jax.devices()`` /
    first trace); the count is locked at backend init.  Any existing
    ``XLA_FLAGS`` content is preserved, with a previous instance of this
    flag replaced.
    """
    if n < 1:
        raise ValueError(f"device count must be >= 1, got {n}")
    jax_mod = sys.modules.get("jax")
    if jax_mod is not None:
        # jax being imported is fine; an initialised backend is not.
        try:
            # populated lazily at first backend use; reading it does NOT
            # trigger initialisation (unlike jax.devices()).
            from jax._src import xla_bridge
            initialised = bool(xla_bridge._backends)
        except Exception:
            initialised = False
        if initialised:
            raise RuntimeError(
                "force_host_device_count called after jax backend init; "
                "the flag would not take effect")
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith(_FLAG + "=")]
    flags.append(f"{_FLAG}={int(n)}")
    os.environ["XLA_FLAGS"] = " ".join(flags)
