"""Training driver: checkpoint/restart, deterministic data, async saves.

Single-host entry point that scales down the production recipe: pick an
arch (``--arch``), build its (possibly reduced) config, shard over the
local mesh, and run train steps with:

  * checkpoint/restart (``--resume`` restores the latest step; data is
    regenerated deterministically from (seed, step) so a restart replays
    the exact stream — no data-service state to recover);
  * async checkpoint writes (training never blocks on the filesystem);
  * elastic restore (the checkpoint is mesh-agnostic — restart on a
    different device count re-shards).

``examples/train_lm.py`` drives this module end-to-end on a ~100M model.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.distributed.sharding import (active_mesh, make_param_shardings,
                                        use_rules)
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.train.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.train.optimizer import OptConfig, opt_init, opt_state_logical
from repro.train.train_step import make_train_step


def train_lm(cfg: T.LMConfig, *, steps: int = 200, batch: int = 8,
             seq_len: int = 256, lr: float = 3e-3, ckpt_dir: str = "",
             ckpt_every: int = 50, resume: bool = False, seed: int = 0,
             n_microbatches: int = 1, mesh=None, rules=None,
             log_every: int = 10, log_fn=print) -> Dict[str, Any]:
    """Train an LM config on the synthetic stream. Returns final metrics."""
    mesh = mesh or make_host_mesh(
        (1, jax.device_count()) if jax.device_count() > 1 else (1, 1))
    rules = rules or {}
    data = SyntheticLM(LMDataConfig(vocab_size=cfg.vocab_size, batch=batch,
                                    seq_len=seq_len, seed=seed))
    opt_cfg = OptConfig(kind="adamw", lr=lr, warmup_steps=min(50, steps//10),
                        decay_steps=steps)

    with use_rules(rules), active_mesh(mesh):
        params, logical = T.init_params(jax.random.PRNGKey(seed), cfg)
        p_sh = make_param_shardings(mesh, logical)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = opt_init(params, opt_cfg)
        o_sh = make_param_shardings(
            mesh, opt_state_logical(logical, opt_cfg))
        opt_state = jax.tree.map(jax.device_put, opt_state, o_sh)

        def loss_fn(p, b):
            return T.loss_fn(p, cfg, b["tokens"], b["labels"])

        step_fn = jax.jit(
            make_train_step(loss_fn, opt_cfg, n_microbatches),
            in_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))

        start_step = 0
        ckpt: Optional[AsyncCheckpointer] = None
        if ckpt_dir:
            ckpt = AsyncCheckpointer(ckpt_dir, keep=3)
            if resume and latest_step(ckpt_dir) is not None:
                state, start_step, extra = restore_checkpoint(
                    ckpt_dir, {"params": params, "opt": opt_state},
                    shardings={"params": p_sh, "opt": o_sh})
                params, opt_state = state["params"], state["opt"]
                log_fn(f"[resume] restored step {start_step} "
                       f"(saved on mesh {extra.get('mesh')})")

        history = []
        t0 = time.time()
        metrics = {}
        for step in range(start_step, steps):
            tokens, labels = data.batch(step)
            batch_arrs = {"tokens": jnp.asarray(tokens),
                          "labels": jnp.asarray(labels)}
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batch_arrs)
            if (step + 1) % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                history.append((step + 1, m["loss"]))
                rate = (step + 1 - start_step) / (time.time() - t0)
                log_fn(f"step {step+1:5d} loss={m['loss']:.4f} "
                       f"ppl={m.get('ppl', 0):.1f} lr={m['lr']:.2e} "
                       f"gnorm={m['grad_norm']:.2f} ({rate:.2f} it/s)")
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          extra={"mesh": list(mesh.shape.values())})
        if ckpt:
            ckpt.save(steps, {"params": params, "opt": opt_state},
                      extra={"mesh": list(mesh.shape.values())})
            ckpt.wait()
        return {"history": history,
                "final": {k: float(v) for k, v in metrics.items()}}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--n-layers", type=int, default=0,
                    help="override layer count (scaled-down full configs)")
    ap.add_argument("--d-model", type=int, default=0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    if spec.family != "lm":
        raise SystemExit("train.py drives LM archs; see examples/ for "
                         "gnn/recsys/fim drivers")
    cfg = spec.smoke_config_fn() if args.smoke else spec.config_fn(None)
    over: Dict[str, Any] = {"dtype": "float32", "remat": "none"}
    if args.n_layers:
        over["n_layers"] = args.n_layers
    if args.d_model:
        over["d_model"] = args.d_model
    cfg = dataclasses.replace(cfg, **over)

    out = train_lm(cfg, steps=args.steps, batch=args.batch,
                   seq_len=args.seq_len, lr=args.lr,
                   ckpt_dir=args.ckpt_dir, resume=args.resume,
                   rules=spec.rules_override)
    print("final:", out["final"])


if __name__ == "__main__":
    main()
