import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (including
# repro.*) — jax locks the device count at first initialization.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell.

For each cell on each production mesh (16x16 single-pod, 2x16x16
multi-pod) this driver:

  1. builds the cell (abstract inputs, shardings) — no allocation,
  2. ``jax.jit(step).lower(...)`` then ``.compile()``,
  3. records ``memory_analysis()`` (fits-on-chip proof),
     ``cost_analysis()`` (FLOPs/bytes) and the parsed per-device
     collective traffic into ``results/dryrun/<cell>.json``.

Usage:
  python -m repro.launch.dryrun                      # everything
  python -m repro.launch.dryrun --mesh single        # one mesh
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --skip-existing      # resume a sweep
"""

import argparse
import contextlib
import json
import time
import traceback

import jax

from repro.configs import REGISTRY, all_cells, get_arch, get_shape
from repro.launch.cells import (build_cell, build_fim_costing,
                                build_lm_costing, build_opt_costing,
                                lower_cell)
from repro.launch.mesh import make_production_mesh
from repro.roofline.hlo import (parse_collectives, COLLECTIVE_KINDS,
                                estimate_bf16_shadow_bytes)


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        with contextlib.suppress(Exception):
            out[k] = int(getattr(mem, k))
    return out


def _metrics(compiled) -> dict:
    """Flat metric dict: flops, bytes, per-kind collective link bytes."""
    costs = compiled.cost_analysis()
    cost = costs[0] if isinstance(costs, (list, tuple)) else costs
    cost = dict(cost)
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0))}
    coll = parse_collectives(compiled.as_text())
    for kind in COLLECTIVE_KINDS:
        v = coll.get(kind, {})
        out[f"coll_{kind}_link_bytes"] = float(v.get("link_bytes", 0.0))
        out[f"coll_{kind}_count"] = float(v.get("count", 0.0))
    return out


def _lin(a: dict, b: dict, ca: float, cb: float) -> dict:
    """ca*a + cb*b elementwise (missing keys = 0), clamped at >= 0."""
    keys = set(a) | set(b)
    return {k: max(ca * a.get(k, 0.0) + cb * b.get(k, 0.0), 0.0)
            for k in keys}


def _lm_cost_fit(arch_id: str, shape_id: str, mesh, kind: str,
                 cfg_overrides=None, dims_overrides=None) -> dict:
    """Exact cost reconstruction for scanned LM programs (see cells.py)."""
    spec = get_arch(arch_id)
    cfg = spec.config_fn(shape_id)
    if cfg_overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **cfg_overrides)
    n_full = (cfg.n_layers - cfg.first_k_dense) if cfg.moe else cfg.n_layers

    m = {}
    for n in (1, 2):
        cc = build_lm_costing(arch_id, shape_id, mesh, n,
                              cfg_overrides=cfg_overrides,
                              dims_overrides=dims_overrides)
        m[n] = _metrics(lower_cell(cc, mesh).compile())
    per_layer = _lin(m[2], m[1], 1.0, -1.0)
    base = _lin(m[1], per_layer, 1.0, -1.0)
    step_cost = _lin(base, per_layer, 1.0, float(n_full))

    detail = {"per_layer": per_layer, "base": base,
              "n_layers_extrapolated": n_full}
    if kind == "train":
        dims = dict(get_shape(spec, shape_id).dims)
        if dims_overrides:
            dims.update(dims_overrides)
        n_mb = dims["n_microbatches"]
        oc = build_opt_costing(arch_id, shape_id, mesh)
        opt_m = _metrics(lower_cell(oc, mesh).compile())
        total = _lin(opt_m, step_cost, 1.0, float(n_mb))
        detail["opt"] = opt_m
        detail["n_microbatches"] = n_mb
    else:
        total = step_cost
    detail["total"] = total
    return detail


def _fim_cost_fit(arch_id: str, shape_id: str, mesh) -> dict:
    """Mining-round totals from reduced-pair-count compiles (scan body
    counted once => measure 1-chunk and 2-chunk rounds, extrapolate)."""
    m = {}
    for n in (1, 2):
        cc = build_fim_costing(arch_id, shape_id, mesh, n)
        m[n] = _metrics(lower_cell(cc, mesh).compile())
    per_chunk = _lin(m[2], m[1], 1.0, -1.0)
    base = _lin(m[1], per_chunk, 1.0, -1.0)
    pairs = get_shape(get_arch(arch_id), shape_id).dims["pairs"]
    n_chunks = max(pairs // 2048, 1)
    total = _lin(base, per_chunk, 1.0, float(n_chunks))
    return {"per_chunk": per_chunk, "base": base,
            "n_chunks": n_chunks, "total": total}


def run_cell(arch_id: str, shape_id: str, mesh, mesh_name: str,
             outdir: str, skip_existing: bool = False) -> dict:
    name = f"{mesh_name}__{arch_id}__{shape_id}".replace("/", "_")
    path = os.path.join(outdir, name + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    chips = 1
    for s in mesh.shape.values():
        chips *= s
    rec = {"arch": arch_id, "shape": shape_id, "mesh": mesh_name,
           "chips": chips, "ok": False}
    t0 = time.time()
    try:
        cell = build_cell(arch_id, shape_id, mesh)
        rec["model_params"] = cell.model_params
        rec["active_params"] = cell.active_params
        if cell.skip_reason:
            rec["skip_reason"] = cell.skip_reason
            rec["ok"] = True
        else:
            lowered = lower_cell(cell, mesh)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = _mem_dict(mem)
            rec["peak_memory_per_chip"] = (
                rec["memory_analysis"].get("temp_size_in_bytes", 0)
                + rec["memory_analysis"].get("argument_size_in_bytes", 0))
            hlo_text = compiled.as_text()
            shadow = estimate_bf16_shadow_bytes(hlo_text)
            rec["cpu_bf16_shadow_bytes"] = shadow
            rec["peak_memory_tpu_estimate"] = max(
                rec["peak_memory_per_chip"] - shadow, 0)
            raw = _metrics(compiled)
            rec["raw_scanned_cost"] = raw

            family = REGISTRY[arch_id].family
            if family == "lm":
                # scanned while-bodies are counted once by cost_analysis:
                # reconstruct exact totals from unrolled reduced depths
                fit = _lm_cost_fit(arch_id, shape_id, mesh, cell.kind)
                rec["cost_fit"] = fit
                total = fit["total"]
            elif family == "fim":
                fit = _fim_cost_fit(arch_id, shape_id, mesh)
                rec["cost_fit"] = fit
                total = fit["total"]
            else:
                total = raw   # no scans in these programs: exact already
            rec["cost_analysis"] = {"flops": total["flops"],
                                    "bytes accessed": total["bytes"]}
            rec["collectives"] = {"total": {
                "link_bytes": sum(v for k, v in total.items()
                                  if k.endswith("_link_bytes")),
                "count": sum(v for k, v in total.items()
                             if k.endswith("_count"))}}
            for kind in COLLECTIVE_KINDS:
                rec["collectives"][kind] = {
                    "link_bytes": total.get(f"coll_{kind}_link_bytes", 0.0),
                    "count": total.get(f"coll_{kind}_count", 0.0)}
            # MODEL_FLOPS: 6*N(active)*D for train cells (D = tokens/step)
            tokens = _tokens_per_step(arch_id, shape_id)
            rec["tokens_per_step"] = tokens
            if tokens and cell.active_params:
                rec["model_flops"] = 6.0 * cell.active_params * tokens
            rec["ok"] = True
    except Exception as e:  # recorded, not fatal — a failed cell is a bug
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["elapsed_s"] = round(time.time() - t0, 1)

    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _tokens_per_step(arch_id: str, shape_id: str) -> int:
    """Tokens processed per step (train/prefill) or per decode step."""
    from repro.configs import get_arch, get_shape
    spec = get_arch(arch_id)
    if spec.family != "lm":
        return 0
    d = get_shape(spec, shape_id).dims
    if "global_batch" in d:
        return d["global_batch"] * d["seq"]
    if shape_id.startswith("prefill"):
        return d["batch"] * d["seq"]
    return d.get("batch", 0)      # decode: one token per sequence


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-fim", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        "dry-run needs 512 host devices; do not import jax before this "
        f"module (got {len(jax.devices())})")

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("1pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("2pod_2x16x16", make_production_mesh(multi_pod=True)))

    cells = all_cells(include_fim=not args.no_fim)
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]

    n_fail = 0
    for mesh_name, mesh in meshes:
        for arch_id, shape_id in cells:
            t0 = time.time()
            rec = run_cell(arch_id, shape_id, mesh, mesh_name,
                           args.outdir, args.skip_existing)
            dt = time.time() - t0
            if rec.get("skip_reason"):
                status = f"SKIP ({rec['skip_reason'][:48]}…)"
            elif rec.get("ok"):
                mem = rec.get("peak_memory_per_chip", 0) / 2**30
                fl = rec.get("cost_analysis", {}).get("flops", 0)
                status = f"OK   mem/chip={mem:6.2f}GiB flops/chip={fl:.3e}"
            else:
                status = "FAIL " + rec.get("error", "?")[:80]
                n_fail += 1
            print(f"[{mesh_name}] {arch_id:24s} {shape_id:14s} "
                  f"{dt:7.1f}s  {status}", flush=True)
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
