"""Version-compat shims for JAX APIs that drifted across releases.

Tested floor: ``jax>=0.4.30`` (declared in pyproject.toml).  Three APIs
this codebase needs moved between 0.4.x and 0.5+:

* ``jax.make_mesh(..., axis_types=...)`` — ``axis_types`` /
  ``jax.sharding.AxisType`` only exist on JAX >= 0.5; ``jax.make_mesh``
  itself only since 0.4.35.
* ``jax.sharding.get_abstract_mesh()`` — JAX >= 0.5 only.
* ``jax.shard_map`` — graduated from ``jax.experimental.shard_map``; the
  ``check_rep`` kwarg was renamed ``check_vma`` along the way.

Every mesh/shard-map construction in the repo goes through this module
so the fallback logic lives in exactly one place.
"""

from __future__ import annotations

import inspect
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    JAX >= 0.5: ``jax.make_mesh(shape, names, axis_types=(Auto,) * n)``
    (pins today's behaviour even if the default ever flips to Explicit).
    0.4.35 <= JAX < 0.5: ``jax.make_mesh`` without ``axis_types``.
    JAX < 0.4.35: plain ``Mesh`` over ``mesh_utils.create_device_mesh``.
    """
    shape = tuple(shape)
    axis_names = tuple(axis_names)
    mk = getattr(jax, "make_mesh", None)
    if mk is not None:
        axis_type = getattr(jax.sharding, "AxisType", None)
        if axis_type is not None:
            return mk(shape, axis_names,
                      axis_types=(axis_type.Auto,) * len(axis_names))
        return mk(shape, axis_names)
    from jax.experimental import mesh_utils

    return Mesh(mesh_utils.create_device_mesh(shape), axis_names)


def get_abstract_mesh():
    """``jax.sharding.get_abstract_mesh()`` or ``None`` where absent."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    return fn() if fn is not None else None


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm


def shard_map(f, *, mesh: Mesh, in_specs, out_specs,
              check_rep: Optional[bool] = False):
    """Apply ``shard_map`` with the replication-check flag this JAX spells
    ``check_rep`` (<= 0.6) or ``check_vma`` (>= 0.7)."""
    sm = _resolve_shard_map()
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    params = inspect.signature(sm).parameters
    if "check_vma" in params:
        kwargs["check_vma"] = check_rep
    elif "check_rep" in params:
        kwargs["check_rep"] = check_rep
    return sm(f, **kwargs)
