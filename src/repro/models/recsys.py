"""RecSys architectures: SASRec, DIN, xDeepFM, two-tower retrieval.

The hot path is the huge sparse embedding lookup.  JAX has no native
EmbeddingBag, so it is built here from ``jnp.take`` + masked segment
reduction (kernel_taxonomy §RecSys) — tables are row-sharded over the
"model" mesh axis ("table_rows" logical axis) and the gather becomes the
standard all-gather-free sharded lookup under SPMD.  The Pallas
``segment_embed`` kernel is the TPU fast path for the flat-bag form.

Training losses follow the papers: SASRec uses per-position sampled
binary CE (1 pos + sampled negs); DIN / xDeepFM binary CTR CE;
two-tower in-batch sampled softmax with logQ correction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------

def embedding_init(rng, vocab: int, d: int, dtype=jnp.float32,
                   scale: float = 0.02):
    t = (jax.random.normal(rng, (vocab, d), jnp.float32) * scale).astype(dtype)
    return {"table": t}, {"table": ("table_rows", "table_dim")}


def embedding_lookup(p: Params, ids: jnp.ndarray) -> jnp.ndarray:
    """Plain row gather; ids (...,) -> (..., D)."""
    return jnp.take(p["table"], ids, axis=0)


def embedding_bag(p: Params, ids: jnp.ndarray, mask: Optional[jnp.ndarray],
                  combiner: str = "mean") -> jnp.ndarray:
    """EmbeddingBag: ids (B, L) multi-hot bags -> (B, D).

    mask (B, L) marks valid slots (padding excluded from the reduction)."""
    e = jnp.take(p["table"], ids, axis=0)             # (B, L, D)
    if mask is None:
        mask = jnp.ones(ids.shape, e.dtype)
    m = mask.astype(e.dtype)[..., None]
    s = (e * m).sum(axis=-2)
    if combiner == "sum":
        return s
    if combiner == "mean":
        return s / jnp.maximum(m.sum(axis=-2), 1.0)
    if combiner == "max":
        neg = jnp.finfo(e.dtype).min
        return jnp.where(m > 0, e, neg).max(axis=-2)
    raise ValueError(combiner)


def _mlp_init(rng, dims: Sequence[int], dtype, final_bias=True):
    # Ranker MLPs are tiny (<= a few MB) and their widths (200, 80, 40...)
    # rarely divide a 16-way model axis: replicate them.  The embedding
    # tables are the memory object and stay row-sharded.
    params, logical = [], []
    rngs = jax.random.split(rng, len(dims) - 1)
    for i in range(len(dims) - 1):
        s = 1.0 / (dims[i] ** 0.5)
        params.append({
            "w": (jax.random.normal(rngs[i], (dims[i], dims[i + 1]),
                                    jnp.float32) * s).astype(dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
        logical.append({"w": (None, None), "b": (None,)})
    return params, logical


def _mlp(layers, x, act=jax.nn.relu, final_act=False):
    for i, lp in enumerate(layers):
        x = x @ lp["w"] + lp["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def _bce_logits(logits, labels):
    logits = logits.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss


# ---------------------------------------------------------------------------
# SASRec (arXiv:1808.09781)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    n_negatives: int = 100
    dropout: float = 0.0       # deterministic runs; kept for fidelity
    dtype: str = "float32"

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)


def sasrec_init(rng, cfg: SASRecConfig):
    dt = cfg.param_dtype
    r = jax.random.split(rng, 3 + cfg.n_blocks)
    params: Params = {}
    logical: Params = {}
    params["item_emb"], logical["item_emb"] = embedding_init(
        r[0], cfg.n_items, cfg.embed_dim, dt)
    params["pos_emb"] = (jax.random.normal(
        r[1], (cfg.seq_len, cfg.embed_dim), jnp.float32) * 0.02).astype(dt)
    logical["pos_emb"] = (None, None)
    params["blocks"], logical["blocks"] = [], []
    d = cfg.embed_dim
    for i in range(cfg.n_blocks):
        k = jax.random.split(r[2 + i], 5)
        s = 1.0 / (d ** 0.5)
        blk = {
            "wq": (jax.random.normal(k[0], (d, d), jnp.float32) * s).astype(dt),
            "wk": (jax.random.normal(k[1], (d, d), jnp.float32) * s).astype(dt),
            "wv": (jax.random.normal(k[2], (d, d), jnp.float32) * s).astype(dt),
            "ff1": {"w": (jax.random.normal(k[3], (d, d), jnp.float32)
                          * s).astype(dt), "b": jnp.zeros((d,), dt)},
            "ff2": {"w": (jax.random.normal(k[4], (d, d), jnp.float32)
                          * s).astype(dt), "b": jnp.zeros((d,), dt)},
            "ln1": {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)},
            "ln2": {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)},
        }
        params["blocks"].append(blk)
        logical["blocks"].append(
            jax.tree.map(lambda p: (None,) * p.ndim, blk))
    return params, logical


def _ln(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    v = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(v + eps) * p["scale"]
            + p["bias"]).astype(x.dtype)


def sasrec_encode(params: Params, cfg: SASRecConfig,
                  seq_ids: jnp.ndarray) -> jnp.ndarray:
    """seq_ids (B, L) item history (0 = padding) -> (B, L, D) states."""
    B, Lq = seq_ids.shape
    x = embedding_lookup(params["item_emb"], seq_ids)
    x = x * (cfg.embed_dim ** 0.5) + params["pos_emb"][None, :Lq]
    x = constrain(x, ("batch", None, None))
    pad = (seq_ids == 0)
    causal = jnp.tril(jnp.ones((Lq, Lq), jnp.bool_))
    mask = causal[None] & ~pad[:, None, :]
    for blk in params["blocks"]:
        h = _ln(blk["ln1"], x)
        q, k, v = h @ blk["wq"], h @ blk["wk"], h @ blk["wv"]
        H = cfg.n_heads
        qh = q.reshape(B, Lq, H, -1)
        kh = k.reshape(B, Lq, H, -1)
        vh = v.reshape(B, Lq, H, -1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qh, kh) / (qh.shape[-1] ** 0.5)
        s = jnp.where(mask[:, None], s.astype(jnp.float32), -1e30)
        a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, vh).reshape(B, Lq, -1)
        x = x + o
        h = _ln(blk["ln2"], x)
        x = x + _mlp([blk["ff1"], blk["ff2"]], h, final_act=False)
    return jnp.where(pad[..., None], 0.0, x)


def sasrec_loss(params, cfg: SASRecConfig, seq_ids, pos_ids, neg_ids,
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Per-position sampled CE: pos_ids (B, L); neg_ids (B, L, n_neg)."""
    h = sasrec_encode(params, cfg, seq_ids)                # (B, L, D)
    pe = embedding_lookup(params["item_emb"], pos_ids)     # (B, L, D)
    ne = embedding_lookup(params["item_emb"], neg_ids)     # (B, L, n, D)
    pos_logit = (h * pe).sum(-1)
    neg_logit = jnp.einsum("bld,blnd->bln", h, ne)
    valid = (pos_ids != 0).astype(jnp.float32)
    lpos = _bce_pointwise(pos_logit, 1.0) * valid
    lneg = (_bce_pointwise(neg_logit, 0.0)
            * valid[..., None]).sum(-1) / max(cfg.n_negatives, 1)
    denom = jnp.maximum(valid.sum(), 1.0)
    loss = (lpos + lneg).sum() / denom
    return loss, {"ce": loss}


def _bce_pointwise(logits, label):
    logits = logits.astype(jnp.float32)
    return (jnp.maximum(logits, 0) - logits * label
            + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def sasrec_score(params, cfg: SASRecConfig, seq_ids,
                 candidate_ids: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Serving: last-position state dotted with candidates (or full catalog)."""
    h = sasrec_encode(params, cfg, seq_ids)[:, -1]         # (B, D)
    if candidate_ids is None:
        return h @ params["item_emb"]["table"].T           # (B, V)
    ce = embedding_lookup(params["item_emb"], candidate_ids)
    return jnp.einsum("bd,bcd->bc", h, ce)


# ---------------------------------------------------------------------------
# DIN (arXiv:1706.06978)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 1_000_000
    n_context: int = 100_000          # context/profile feature vocab
    n_context_fields: int = 4
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    dtype: str = "float32"

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)


def din_init(rng, cfg: DINConfig):
    dt = cfg.param_dtype
    r = jax.random.split(rng, 4)
    params: Params = {}
    logical: Params = {}
    params["item_emb"], logical["item_emb"] = embedding_init(
        r[0], cfg.n_items, cfg.embed_dim, dt)
    params["ctx_emb"], logical["ctx_emb"] = embedding_init(
        r[1], cfg.n_context, cfg.embed_dim, dt)
    d = cfg.embed_dim
    attn_dims = (4 * d,) + tuple(cfg.attn_mlp) + (1,)
    params["attn_mlp"], logical["attn_mlp"] = _mlp_init(r[2], attn_dims, dt)
    mlp_in = d + d + cfg.n_context_fields * d
    mlp_dims = (mlp_in,) + tuple(cfg.mlp) + (1,)
    params["mlp"], logical["mlp"] = _mlp_init(r[3], mlp_dims, dt)
    return params, logical


def din_forward(params, cfg: DINConfig, hist_ids, target_id, ctx_ids,
                ) -> jnp.ndarray:
    """hist_ids (B, L); target_id (B,); ctx_ids (B, n_ctx_fields) -> logits."""
    he = embedding_lookup(params["item_emb"], hist_ids)     # (B, L, D)
    te = embedding_lookup(params["item_emb"], target_id)    # (B, D)
    mask = (hist_ids != 0)
    tb = jnp.broadcast_to(te[:, None], he.shape)
    feats = jnp.concatenate([he, tb, he - tb, he * tb], axis=-1)
    w = _mlp(params["attn_mlp"], feats)[..., 0]             # (B, L)
    w = jnp.where(mask, w.astype(jnp.float32), -1e30)
    # DIN uses un-normalised attention weights in the paper; the common
    # production variant (and ours) is masked softmax for stability.
    a = jax.nn.softmax(w, axis=-1).astype(he.dtype)
    user = jnp.einsum("bl,bld->bd", a, he)
    ctx = embedding_lookup(params["ctx_emb"], ctx_ids)      # (B, F, D)
    ctx = ctx.reshape(ctx.shape[0], -1)
    z = jnp.concatenate([user, te, ctx], axis=-1)
    return _mlp(params["mlp"], z)[..., 0]


def din_score_candidates(params, cfg: DINConfig, hist_ids, ctx_ids,
                         candidate_ids) -> jnp.ndarray:
    """Rank a large candidate set for ONE user (the retrieval_cand shape).

    hist_ids (1, L) and ctx_ids (1, F) describe the user; candidate_ids
    (C,) are scored through full target attention — the candidate axis is
    the data-parallel axis ("candidates" logical name)."""
    C = candidate_ids.shape[0]
    hist = jnp.broadcast_to(hist_ids, (C,) + hist_ids.shape[1:])
    ctx = jnp.broadcast_to(ctx_ids, (C,) + ctx_ids.shape[1:])
    hist = constrain(hist, ("candidates", None))
    return din_forward(params, cfg, hist, candidate_ids, ctx)


def din_loss(params, cfg: DINConfig, hist_ids, target_id, ctx_ids, labels):
    logits = din_forward(params, cfg, hist_ids, target_id, ctx_ids)
    loss = _bce_logits(logits, labels.astype(jnp.float32))
    return loss, {"ce": loss}


# ---------------------------------------------------------------------------
# xDeepFM (arXiv:1803.05170)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_fields: int = 39
    vocab_per_field: int = 100_000
    embed_dim: int = 10
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    mlp: Tuple[int, ...] = (400, 400)
    dtype: str = "float32"

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def total_vocab(self):
        return self.n_fields * self.vocab_per_field


def xdeepfm_init(rng, cfg: XDeepFMConfig):
    dt = cfg.param_dtype
    r = jax.random.split(rng, 5)
    params: Params = {}
    logical: Params = {}
    # One concatenated table with per-field offsets (quotient trick scale).
    params["emb"], logical["emb"] = embedding_init(
        r[0], cfg.total_vocab, cfg.embed_dim, dt)
    params["linear"], logical["linear"] = embedding_init(
        r[1], cfg.total_vocab, 1, dt)
    # CIN weights: layer k maps (H_{k-1} x m) interaction maps -> H_k
    params["cin"], logical["cin"] = [], []
    h_prev = cfg.n_fields
    cin_rngs = jax.random.split(r[2], len(cfg.cin_layers))
    for k, hk in enumerate(cfg.cin_layers):
        s = 1.0 / ((h_prev * cfg.n_fields) ** 0.5)
        params["cin"].append(
            (jax.random.normal(cin_rngs[k], (hk, h_prev * cfg.n_fields),
                               jnp.float32) * s).astype(dt))
        logical["cin"].append((None, None))  # 200x7800 = 6MB: replicate
        h_prev = hk
    mlp_dims = ((cfg.n_fields * cfg.embed_dim,) + tuple(cfg.mlp) + (1,))
    params["mlp"], logical["mlp"] = _mlp_init(r[3], mlp_dims, dt)
    s = 1.0 / (sum(cfg.cin_layers) ** 0.5)
    params["cin_out"] = {
        "w": (jax.random.normal(r[4], (sum(cfg.cin_layers), 1), jnp.float32)
              * s).astype(dt),
        "b": jnp.zeros((1,), dt)}
    logical["cin_out"] = {"w": (None, None), "b": (None,)}
    return params, logical


def xdeepfm_forward(params, cfg: XDeepFMConfig, field_ids) -> jnp.ndarray:
    """field_ids (B, m) — already offset into the concatenated vocab."""
    e = embedding_lookup(params["emb"], field_ids)          # (B, m, D)
    e = constrain(e, ("batch", "fields", None))
    # linear part
    lin = embedding_lookup(params["linear"], field_ids)[..., 0].sum(-1)
    # CIN: x^k_{h,d} = sum_{i,j} W^k_{h,(i,j)} x^{k-1}_{i,d} x^0_{j,d}
    x0 = e
    xk = e
    pooled = []
    for wk in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
        z = z.reshape(z.shape[0], -1, cfg.embed_dim)        # (B, Hk*m, D)
        xk = jnp.einsum("hi,bid->bhd", wk, z)
        pooled.append(xk.sum(-1))                           # (B, Hk)
    cin_feat = jnp.concatenate(pooled, axis=-1)
    cin_logit = (cin_feat @ params["cin_out"]["w"]
                 + params["cin_out"]["b"])[..., 0]
    deep = _mlp(params["mlp"], e.reshape(e.shape[0], -1))[..., 0]
    return lin + cin_logit + deep


def xdeepfm_loss(params, cfg, field_ids, labels):
    logits = xdeepfm_forward(params, cfg, field_ids)
    loss = _bce_logits(logits, labels.astype(jnp.float32))
    return loss, {"ce": loss}


# ---------------------------------------------------------------------------
# Two-tower retrieval (Yi et al., RecSys'19)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_users: int = 5_000_000
    n_items: int = 2_000_000
    n_user_hist: int = 50              # history bag length
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    temperature: float = 0.05
    dtype: str = "float32"

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)


def twotower_init(rng, cfg: TwoTowerConfig):
    dt = cfg.param_dtype
    r = jax.random.split(rng, 4)
    params: Params = {}
    logical: Params = {}
    params["user_emb"], logical["user_emb"] = embedding_init(
        r[0], cfg.n_users, cfg.embed_dim, dt)
    params["item_emb"], logical["item_emb"] = embedding_init(
        r[1], cfg.n_items, cfg.embed_dim, dt)
    # user tower consumes [user_id_emb ; mean(history item embs)]
    u_dims = (2 * cfg.embed_dim,) + tuple(cfg.tower_mlp)
    i_dims = (cfg.embed_dim,) + tuple(cfg.tower_mlp)
    params["user_tower"], logical["user_tower"] = _mlp_init(r[2], u_dims, dt)
    params["item_tower"], logical["item_tower"] = _mlp_init(r[3], i_dims, dt)
    return params, logical


def user_embed(params, cfg: TwoTowerConfig, user_id, hist_ids, hist_mask):
    ue = embedding_lookup(params["user_emb"], user_id)
    he = embedding_bag(params["item_emb"], hist_ids, hist_mask, "mean")
    z = jnp.concatenate([ue, he], axis=-1)
    z = _mlp(params["user_tower"], z, final_act=False)
    return _l2norm(z)


def item_embed(params, cfg: TwoTowerConfig, item_id):
    z = embedding_lookup(params["item_emb"], item_id)
    z = _mlp(params["item_tower"], z, final_act=False)
    return _l2norm(z)


def _l2norm(z):
    return z / jnp.maximum(
        jnp.linalg.norm(z.astype(jnp.float32), axis=-1, keepdims=True),
        1e-12).astype(z.dtype)


def twotower_loss(params, cfg: TwoTowerConfig, user_id, hist_ids, hist_mask,
                  pos_item, item_logq,
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """In-batch sampled softmax with logQ correction (Yi et al. '19).

    ``item_logq`` (B,) is log of each positive item's sampling probability
    (its popularity under the in-batch negative distribution)."""
    u = user_embed(params, cfg, user_id, hist_ids, hist_mask)   # (B, D)
    it = item_embed(params, cfg, pos_item)                      # (B, D)
    logits = (u @ it.T) / cfg.temperature                       # (B, B)
    logits = logits.astype(jnp.float32) - item_logq[None, :]
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], -1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, {"ce": loss, "in_batch_acc": acc}


def retrieval_scores(params, cfg: TwoTowerConfig, user_id, hist_ids,
                     hist_mask, candidate_ids, topk: int = 100):
    """Score one (or few) queries against a large candidate set.

    Batched dot + top-k — the ``retrieval_cand`` serving shape.  The
    blocked screened variant (early-stopping transfer from the paper) is
    ``retrieval_scores_screened`` below."""
    u = user_embed(params, cfg, user_id, hist_ids, hist_mask)   # (B, D)
    ie = item_embed(params, cfg, candidate_ids)                 # (C, D)
    scores = u @ ie.T                                           # (B, C)
    return jax.lax.top_k(scores, topk)


def retrieval_scores_screened(params, cfg: TwoTowerConfig, user_id,
                              hist_ids, hist_mask, candidate_ids,
                              topk: int = 100, shortlist: int = 4096):
    """Early-stopping transfer (beyond-paper, DESIGN.md §4): two-phase
    retrieval.

    Phase 1 (screen): the candidate tower + dot run in bf16 over ALL
    candidates — half the bytes/flops of the fp32 scan — and a shortlist
    of ``shortlist`` >> topk survivors is kept.  Phase 2 (exact): the
    fp32 tower re-scores only the shortlist.  This is the paper's
    "cheap evidence first, full work only where the threshold is still
    reachable" applied to top-k scoring: the bf16 score error is far
    smaller than the score gap at rank ``shortlist``, so the true top-k
    survives the screen (validated in tests/test_retrieval_screen.py)."""
    u = user_embed(params, cfg, user_id, hist_ids, hist_mask)   # (B, D)
    # phase 1: bf16 screen over all candidates
    e8 = jnp.take(params["item_emb"]["table"], candidate_ids, axis=0
                  ).astype(jnp.bfloat16)
    z = e8
    for i, lp in enumerate(params["item_tower"]):
        z = z @ lp["w"].astype(jnp.bfloat16) + lp["b"].astype(jnp.bfloat16)
        if i < len(params["item_tower"]) - 1:
            z = jax.nn.relu(z)
    z = _l2norm(z)
    approx = (u.astype(jnp.bfloat16) @ z.T).astype(jnp.float32)  # (B, C)
    _, short_idx = jax.lax.top_k(approx, shortlist)              # (B, S)
    # phase 2: exact fp32 rescore of the shortlist only
    short_ids = jnp.take(candidate_ids, short_idx[0], axis=0)
    ie = item_embed(params, cfg, short_ids)                      # (S, D)
    exact = u @ ie.T                                             # (B, S)
    vals, pos = jax.lax.top_k(exact, topk)
    return vals, jnp.take(short_idx[0], pos[0], axis=0)[None]
