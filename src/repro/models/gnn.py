"""GraphSAGE (Hamilton et al., arXiv:1706.02216) in pure JAX.

Message passing is built on ``jax.ops.segment_sum`` over an edge index —
JAX has no CSR SpMM, so the scatter/segment formulation IS the system
(kernel_taxonomy §GNN).  Two execution modes cover the four assigned
shapes:

  * full-batch (``full_graph_sm``, ``ogb_products``, ``molecule``):
    the whole edge list is aggregated per layer; nodes/edges shard over
    the (pod, data) mesh axes, features over "model".
  * sampled minibatch (``minibatch_lg``): the uniform fanout sampler in
    ``repro.data.graph_data`` materialises dense neighbor blocks
    (B, f2, f1, F) and aggregation is plain masked means — the
    GraphSAGE-paper training regime for Reddit-scale graphs.

Aggregator: mean (the assigned config).  Layer rule (paper Alg. 1):
    h_v^k = relu(W_k . concat(h_v^{k-1}, mean_{u in N(v)} h_u^{k-1}))
followed by L2 normalisation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    d_feat: int = 602
    n_classes: int = 41
    aggregator: str = "mean"
    fanouts: Tuple[int, ...] = (25, 10)   # layer-1, layer-2 sample sizes
    dtype: str = "float32"
    l2_normalize: bool = True

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)


def init_params(rng, cfg: SAGEConfig):
    dt = cfg.param_dtype
    params: Params = {"layers": []}
    logical: Params = {"layers": []}
    d_in = cfg.d_feat
    rngs = jax.random.split(rng, cfg.n_layers + 1)
    for i in range(cfg.n_layers):
        d_out = cfg.d_hidden
        s = 1.0 / (d_in ** 0.5)
        k = jax.random.split(rngs[i], 2)
        params["layers"].append({
            "w_self": (jax.random.normal(k[0], (d_in, d_out), jnp.float32)
                       * s).astype(dt),
            "w_neigh": (jax.random.normal(k[1], (d_in, d_out), jnp.float32)
                        * s).astype(dt),
            "bias": jnp.zeros((d_out,), dt),
        })
        logical["layers"].append({
            "w_self": ("feat", "hidden"),
            "w_neigh": ("feat", "hidden"),
            "bias": ("hidden",),
        })
        d_in = d_out
    s = 1.0 / (d_in ** 0.5)
    params["head"] = {
        "w": (jax.random.normal(rngs[-1], (d_in, cfg.n_classes), jnp.float32)
              * s).astype(dt),
        "bias": jnp.zeros((cfg.n_classes,), dt),
    }
    logical["head"] = {"w": ("hidden", None), "bias": (None,)}
    return params, logical


def _sage_combine(lp: Params, h_self: jnp.ndarray, h_neigh: jnp.ndarray,
                  cfg: SAGEConfig, last: bool) -> jnp.ndarray:
    y = (h_self @ lp["w_self"] + h_neigh @ lp["w_neigh"] + lp["bias"])
    if not last:
        y = jax.nn.relu(y)
    if cfg.l2_normalize:
        y = y / jnp.maximum(
            jnp.linalg.norm(y.astype(jnp.float32), axis=-1, keepdims=True),
            1e-12).astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# full-batch forward: segment_sum over the global edge list
# ---------------------------------------------------------------------------

def forward_full(params: Params, cfg: SAGEConfig, x: jnp.ndarray,
                 edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                 ) -> jnp.ndarray:
    """x (N, F); edge arrays (E,) int32 (src -> dst messages).

    Mean aggregation = segment_sum(messages) / segment_sum(1).  Self loops
    are NOT assumed; isolated nodes see a zero neighbor vector."""
    n = x.shape[0]
    h = x.astype(cfg.param_dtype)   # bf16 configs halve gather/collective
    deg = jax.ops.segment_sum(jnp.ones_like(edge_src, jnp.float32),
                              edge_dst, num_segments=n)
    inv_deg = (1.0 / jnp.maximum(deg, 1.0)).astype(h.dtype)
    for li, lp in enumerate(params["layers"]):
        msgs = h[edge_src]
        agg = jax.ops.segment_sum(msgs, edge_dst, num_segments=n)
        agg = agg * inv_deg[:, None]
        h = _sage_combine(lp, h, agg, cfg,
                          last=(li == cfg.n_layers - 1))
        h = constrain(h, ("nodes", "hidden"))
    return h @ params["head"]["w"] + params["head"]["bias"]


# ---------------------------------------------------------------------------
# sampled minibatch forward: dense fanout blocks
# ---------------------------------------------------------------------------

def forward_sampled(params: Params, cfg: SAGEConfig,
                    feats: Tuple[jnp.ndarray, ...],
                    masks: Optional[Tuple[jnp.ndarray, ...]] = None,
                    ) -> jnp.ndarray:
    """2-layer sampled forward (GraphSAGE minibatch regime).

    feats = (x_root (B,F), x_hop1 (B,f1,F), x_hop2 (B,f1,f2,F)) where f1 is
    the root fanout and f2 the second-hop fanout.  ``masks`` marks real
    (non-padded) samples.  Aggregation collapses hop2 -> hop1 -> root."""
    assert cfg.n_layers == 2, "sampled path implements the assigned 2-layer net"
    x_root, x_h1, x_h2 = feats
    if masks is None:
        m1 = jnp.ones(x_h1.shape[:-1], x_root.dtype)
        m2 = jnp.ones(x_h2.shape[:-1], x_root.dtype)
    else:
        m1, m2 = (m.astype(x_root.dtype) for m in masks)

    lp1, lp2 = params["layers"]

    def mean_agg(xs, mask):  # (..., k, F), (..., k)
        s = (xs * mask[..., None]).sum(-2)
        d = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
        return s / d

    # layer 1 applied at depth-1 nodes (and root) using depth-2 neighbors
    agg2 = mean_agg(x_h2, m2)                      # (B, f1, F)
    h1 = _sage_combine(lp1, x_h1, agg2, cfg, last=False)   # (B, f1, H)
    agg1_root = mean_agg(x_h1, m1)                 # (B, F)
    h_root = _sage_combine(lp1, x_root, agg1_root, cfg, last=False)

    # layer 2 at root using depth-1 hidden states
    agg1 = mean_agg(h1, m1)                        # (B, H)
    h = _sage_combine(lp2, h_root, agg1, cfg, last=True)
    h = constrain(h, ("nodes", "hidden"))
    return h @ params["head"]["w"] + params["head"]["bias"]


# ---------------------------------------------------------------------------
# locality-partitioned full-batch forward (hillclimb variant)
# ---------------------------------------------------------------------------
#
# The GSPMD segment_sum over globally-sharded edges all-reduces the FULL
# node array per layer (the scatter-add cannot prove locality).  Real
# distributed GNN systems partition edges by destination shard and shard
# features, making aggregation shard-local:
#
#   * edges are pre-partitioned so shard s holds exactly the edges whose
#     dst lies in its node range (a data-pipeline invariant — the host
#     sorts edges once);
#   * node features are sharded (nodes x features) over (data x model);
#   * per layer: all-gather x over the NODE axis moves (N, F/16) per chip
#     (vs all-reducing (N, H) full); the W contraction over the sharded
#     feature axis psums a small (N_local, H) block.
#
# Exposed as a shard_map program builder; differentiable (psum transposes
# to psum), so the full train step works through it.

def make_sharded_loss(mesh, cfg: SAGEConfig, n_nodes: int, f_pad: int,
                      node_axes=("data",), feat_axis: str = "model"):
    import functools
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    node_spec = node_axes if len(node_axes) > 1 else node_axes[0]
    h_dim = cfg.d_hidden

    def _layer(lp, x_local, x_feat_local, edge_src, edge_dst_local,
               inv_deg, n_local, last):
        # all-gather over the node axis: (N, F_local) everywhere
        xg = jax.lax.all_gather(x_feat_local, node_axes, axis=0,
                                tiled=True)
        msgs = xg[edge_src]                          # (E_local, F_local)
        agg = jax.ops.segment_sum(msgs, edge_dst_local,
                                  num_segments=n_local)
        agg = agg * inv_deg[:, None]
        # contraction over the sharded feature axis -> psum
        y = (x_local @ lp["w_self"] + agg @ lp["w_neigh"])
        y = jax.lax.psum(y, feat_axis) + lp["bias"]
        if not last:
            y = jax.nn.relu(y)
        if cfg.l2_normalize:
            y = y / jnp.maximum(jnp.linalg.norm(
                y.astype(jnp.float32), axis=-1, keepdims=True),
                1e-12).astype(y.dtype)
        return y                                     # (N_local, H) full H

    def _feat_slice(h, width):
        r = jax.lax.axis_index(feat_axis)
        return jax.lax.dynamic_slice_in_dim(h, r * width, width, axis=1)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(node_spec, feat_axis), P(node_spec), P(node_spec),
                  P(node_spec), P(node_spec)),
        out_specs=P(), check_rep=False)
    def loss_fn(params, x, edge_src, edge_dst_local, labels, mask):
        n_local = x.shape[0]
        deg = jax.ops.segment_sum(
            jnp.ones_like(edge_dst_local, jnp.float32), edge_dst_local,
            num_segments=n_local)
        inv_deg = (1.0 / jnp.maximum(deg, 1.0)).astype(x.dtype)

        # layer 1: params sliced to this shard's feature range
        f_local = x.shape[1]
        r = jax.lax.axis_index(feat_axis)
        lp1 = params["layers"][0]
        lp1 = {"w_self": jax.lax.dynamic_slice_in_dim(
                   lp1["w_self"], r * f_local, f_local, 0),
               "w_neigh": jax.lax.dynamic_slice_in_dim(
                   lp1["w_neigh"], r * f_local, f_local, 0),
               "bias": lp1["bias"]}
        h = _layer(lp1, x, x, edge_src, edge_dst_local, inv_deg,
                   n_local, last=False)              # (N_local, H)

        h_width = h_dim // _axis_size(mesh, feat_axis)
        hf = _feat_slice(h, h_width)
        lp2 = params["layers"][1]
        lp2 = {"w_self": jax.lax.dynamic_slice_in_dim(
                   lp2["w_self"], r * h_width, h_width, 0),
               "w_neigh": jax.lax.dynamic_slice_in_dim(
                   lp2["w_neigh"], r * h_width, h_width, 0),
               "bias": lp2["bias"]}
        h2 = _layer(lp2, hf, hf, edge_src, edge_dst_local, inv_deg,
                    n_local, last=True)
        logits = h2 @ params["head"]["w"] + params["head"]["bias"]

        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        lp_tok = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        m = mask.astype(jnp.float32)
        loss_sum = jax.lax.psum(-(lp_tok * m).sum(), node_axes)
        n = jax.lax.psum(m.sum(), node_axes)
        return loss_sum / jnp.maximum(n, 1.0)

    return loss_fn


def _axis_size(mesh, name: str) -> int:
    return mesh.shape[name]


def loss_full(params, cfg: SAGEConfig, x, edge_src, edge_dst, labels,
              label_mask) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits = forward_full(params, cfg, x, edge_src, edge_dst)
    return _masked_ce(logits, labels, label_mask)


def loss_sampled(params, cfg: SAGEConfig, feats, masks, labels,
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits = forward_sampled(params, cfg, feats, masks)
    return _masked_ce(logits, labels, jnp.ones_like(labels, jnp.bool_))


def _masked_ce(logits, labels, mask):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    lp = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = mask.astype(jnp.float32)
    loss = -(lp * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    acc = ((logits.argmax(-1) == labels) * mask).sum() / jnp.maximum(
        mask.sum(), 1.0)
    return loss, {"ce": loss, "acc": acc}
