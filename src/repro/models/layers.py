"""Transformer building blocks in pure JAX (no flax).

Every ``*_init`` returns ``(params, logical)`` — two pytrees with the same
structure, the second holding logical-axis name tuples consumed by
``repro.distributed.sharding.make_param_shardings``.  Apply functions are
pure; dtype policy is explicit (params in ``param_dtype``, matmuls in
``compute_dtype``, softmax/statistics in fp32).

Attention is the chunked online-softmax formulation (lax.scan over KV
chunks) so the quadratic score matrix never materialises — this is the
XLA-everywhere implementation; the Pallas flash kernel in
``repro.kernels.flash_attention`` is the TPU hot path and is validated
against the same reference.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

Params = Dict[str, Any]
Logical = Dict[str, Any]


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def _split(rng, n):
    return jax.random.split(rng, n)


def dense_init(rng, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.bfloat16, axes=("embed", "ff"),
               ) -> Tuple[Params, Logical]:
    scale = 1.0 / (d_in ** 0.5)
    p = {"kernel": (jax.random.normal(rng, (d_in, d_out), jnp.float32)
                    * scale).astype(dtype)}
    lg = {"kernel": axes}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
        lg["bias"] = (axes[-1],)
    return p, lg


def dense(p: Params, x: jnp.ndarray, compute_dtype=jnp.bfloat16):
    y = jnp.einsum("...d,df->...f", x.astype(compute_dtype),
                   p["kernel"].astype(compute_dtype))
    if "bias" in p:
        y = y + p["bias"].astype(compute_dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> Tuple[Params, Logical]:
    return ({"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)})


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_core(x, scale, eps):
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss[..., None] / x.shape[-1] + eps)
    return x * inv.astype(x.dtype) * scale.astype(x.dtype)


def _rmsnorm_fwd(x, scale, eps):
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss[..., None] / x.shape[-1] + eps)  # fp32 (...,1)
    return x * inv.astype(x.dtype) * scale.astype(x.dtype), (x, scale, inv)


def _rmsnorm_bwd(eps, res, g):
    # Hand-written VJP: all x-sized math stays in x's dtype; fp32 appears
    # only in per-token scalars and dot ACCUMULATORS.  The autodiff VJP
    # multiplies an fp32 cotangent into x, and XLA then hoists the
    # convert over the scan-saved residual stack — an fp32 image of every
    # layer input (24GiB/chip on command-r train).  This rule avoids any
    # fp32 x-sized tensor entirely.
    x, scale, inv = res
    d = x.shape[-1]
    inv_b = inv.astype(x.dtype)
    gs = g * scale.astype(x.dtype)                       # (..., d)
    dot = jnp.einsum("...d,...d->...", gs, x,
                     preferred_element_type=jnp.float32)[..., None]
    coeff = (inv * inv * inv * dot / d).astype(x.dtype)  # (..., 1)
    d_x = gs * inv_b - x * coeff
    xin = x * inv_b
    d_scale = jnp.einsum(
        "...d,...d->d" if x.ndim > 1 else "d,d->d", g, xin,
        preferred_element_type=jnp.float32).astype(scale.dtype)
    return d_x, d_scale


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5):
    return _rmsnorm_core(x, p["scale"], eps)


def layernorm_init(d: int, dtype=jnp.float32) -> Tuple[Params, Logical]:
    return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)})


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def embed_init(rng, vocab: int, d: int, dtype=jnp.bfloat16,
               axes=("vocab", "embed")) -> Tuple[Params, Logical]:
    p = {"table": (jax.random.normal(rng, (vocab, d), jnp.float32)
                   * 0.02).astype(dtype)}
    return p, {"table": axes}


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float = 1e4) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                         # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,d/2)
    if x.ndim == angles.ndim + 1:                        # has heads dim
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (GQA-aware)
# ---------------------------------------------------------------------------

def chunked_attention(q: jnp.ndarray,       # (B, Sq, H, Dh)
                      k: jnp.ndarray,       # (B, Sk, KH, Dh)
                      v: jnp.ndarray,       # (B, Sk, KH, Dv)
                      *,
                      causal: bool,
                      q_offset: jnp.ndarray | int = 0,
                      window: int = 0,
                      kv_valid_len: Optional[jnp.ndarray] = None,
                      chunk: int = 1024,
                      softmax_scale: Optional[float] = None,
                      unroll: bool = False,
                      ) -> jnp.ndarray:
    """Memory-efficient attention: scan over KV chunks, fp32 statistics.

    GQA is handled by folding query heads into (KH, G) groups so KV is
    never repeated.  ``q_offset`` is the absolute position of q[:, 0]
    (decode steps pass the cache length).  ``window`` > 0 adds a sliding
    window mask (Mistral-style); ``kv_valid_len`` masks a partially filled
    cache."""
    B, Sq, H, Dh = q.shape
    _, Sk, KH, _ = k.shape
    Dv = v.shape[-1]
    assert H % KH == 0, (H, KH)
    G = H // KH
    scale = softmax_scale if softmax_scale is not None else Dh ** -0.5
    if Sk % chunk:
        chunk = Sk  # fall back to a single chunk for odd cache sizes
    n_chunks = Sk // chunk

    qg = q.reshape(B, Sq, KH, G, Dh)
    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)

    kc = k.reshape(B, n_chunks, chunk, KH, Dh)
    vc = v.reshape(B, n_chunks, chunk, KH, Dv)
    kc = jnp.moveaxis(kc, 1, 0)   # (n, B, chunk, KH, Dh)
    vc = jnp.moveaxis(vc, 1, 0)

    neg = jnp.float32(-1e30)

    def step(carry, xs):
        m, den, acc = carry
        k_j, v_j, j = xs
        kv_pos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg.astype(jnp.float32),
                       k_j.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((Sq, chunk), jnp.bool_)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        if kv_valid_len is not None:
            mask = mask[None] & (kv_pos[None, None, :]
                                 < kv_valid_len[:, None, None])
            s = jnp.where(mask[:, :, None, None, :], s, neg)
        else:
            s = jnp.where(mask[None, :, None, None, :], s, neg)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        den_new = den * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckv->bqkgv", p, v_j.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, den_new, acc_new), None

    m0 = jnp.full((B, Sq, KH, G), neg, jnp.float32)
    den0 = jnp.zeros((B, Sq, KH, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KH, G, Dv), jnp.float32)
    if unroll:
        # Costing mode (launch/dryrun.py): cost_analysis counts a scan
        # body once, so the chunk walk is unrolled to be costed exactly.
        carry = (m0, den0, a0)
        for j in range(n_chunks):
            carry, _ = step(carry, (kc[j], vc[j], jnp.int32(j)))
        m, den, acc = carry
    else:
        (m, den, acc), _ = jax.lax.scan(
            step, (m0, den0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(den[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (covers MHA, GQA, QKV-bias, SWA)
# ---------------------------------------------------------------------------

def gqa_init(rng, d_model: int, n_heads: int, n_kv_heads: int, d_head: int,
             *, qkv_bias: bool = False, dtype=jnp.bfloat16,
             ) -> Tuple[Params, Logical]:
    r = _split(rng, 4)
    s = 1.0 / (d_model ** 0.5)
    p: Params = {
        "wq": (jax.random.normal(r[0], (d_model, n_heads, d_head),
                                 jnp.float32) * s).astype(dtype),
        "wk": (jax.random.normal(r[1], (d_model, n_kv_heads, d_head),
                                 jnp.float32) * s).astype(dtype),
        "wv": (jax.random.normal(r[2], (d_model, n_kv_heads, d_head),
                                 jnp.float32) * s).astype(dtype),
        "wo": (jax.random.normal(r[3], (n_heads, d_head, d_model),
                                 jnp.float32) * s).astype(dtype),
    }
    lg: Logical = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, d_head), dtype)
        p["bk"] = jnp.zeros((n_kv_heads, d_head), dtype)
        p["bv"] = jnp.zeros((n_kv_heads, d_head), dtype)
        lg["bq"] = ("heads", "head_dim")
        lg["bk"] = ("kv_heads", "head_dim")
        lg["bv"] = ("kv_heads", "head_dim")
    return p, lg


def gqa_apply(p: Params, x: jnp.ndarray, *, positions: jnp.ndarray,
              rope_theta: float = 1e4, window: int = 0,
              attn_chunk: int = 1024, compute_dtype=jnp.bfloat16,
              return_kv: bool = False, attn_unroll: bool = False):
    """Training/prefill forward: full-sequence causal attention.

    ``return_kv=True`` additionally returns the (RoPE'd) K and raw V —
    exactly what the decode cache stores (prefill path)."""
    cd = compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    q = constrain(q, ("batch", None, "act_heads", None))
    k = constrain(k, ("batch", None, "act_kv_heads", None))
    o = chunked_attention(q, k, v, causal=True, window=window,
                          chunk=attn_chunk, unroll=attn_unroll)
    y = jnp.einsum("bshk,hkd->bsd", o.astype(cd), p["wo"].astype(cd))
    if return_kv:
        return y, (k, v)
    return y


def gqa_decode(p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               *, rope_theta: float = 1e4, window: int = 0,
               attn_chunk: int = 1024, compute_dtype=jnp.bfloat16,
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step.  cache = {k: (B, S, KH, Dh), v: ..., len: (B,)}.

    With ``window`` > 0 the cache is a ring buffer of size ``window``.
    Keys are stored post-RoPE at their absolute positions."""
    cd = compute_dtype
    B, one, _ = x.shape
    assert one == 1
    pos = cache["len"]                                    # (B,) int32
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    k_new = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wk"].astype(cd))
    v_new = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k_new = k_new + p["bk"].astype(cd)
        v_new = v_new + p["bv"].astype(cd)
    q = apply_rope(q, pos[:, None], rope_theta)
    k_new = apply_rope(k_new, pos[:, None], rope_theta)

    S = cache["k"].shape[1]
    slot = (pos % S if window else jnp.minimum(pos, S - 1))  # (B,)
    k_cache = _batched_set(cache["k"], k_new[:, 0], slot)
    v_cache = _batched_set(cache["v"], v_new[:, 0], slot)
    # Decode caches may shard their SEQ axis over "model" (GQA kv_heads <
    # model size); the direct softmax below then partitions like
    # flash-decoding: per-shard partial max/sum + tiny all-reduces.
    k_cache = constrain(k_cache, ("batch", "kv_seq", "act_kv_heads", None))
    v_cache = constrain(v_cache, ("batch", "kv_seq", "act_kv_heads", None))
    valid = jnp.minimum(pos + 1, S)
    # Ring buffers (window>0): every slot is valid once wrapped; RoPE'd
    # keys carry absolute positions so slot order does not matter.
    o = _direct_decode_attention(q, k_cache, v_cache, valid)
    y = jnp.einsum("bshk,hkd->bsd", o.astype(cd), p["wo"].astype(cd))
    new_cache = {"k": k_cache, "v": v_cache, "len": pos + 1}
    return y, new_cache


def _direct_decode_attention(q: jnp.ndarray,      # (B, 1, H, Dh)
                             k: jnp.ndarray,      # (B, S, KH, Dh)
                             v: jnp.ndarray,      # (B, S, KH, Dv)
                             valid: jnp.ndarray,  # (B,)
                             ) -> jnp.ndarray:
    """Single-token attention over the full cache (no chunk scan — the
    scan would serialise what GSPMD can partition over a sharded S)."""
    B, _, H, Dh = q.shape
    _, S, KH, Dv = v.shape
    G = H // KH
    qg = q.reshape(B, 1, KH, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * (Dh ** -0.5)
    mask = jnp.arange(S)[None, :] < valid[:, None]          # (B, S)
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskv->bqkgv", a, v.astype(jnp.float32))
    return o.reshape(B, 1, H, Dv).astype(q.dtype)


def _batched_set(buf: jnp.ndarray, val: jnp.ndarray,
                 idx: jnp.ndarray) -> jnp.ndarray:
    """buf: (B, S, ...); val: (B, ...); idx: (B,) -> buf with per-batch set."""
    return buf.at[jnp.arange(buf.shape[0]), idx].set(val.astype(buf.dtype))


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLADims:
    d_model: int
    n_heads: int
    q_lora: int          # 0 => no query compression
    kv_lora: int
    d_nope: int          # per-head non-rotary qk dim
    d_rope: int          # per-head rotary qk dim (key side is shared)
    d_v: int


def mla_init(rng, dims: MLADims, dtype=jnp.bfloat16) -> Tuple[Params, Logical]:
    r = _split(rng, 6)
    d, H = dims.d_model, dims.n_heads
    s = 1.0 / (d ** 0.5)

    def w(rng_, shape):
        return (jax.random.normal(rng_, shape, jnp.float32) * s).astype(dtype)

    p: Params = {}
    lg: Logical = {}
    if dims.q_lora:
        p["wq_a"] = w(r[0], (d, dims.q_lora))
        lg["wq_a"] = ("embed", "lora")
        p["q_norm"], ln = rmsnorm_init(dims.q_lora, dtype)
        p["q_norm"] = p["q_norm"]["scale"]
        lg["q_norm"] = ("lora",)
        p["wq_b"] = w(r[1], (dims.q_lora, H, dims.d_nope + dims.d_rope))
        lg["wq_b"] = ("lora", "heads", "head_dim")
        del ln
    else:
        p["wq"] = w(r[1], (d, H, dims.d_nope + dims.d_rope))
        lg["wq"] = ("embed", "heads", "head_dim")
    p["wkv_a"] = w(r[2], (d, dims.kv_lora + dims.d_rope))
    lg["wkv_a"] = ("embed", "lora")
    p["kv_norm"] = rmsnorm_init(dims.kv_lora, dtype)[0]["scale"]
    lg["kv_norm"] = ("lora",)
    p["wk_b"] = w(r[3], (dims.kv_lora, H, dims.d_nope))
    lg["wk_b"] = ("lora", "heads", "head_dim")
    p["wv_b"] = w(r[4], (dims.kv_lora, H, dims.d_v))
    lg["wv_b"] = ("lora", "heads", "head_dim")
    p["wo"] = w(r[5], (H, dims.d_v, d))
    lg["wo"] = ("heads", "head_dim", "embed")
    return p, lg


def _mla_q(p, x, dims: MLADims, cd):
    if dims.q_lora:
        q_c = jnp.einsum("bsd,dr->bsr", x.astype(cd), p["wq_a"].astype(cd))
        q_c = rmsnorm({"scale": p["q_norm"]}, q_c)
        q = jnp.einsum("bsr,rhk->bshk", q_c.astype(cd), p["wq_b"].astype(cd))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    return q[..., :dims.d_nope], q[..., dims.d_nope:]


def mla_apply(p: Params, x: jnp.ndarray, dims: MLADims, *,
              positions: jnp.ndarray, rope_theta: float = 1e4,
              attn_chunk: int = 1024, compute_dtype=jnp.bfloat16,
              return_kv: bool = False, attn_unroll: bool = False):
    """Training/prefill forward (expanded formulation).

    ``return_kv=True`` additionally returns (c_kv, k_rope) — the latent
    cache entries the absorbed decode path consumes."""
    cd = compute_dtype
    q_nope, q_rope = _mla_q(p, x, dims, cd)
    q_rope = apply_rope(q_rope, positions, rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x.astype(cd), p["wkv_a"].astype(cd))
    c_kv, k_rope = kv[..., :dims.kv_lora], kv[..., dims.kv_lora:]
    c_kv = rmsnorm({"scale": p["kv_norm"]}, c_kv)
    k_rope = apply_rope(k_rope, positions, rope_theta)   # (B, S, d_rope)

    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv.astype(cd),
                        p["wk_b"].astype(cd))
    v = jnp.einsum("bsr,rhk->bshk", c_kv.astype(cd), p["wv_b"].astype(cd))
    H = dims.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (H, dims.d_rope))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope_h], -1)
    scale = (dims.d_nope + dims.d_rope) ** -0.5
    o = chunked_attention(q, k, v, causal=True, chunk=attn_chunk,
                          softmax_scale=scale, unroll=attn_unroll)
    y = jnp.einsum("bshk,hkd->bsd", o.astype(cd), p["wo"].astype(cd))
    if return_kv:
        return y, (c_kv, k_rope)
    return y


def mla_decode(p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               dims: MLADims, *, rope_theta: float = 1e4,
               compute_dtype=jnp.bfloat16,
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Absorbed-matmul decode: the KV cache holds only the latent
    ``c_kv (B, S, kv_lora)`` and shared ``k_rope (B, S, d_rope)``; w_uk is
    absorbed into the query and w_uv into the output so per-step compute
    scales with kv_lora, not n_heads * d_head * S (DeepSeek-V2 §2.1)."""
    cd = compute_dtype
    pos = cache["len"]
    q_nope, q_rope = _mla_q(p, x, dims, cd)               # (B,1,H,*)
    q_rope = apply_rope(q_rope, pos[:, None], rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x.astype(cd), p["wkv_a"].astype(cd))
    c_new, kr_new = kv[..., :dims.kv_lora], kv[..., dims.kv_lora:]
    c_new = rmsnorm({"scale": p["kv_norm"]}, c_new)
    kr_new = apply_rope(kr_new, pos[:, None], rope_theta)

    S = cache["c_kv"].shape[1]
    slot = jnp.minimum(pos, S - 1)
    c_kv = _batched_set(cache["c_kv"], c_new[:, 0], slot)
    k_rope = _batched_set(cache["k_rope"], kr_new[:, 0], slot)
    c_kv = constrain(c_kv, ("batch", "kv_seq", None))
    k_rope = constrain(k_rope, ("batch", "kv_seq", None))
    valid = jnp.minimum(pos + 1, S)

    # absorb: q_lat[h] = q_nope[h] @ wk_b[:, h, :]^T  -> latent space
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(cd))
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                       c_kv.astype(jnp.float32))          # (B,H,1,S)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    scale = (dims.d_nope + dims.d_rope) ** -0.5
    s = (s_lat + s_rope) * scale
    mask = jnp.arange(S)[None, None, None, :] < valid[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", a, c_kv.astype(jnp.float32))
    o = jnp.einsum("bshr,rhk->bshk", o_lat.astype(cd), p["wv_b"].astype(cd))
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cd))
    return y, {"c_kv": c_kv, "k_rope": k_rope, "len": pos + 1}


# ---------------------------------------------------------------------------
# MLPs: SwiGLU and sort-based top-k MoE
# ---------------------------------------------------------------------------

def swiglu_init(rng, d: int, f: int, dtype=jnp.bfloat16,
                ff_axis: str = "ff") -> Tuple[Params, Logical]:
    r = _split(rng, 3)
    s_in, s_out = 1.0 / (d ** 0.5), 1.0 / (f ** 0.5)
    p = {
        "w_gate": (jax.random.normal(r[0], (d, f), jnp.float32)
                   * s_in).astype(dtype),
        "w_up": (jax.random.normal(r[1], (d, f), jnp.float32)
                 * s_in).astype(dtype),
        "w_down": (jax.random.normal(r[2], (f, d), jnp.float32)
                   * s_out).astype(dtype),
    }
    lg = {"w_gate": ("embed", ff_axis), "w_up": ("embed", ff_axis),
         "w_down": (ff_axis, "embed")}
    return p, lg


def swiglu(p: Params, x: jnp.ndarray, compute_dtype=jnp.bfloat16):
    cd = compute_dtype
    g = jnp.einsum("...d,df->...f", x.astype(cd), p["w_gate"].astype(cd))
    u = jnp.einsum("...d,df->...f", x.astype(cd), p["w_up"].astype(cd))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
    h = constrain(h, ("batch",) + (None,) * (h.ndim - 2) + ("act_ff",))
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(cd))


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int            # per-expert hidden
    n_shared: int = 0    # shared experts (DeepSeek)
    capacity_factor: float = 1.25
    # Dispatch groups: routing/sort/scatter run independently per token
    # group whose leading axis is sharded like the batch — a GLOBAL sort
    # would force replicated (T*k, D) intermediates under GSPMD (observed
    # as a 125GiB/chip blow-up on deepseek-v2 train).
    dispatch_groups: int = 32


def moe_init(rng, dims: MoEDims, dtype=jnp.bfloat16) -> Tuple[Params, Logical]:
    r = _split(rng, 5)
    d, E, f = dims.d_model, dims.n_experts, dims.d_ff
    s_in, s_out = 1.0 / (d ** 0.5), 1.0 / (f ** 0.5)

    def w(rng_, shape, s):
        return (jax.random.normal(rng_, shape, jnp.float32) * s).astype(dtype)

    p: Params = {
        "router": w(r[0], (d, E), s_in).astype(jnp.float32),
        "w_gate": w(r[1], (E, d, f), s_in),
        "w_up": w(r[2], (E, d, f), s_in),
        "w_down": w(r[3], (E, f, d), s_out),
    }
    lg: Logical = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_ff"),
        "w_up": ("experts", "embed", "expert_ff"),
        "w_down": ("experts", "expert_ff", "embed"),
    }
    if dims.n_shared:
        sp, sl = swiglu_init(r[4], d, dims.n_shared * f, dtype, ff_axis="ff")
        p["shared"] = sp
        lg["shared"] = sl
    return p, lg


def _pick_groups(preferred: int, T: int) -> int:
    g = min(preferred, T)
    while T % g:
        g -= 1
    return max(g, 1)


def moe_apply(p: Params, x: jnp.ndarray, dims: MoEDims, *,
              compute_dtype=jnp.bfloat16,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based dropping MoE (MegaBlocks/MaxText style), group-local.

    Tokens are split into ``dispatch_groups`` groups (leading axis sharded
    like the batch).  Within each group, top_k copies are sorted by
    destination expert and bucketed into per-expert capacity ``C`` slots;
    expert compute is one ``(G, E, C, *)`` grouped GEMM with the expert
    dim sharded over "model" (expert parallelism — the group<->expert
    reshards become all-to-alls under SPMD).  Returns (y, aux_loss)."""
    cd = compute_dtype
    B, S, D = x.shape
    E, K = dims.n_experts, dims.top_k
    T = B * S
    G = _pick_groups(dims.dispatch_groups, T)
    Tg = T // G
    xg = x.reshape(G, Tg, D)
    xg = constrain(xg, ("batch", None, None))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, K)                 # (G, Tg, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e (global)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
        jnp.ones((T * K,), jnp.float32)) / (T * K)
    aux = E * jnp.sum(me * ce)

    C = int((Tg * K / E) * dims.capacity_factor) + 1
    C = max(4, -(-C // 4) * 4)
    n = Tg * K

    def dispatch_one(x_t, ids_t, gates_t):
        # x_t (Tg, D); ids/gates (Tg, K) — pure group-local dispatch.
        expert_of = ids_t.reshape(n)
        token_of = jnp.arange(n, dtype=jnp.int32) // K
        gate_of = gates_t.reshape(n)
        order = jnp.argsort(expert_of, stable=True)
        se, st_, sg = expert_of[order], token_of[order], gate_of[order]
        starts = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype),
                                  side="left")
        pos = jnp.arange(n, dtype=jnp.int32) - starts[se].astype(jnp.int32)
        keep = pos < C
        slot = jnp.where(keep, se.astype(jnp.int32) * C + pos, E * C)
        buf = jnp.zeros((E * C, D), cd).at[slot].set(
            x_t[st_].astype(cd), mode="drop")
        return buf.reshape(E, C, D), (st_, sg, keep, slot)

    buf, (st_, sg, keep, slot) = jax.vmap(dispatch_one)(xg, ids, gates)
    buf = constrain(buf, ("batch", "experts_act", None, None))

    g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(cd))
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(cd))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
    yb = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cd))
    yb = constrain(yb, ("batch", "experts_act", None, None))

    def combine_one(yb_g, st_g, sg_g, keep_g, slot_g):
        y_cp = yb_g.reshape(E * C, D)[jnp.minimum(slot_g, E * C - 1)]
        y_cp = (y_cp * (keep_g & (slot_g < E * C))[:, None]
                * sg_g[:, None].astype(cd))
        return jnp.zeros((Tg, D), cd).at[st_g].add(y_cp)

    y = jax.vmap(combine_one)(yb, st_, sg, keep, slot)
    y = constrain(y, ("batch", None, None))

    if "shared" in p:
        y = y + swiglu(p["shared"], xg, cd)
    return y.reshape(B, S, D).astype(x.dtype), aux
