# Model definitions: transformer LMs, GraphSAGE, recsys rankers/retrievers.
# Import submodules directly (repro.models.transformer etc.); this package
# stays import-light so partial builds work.
