"""Decoder-only LM covering all five assigned architectures.

One config dataclass spans dense GQA (command-r-plus, granite), QKV-bias
(qwen1.5), MLA + fine-grained MoE with shared experts (deepseek-v2), and
SWA + MoE (mixtral).  Layers are stacked (leading ``L`` axis) and executed
with ``lax.scan`` so HLO size is independent of depth — that is what keeps
the 64-layer 104B dry-run compile tractable.  Heterogeneous stacks
(DeepSeek's leading dense layers) are two scans.

Params/logical trees follow repro.models.layers conventions; sharding is
applied by the caller via repro.distributed.sharding.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 => d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01
    # MLA
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # attention flavour
    sliding_window: int = 0          # 0 => full causal
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    # execution
    attn_chunk: int = 1024
    vocab_pad_multiple: int = 128
    dtype: str = "bfloat16"
    remat: str = "dots"              # none | dots | full
    # unroll the layer stacks instead of lax.scan — used by the roofline
    # costing compiles (cost_analysis counts a while body exactly once,
    # so costs are measured on small UNROLLED depths and extrapolated;
    # see launch/dryrun.py).
    unroll_layers: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def mla_dims(self) -> L.MLADims:
        return L.MLADims(self.d_model, self.n_heads, self.q_lora,
                         self.kv_lora, self.qk_nope_dim, self.qk_rope_dim,
                         self.v_head_dim)

    @property
    def moe_dims(self) -> L.MoEDims:
        return L.MoEDims(self.d_model, self.n_experts, self.top_k,
                         self.moe_d_ff or self.d_ff, self.n_shared_experts,
                         self.capacity_factor)

    def param_count(self) -> int:
        """Total parameters (for MODEL_FLOPS = 6*N*D roofline terms)."""
        shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0),
                                                    self)[0])
        return sum(int(jnp.prod(jnp.array(x.shape)))
                   for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        total = self.param_count()
        f = self.moe_d_ff or self.d_ff
        n_moe_layers = self.n_layers - self.first_k_dense
        per_expert = 3 * self.d_model * f
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return total - inactive


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(rng, cfg: LMConfig, use_moe: bool):
    r = jax.random.split(rng, 4)
    dt = cfg.param_dtype
    p: Dict[str, Any] = {}
    lg: Dict[str, Any] = {}
    p["attn_norm"], lg["attn_norm"] = L.rmsnorm_init(cfg.d_model, dt)
    if cfg.mla:
        p["attn"], lg["attn"] = L.mla_init(r[0], cfg.mla_dims, dt)
    else:
        p["attn"], lg["attn"] = L.gqa_init(
            r[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=dt)
    p["mlp_norm"], lg["mlp_norm"] = L.rmsnorm_init(cfg.d_model, dt)
    if use_moe:
        p["mlp"], lg["mlp"] = L.moe_init(r[1], cfg.moe_dims, dt)
    else:
        p["mlp"], lg["mlp"] = L.swiglu_init(r[1], cfg.d_model, cfg.d_ff, dt)
    return p, lg


def _stack_init(rng, cfg: LMConfig, n: int, use_moe: bool):
    """Init n layers with stacked (leading-L) leaves via vmap."""
    if n == 0:
        return None, None
    rngs = jax.random.split(rng, n)
    p0, l0 = _layer_init(rngs[0], cfg, use_moe)  # structure template
    stacked = jax.vmap(lambda r: _layer_init(r, cfg, use_moe)[0])(rngs)
    logical = jax.tree.map(
        lambda names: (None,) + tuple(names), l0,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            n_ is None or isinstance(n_, str) for n_ in x))
    del p0
    return stacked, logical


def init_params(rng, cfg: LMConfig):
    r = jax.random.split(rng, 4)
    dt = cfg.param_dtype
    params: Dict[str, Any] = {}
    logical: Dict[str, Any] = {}
    params["embed"], logical["embed"] = L.embed_init(
        r[0], cfg.padded_vocab, cfg.d_model, dt)
    n_dense = cfg.first_k_dense if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - cfg.first_k_dense if cfg.moe else 0
    if n_dense:
        params["dense_layers"], logical["dense_layers"] = _stack_init(
            r[1], cfg, n_dense, use_moe=False)
    if n_moe:
        params["moe_layers"], logical["moe_layers"] = _stack_init(
            r[2], cfg, n_moe, use_moe=True)
    params["final_norm"], logical["final_norm"] = L.rmsnorm_init(
        cfg.d_model, dt)
    if not cfg.tie_embeddings:
        params["lm_head"], logical["lm_head"] = L.embed_init(
            r[3], cfg.padded_vocab, cfg.d_model, dt)
    return params, logical


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _remat(fn, cfg: LMConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


def _stack_len(stack) -> int:
    return jax.tree.leaves(stack)[0].shape[0]


def _stack_at(stack, i: int):
    return jax.tree.map(lambda x: x[i], stack)


def _scan_or_unroll(step, carry, stack, unroll: bool):
    """lax.scan over stacked layer params, or a python loop (costing)."""
    if not unroll:
        return jax.lax.scan(step, carry, stack)
    ys = []
    for i in range(_stack_len(stack)):
        carry, y = step(carry, _stack_at(stack, i))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *xs: jnp.stack(xs), *ys)
    else:
        ys = None
    return carry, ys


def _layer_fwd(cfg: LMConfig, use_moe: bool, x, lp, positions):
    # NOTE: when this runs under jax.checkpoint, ``positions`` MUST be an
    # explicit argument — a closed-over tracer disables rematerialisation
    # of everything that depends on it (the RoPE'd q/k and their fp32
    # score operands were silently saved per layer: +24GiB/chip).
    h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
    if cfg.mla:
        a = L.mla_apply(lp["attn"], h, cfg.mla_dims, positions=positions,
                        rope_theta=cfg.rope_theta, attn_chunk=cfg.attn_chunk,
                        compute_dtype=cfg.param_dtype,
                        attn_unroll=cfg.unroll_layers)
    else:
        a = L.gqa_apply(lp["attn"], h, positions=positions,
                        rope_theta=cfg.rope_theta, window=cfg.sliding_window,
                        attn_chunk=cfg.attn_chunk,
                        compute_dtype=cfg.param_dtype,
                        attn_unroll=cfg.unroll_layers)
    x = x + a
    h = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
    if use_moe:
        m, aux = L.moe_apply(lp["mlp"], h, cfg.moe_dims,
                             compute_dtype=cfg.param_dtype)
    else:
        m, aux = L.swiglu(lp["mlp"], h, cfg.param_dtype), jnp.float32(0)
    x = x + m
    x = constrain(x, ("batch", None, "act_embed"))
    return x, aux


def forward(params, cfg: LMConfig, tokens: jnp.ndarray,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> (logits (B, S, Vpad) fp32, moe aux loss)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = params["embed"]["table"][tokens]
    x = constrain(x, ("batch", None, "act_embed"))

    aux_total = jnp.float32(0)

    def scan_stack(x, stack, use_moe, aux_total):
        body = _remat(functools.partial(_layer_fwd, cfg, use_moe), cfg)

        def step(carry, lp):
            x, aux = carry
            x, a = body(x, lp, positions)
            return (x, aux + a), None

        (x, aux_total), _ = _scan_or_unroll(step, (x, aux_total), stack,
                                            cfg.unroll_layers)
        return x, aux_total

    if "dense_layers" in params:
        x, aux_total = scan_stack(x, params["dense_layers"], False, aux_total)
    if "moe_layers" in params:
        x, aux_total = scan_stack(x, params["moe_layers"], True, aux_total)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"]["table"] if cfg.tie_embeddings
            else params["lm_head"]["table"])
    logits = jnp.einsum("bsd,vd->bsv", x.astype(cfg.param_dtype),
                        head.astype(cfg.param_dtype))
    logits = constrain(logits, ("batch", None, "vocab_act"))
    # Returned in param dtype: the fp32 (B, S, V) tensor must NEVER be
    # materialised (it is 4x the largest activation in the model); the
    # loss below keeps all fp32 math inside fused reductions.
    return logits, aux_total


def loss_fn(params, cfg: LMConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Causal LM loss; labels are next-token ids, -1 = masked.

    Memory note: CE is computed as logsumexp(logits) - logits[label] so
    no (B, S, V) buffer beyond the bf16 logits exists — the fp32
    exp/convert fuse into the reduction (this was an 80GiB/chip swing on
    the qwen train cell before the rewrite)."""
    logits, aux = forward(params, cfg, tokens)
    neg = jnp.asarray(jnp.finfo(jnp.float32).min / 2, logits.dtype)
    pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
    logits = jnp.where(pad[None, None, :], neg, logits)

    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    # fp32 exp + sum fused into the reduce; no fp32 (B,S,V) buffer
    s = jnp.sum(jnp.exp((logits - m).astype(jnp.float32)), axis=-1)
    lse = jnp.log(s) + m[..., 0].astype(jnp.float32)

    valid = labels >= 0
    safe_labels = jnp.maximum(labels, 0)
    label_logit = jnp.take_along_axis(
        logits, safe_labels[..., None], axis=-1)[..., 0].astype(jnp.float32)
    n_valid = jnp.maximum(valid.sum(), 1)
    ce = ((lse - label_logit) * valid).sum() / n_valid
    total = ce + cfg.moe_aux_weight * aux
    return total, {"ce": ce, "aux": aux,
                   "ppl": jnp.exp(jnp.minimum(ce, 20.0))}


def prefill(params, cfg: LMConfig, tokens: jnp.ndarray,
            max_len: Optional[int] = None,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Serve-side prefill: run the full prompt, return last-token logits
    and the populated KV cache (ready for decode_step).

    ``max_len`` sets the cache capacity (>= prompt length; defaults to the
    prompt length).  For SWA models only the trailing ``window`` positions
    are kept, rolled so ring-buffer slots line up with ``pos % window``."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = params["embed"]["table"][tokens]
    x = constrain(x, ("batch", None, "act_embed"))

    def layer_fwd_kv(x, lp, use_moe):
        h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        if cfg.mla:
            a, kv = L.mla_apply(lp["attn"], h, cfg.mla_dims,
                                positions=positions,
                                rope_theta=cfg.rope_theta,
                                attn_chunk=cfg.attn_chunk, return_kv=True,
                                compute_dtype=cfg.param_dtype,
                                attn_unroll=cfg.unroll_layers)
        else:
            a, kv = L.gqa_apply(lp["attn"], h, positions=positions,
                                rope_theta=cfg.rope_theta,
                                window=cfg.sliding_window,
                                attn_chunk=cfg.attn_chunk, return_kv=True,
                                compute_dtype=cfg.param_dtype,
                                attn_unroll=cfg.unroll_layers)
        x = x + a
        h = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
        if use_moe:
            m, _ = L.moe_apply(lp["mlp"], h, cfg.moe_dims,
                               compute_dtype=cfg.param_dtype)
        else:
            m = L.swiglu(lp["mlp"], h, cfg.param_dtype)
        x = x + m
        x = constrain(x, ("batch", None, "act_embed"))
        if cfg.sliding_window and S > cfg.sliding_window:
            # Keep the trailing window, rolled so index == pos % window
            # (ring-buffer alignment for any prompt length).
            w = cfg.sliding_window
            kv = tuple(jnp.roll(
                jax.lax.dynamic_slice_in_dim(t, S - w, w, axis=1),
                (S - w) % w, axis=1) for t in kv)
        return x, kv

    kv_stacks = []
    for key, use_moe in (("dense_layers", False), ("moe_layers", True)):
        if key not in params:
            continue

        def step(x, lp, _use_moe=use_moe):
            x, kv = layer_fwd_kv(x, lp, _use_moe)
            return x, kv

        x, kvs = _scan_or_unroll(step, x, params[key], cfg.unroll_layers)
        kv_stacks.append(kvs)

    kv0 = tuple(jnp.concatenate([ks[i] for ks in kv_stacks], axis=0)
                for i in range(2))
    # Pad the seq axis to the cache capacity: the ring window for SWA,
    # otherwise max_len (room for the decode phase).
    if cfg.sliding_window:
        cap = cfg.sliding_window
    else:
        cap = max(max_len or S, S)
    cur = kv0[0].shape[2]
    if cur < cap:
        kv0 = tuple(jnp.pad(t, ((0, 0), (0, 0), (0, cap - cur))
                    + ((0, 0),) * (t.ndim - 3)) for t in kv0)
    if cfg.mla:
        cache = {"c_kv": kv0[0], "k_rope": kv0[1]}
    else:
        cache = {"k": kv0[0], "v": kv0[1]}
    cache["len"] = jnp.full((B,), S, jnp.int32)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"]["table"] if cfg.tie_embeddings
            else params["lm_head"]["table"])
    last = x[:, -1]
    logits = jnp.einsum("bd,vd->bv", last.astype(cfg.param_dtype),
                        head.astype(cfg.param_dtype))
    return logits.astype(jnp.float32), cache


# ---------------------------------------------------------------------------
# decode (serve) path
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int,
               dtype=None) -> Dict[str, jnp.ndarray]:
    """KV cache pytree.  SWA models get a ring buffer of window size;
    MLA models cache the latent + shared rope key only."""
    dt = dtype or cfg.param_dtype
    S = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    nl = cfg.n_layers
    if cfg.mla:
        return {
            "c_kv": jnp.zeros((nl, batch, S, cfg.kv_lora), dt),
            "k_rope": jnp.zeros((nl, batch, S, cfg.qk_rope_dim), dt),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((nl, batch, S, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((nl, batch, S, cfg.n_kv_heads, cfg.head_dim), dt),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def cache_logical(cfg: LMConfig) -> Dict[str, Tuple]:
    """Logical axes for the cache (sharded like activations)."""
    if cfg.mla:
        return {"c_kv": (None, "batch", "kv_seq", None),
                "k_rope": (None, "batch", "kv_seq", None),
                "len": ("batch",)}
    return {"k": (None, "batch", "kv_seq", "kv_heads", None),
            "v": (None, "batch", "kv_seq", "kv_heads", None),
            "len": ("batch",)}


def decode_step(params, cfg: LMConfig, token: jnp.ndarray,
                cache: Dict[str, jnp.ndarray],
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One token for every sequence.  token (B,) int32 -> logits (B, Vpad)."""
    x = params["embed"]["table"][token][:, None, :]     # (B, 1, D)
    x = constrain(x, ("batch", None, "act_embed"))
    pos = cache["len"]

    def layer_step(x, xs):
        if cfg.mla:
            lp, c_kv_l, k_rope_l = xs
            lcache = {"c_kv": c_kv_l, "k_rope": k_rope_l, "len": pos}
            h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
            a, nc = L.mla_decode(lp["attn"], h, lcache, cfg.mla_dims,
                                 rope_theta=cfg.rope_theta,
                                 compute_dtype=cfg.param_dtype)
            new_slices = (nc["c_kv"], nc["k_rope"])
        else:
            lp, k_l, v_l = xs
            lcache = {"k": k_l, "v": v_l, "len": pos}
            h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
            a, nc = L.gqa_decode(lp["attn"], h, lcache,
                                 rope_theta=cfg.rope_theta,
                                 window=cfg.sliding_window,
                                 attn_chunk=cfg.attn_chunk,
                                 compute_dtype=cfg.param_dtype)
            new_slices = (nc["k"], nc["v"])
        x = x + a
        h = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
        if "router" in lp["mlp"]:
            m, _ = L.moe_apply(lp["mlp"], h, cfg.moe_dims,
                               compute_dtype=cfg.param_dtype)
        else:
            m = L.swiglu(lp["mlp"], h, cfg.param_dtype)
        return x + m, new_slices

    # Assemble the per-layer scan inputs in stack order (dense then moe).
    stacks = []
    if "dense_layers" in params:
        stacks.append(params["dense_layers"])
    if "moe_layers" in params:
        stacks.append(params["moe_layers"])

    offset = 0
    new_cache = dict(cache)
    for stack in stacks:
        n = jax.tree.leaves(stack)[0].shape[0]
        sl = slice(offset, offset + n)
        if cfg.mla:
            xs = (stack, cache["c_kv"][sl], cache["k_rope"][sl])
        else:
            xs = (stack, cache["k"][sl], cache["v"][sl])
        x, new_slices = _scan_or_unroll(layer_step, x, xs,
                                        cfg.unroll_layers)
        if cfg.mla:
            new_cache["c_kv"] = new_cache["c_kv"].at[sl].set(new_slices[0])
            new_cache["k_rope"] = new_cache["k_rope"].at[sl].set(
                new_slices[1])
        else:
            new_cache["k"] = new_cache["k"].at[sl].set(new_slices[0])
            new_cache["v"] = new_cache["v"].at[sl].set(new_slices[1])
        offset += n

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"]["table"] if cfg.tie_embeddings
            else params["lm_head"]["table"])
    logits = jnp.einsum("bsd,vd->bsv", x.astype(cfg.param_dtype),
                        head.astype(cfg.param_dtype))[:, 0]
    new_cache["len"] = cache["len"] + 1
    return logits.astype(jnp.float32), new_cache
