"""Pallas TPU kernel: batched PrePost+ N-list merge with early stopping.

The sequential heart of ``ops.nlist_extend`` (DESIGN.md §Errata for the
ES criterion): one grid program per candidate pair walks the two operand
PP-code lists with a two-pointer ``lax.while_loop``, records which V
ancestor code each U code matched (``out_slot``), and aborts the moment
the corrected bound ``z_mass + (rho_V - skip)`` drops below minsup.

Grid/layout mirrors ``bitmap_intersect.py``: operand rows are
``(1, L)`` VMEM blocks indexed dynamically by the loop carry; per-pair
scalars (lengths, rho, outputs) live in SMEM.  N-lists are short by
construction — PrePost+'s selling point — so the bucketed ``(1, L)``
rows are tiny VMEM residents.

Semantics are defined by ``kernels/ref.py::_nl_merge_vmapped`` (the body
of ``nlist_intersect_ref`` / ``nlist_presize_ref`` /
``nlist_extend_ref``) and must match it bit-for-bit;
tests/test_kernels.py sweeps shapes, lengths, ES on/off and minsup
values.  On the mining hot path this kernel is the merge phase of the
*pre-pass* dispatch (``ops.nlist_presize``, ISSUE 5): its match table
stays on device while the host allocates tight extents for the
surviving children only, and the separate scatter dispatch
(``ops.nlist_scatter``) Z-merges it into the pool — the merge loop
runs exactly once per candidate, and dead candidates are never
scattered.  The one-dispatch composition (``ops.nlist_extend``, same
kernel, survivor-gated scatter fused behind it) remains the
micro-bench API.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitmap import NL_SENTINEL


def _kernel(early_stop: bool, lu: int,
            minsup_ref, up_ref, upo_ref, uf_ref, vp_ref, vpo_ref, vf_ref,
            nu_ref, nv_ref, rho_ref,
            slot_ref, mass_ref, cmp_ref, chk_ref, alive_ref):
    """One candidate pair: two-pointer NL merge.

    minsup_ref: (1,) SMEM             — scalar threshold
    up/upo/uf_ref: (1, lu) VMEM       — U (pre, post, freq) rows
    vp/vpo/vf_ref: (1, lv) VMEM       — V rows
    nu/nv/rho_ref: (1,) SMEM          — actual lengths + sibling support
    slot_ref: (1, lu) VMEM            — matched V index per U slot
    mass_ref/cmp_ref/chk_ref/alive_ref: (1,) SMEM outputs
    """
    minsup = minsup_ref[0]
    nu = nu_ref[0]
    nv = nv_ref[0]
    rho = rho_ref[0]

    # Unmatched slots must read back as sentinel: clear the row first.
    slot_ref[0] = jnp.full((lu,), NL_SENTINEL, jnp.int32)

    def cond(st):
        i, j, _, _, _, _, alive = st
        return jnp.logical_and(jnp.logical_and(i < nu, j < nv), alive)

    def body(st):
        i, j, z_mass, skip, cmps, checks, alive = st
        cmps = cmps + 1
        xi_pre = up_ref[0, i]
        xi_post = upo_ref[0, i]
        xi_f = uf_ref[0, i]
        yj_pre = vp_ref[0, j]
        yj_post = vpo_ref[0, j]
        yj_f = vf_ref[0, j]
        is_desc = jnp.logical_and(xi_pre > yj_pre, xi_post < yj_post)
        adv = jnp.logical_or(is_desc, xi_pre <= yj_pre)
        slot_ref[0, i] = jnp.where(is_desc, j, slot_ref[0, i])
        z_mass = z_mass + jnp.where(is_desc, xi_f, 0)
        skip = skip + jnp.where(adv, 0, yj_f)
        checks = checks + jnp.where(adv, 0, 1)
        if early_stop:
            alive = jnp.logical_and(alive, z_mass + (rho - skip) >= minsup)
        i = i + jnp.where(adv, 1, 0)
        j = j + jnp.where(adv, 0, 1)
        return i, j, z_mass, skip, cmps, checks, alive

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.int32(0), jnp.int32(0), jnp.bool_(True))
    _, _, z_mass, _, cmps, checks, alive = jax.lax.while_loop(
        cond, body, init)
    mass_ref[0] = z_mass
    cmp_ref[0] = cmps
    chk_ref[0] = checks
    alive_ref[0] = alive.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("early_stop", "interpret"))
def nlist_merge(
    u_pre: jnp.ndarray, u_post: jnp.ndarray, u_freq: jnp.ndarray,  # (P, Lu)
    v_pre: jnp.ndarray, v_post: jnp.ndarray, v_freq: jnp.ndarray,  # (P, Lv)
    u_len: jnp.ndarray, v_len: jnp.ndarray,                        # (P,)
    rho_v: jnp.ndarray,                                            # (P,)
    minsup: jnp.ndarray,                                           # scalar
    *,
    early_stop: bool = True,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """Pallas NL merge.  Returns ``(out_slot, support, comparisons,
    checks, alive)`` bit-exact vs ``ref._nl_merge_vmapped``."""
    n_pairs, lu = u_pre.shape
    _, lv = v_pre.shape
    minsup_arr = jnp.reshape(jnp.asarray(minsup, jnp.int32), (1,))
    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)

    kernel = functools.partial(_kernel, early_stop, lu)
    out_slot, z_mass, cmps, checks, alive_i = pl.pallas_call(
        kernel,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # minsup (whole array)
            pl.BlockSpec((1, lu), lambda p: (p, 0)),
            pl.BlockSpec((1, lu), lambda p: (p, 0)),
            pl.BlockSpec((1, lu), lambda p: (p, 0)),
            pl.BlockSpec((1, lv), lambda p: (p, 0)),
            pl.BlockSpec((1, lv), lambda p: (p, 0)),
            pl.BlockSpec((1, lv), lambda p: (p, 0)),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, lu), lambda p: (p, 0)),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pairs, lu), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs,), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs,), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs,), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs,), jnp.int32),
        ],
        interpret=interpret,
    )(minsup_arr, i32(u_pre), i32(u_post), i32(u_freq),
      i32(v_pre), i32(v_post), i32(v_freq),
      i32(u_len), i32(v_len), i32(rho_v))
    alive = alive_i.astype(jnp.bool_)
    support = jnp.where(alive, z_mass, 0)  # aborted => certified < minsup
    return out_slot, support, cmps, checks, alive
