"""Pallas TPU kernel: blocked dEclat difference with early stopping and
zero-block skipping (ISSUE 6).

The diffset sibling of ``kernels/bitmap_intersect.py``: one program per
candidate pair walks the blocks of ``Z = U & ~V`` with a
``lax.while_loop`` and aborts the moment the *difference* bound
``rho_parent - count`` drops below minsup (dEclat:
``sup(Pxy) = sup(Px) - |D(Pxy)|`` only decreases as diff words emit —
the paper's DIFFERENCE_ES quantised to blocks).  The block-0 iteration
IS the one-block screen, exactly like the intersect kernel.

What earns diffsets their own kernel is the *work counter*: a block
where the U operand has no set bits can never contribute to ``Z``
(``U & ~V`` is zero wherever ``U`` is), and diffset rows are exactly
the operands that go sparse on dense data — ``|d|`` shrinks as classes
deepen.  The per-block U mass is free from the operand's suffix table
(``su[k] - su[k+1]``), so ``blocks_done`` charges only the
*nonzero-mass* blocks a live pair visits.  Counts, aliveness and the
scattered ``Z`` stay bit-identical to
``bitmap_intersect_es(mode="andnot")`` on the same operands; only the
word-op numerator differs.

Because skipping decouples ``blocks_done`` from the abort point, the
ref's ``alive`` flag can no longer be recovered from ``blocks_done >=
n_blocks`` the way the intersect wrapper does — this kernel publishes
``alive`` explicitly through a fourth SMEM output.

Semantics are defined by ``kernels/ref.py::bitmap_diff_es_ref`` and must
match it bit-for-bit (tests/test_kernels.py sweeps shapes and minsup
values, including minsup<=0 == ES disabled).  The mining hot path wraps
this kernel in ``ops.screen_and_diff`` (gather + survivor-only child
scatter around one ``pallas_call``), mirroring the intersect path's
fused dispatch contract.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bitmap_intersect import _popcount_sum


def _kernel(n_blocks: int,
            minsup_ref, u_ref, v_ref, su_ref, rho_ref,
            z_ref, cnt_ref, blocks_ref, alive_ref):
    """One candidate pair: blocked ES difference with zero-block skip.

    minsup_ref: (1,) SMEM     — scalar-prefetch style threshold
    u_ref/v_ref: (1, nb, bw)  VMEM operand rows
    su_ref: (1, nb+1)         SMEM U suffix popcount row (mass source)
    rho_ref: (1,) SMEM        — parent support (difference bound)
    z_ref: (1, nb, bw) VMEM   — diffset row (zeros past abort)
    cnt_ref, blocks_ref, alive_ref: (1,) SMEM outputs
    """
    minsup = minsup_ref[0]
    rho = rho_ref[0]

    # Dead blocks must read back as zero: clear the output row first.
    z_ref[0] = jnp.zeros_like(z_ref[0])

    def cond(carry):
        k, _, _, alive = carry
        return jnp.logical_and(k < n_blocks, alive)

    def body(carry):
        k, cnt, blocks, alive = carry
        z_k = u_ref[0, k] & ~v_ref[0, k]
        z_ref[0, k] = z_k
        cnt = cnt + _popcount_sum(z_k)
        mass = su_ref[0, k] - su_ref[0, k + 1]
        blocks = blocks + (mass > 0).astype(jnp.int32)
        alive = (rho - cnt) >= minsup
        return k + 1, cnt, blocks, alive

    _, cnt, blocks, alive = jax.lax.while_loop(
        cond, body,
        (jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.bool_(True)))
    cnt_ref[0] = cnt
    blocks_ref[0] = blocks
    alive_ref[0] = alive.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_diff_es(
    U: jnp.ndarray,           # uint32 (n_pairs, n_blocks, bw)
    V: jnp.ndarray,           # uint32 (n_pairs, n_blocks, bw)
    suffix_u: jnp.ndarray,    # int32  (n_pairs, n_blocks + 1)
    rho_parent: jnp.ndarray,  # int32  (n_pairs,)
    minsup: jnp.ndarray,      # int32  scalar; <= 0 disables ES
    *,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pallas ES difference.  Returns (Z, counts, blocks_done, alive).

    ``interpret=True`` (the CPU default here) runs the kernel body in the
    Pallas interpreter for validation; on TPU pass ``interpret=False``.
    """
    n_pairs, n_blocks, bw = U.shape
    minsup_arr = jnp.reshape(jnp.asarray(minsup, jnp.int32), (1,))

    kernel = functools.partial(_kernel, n_blocks)
    z, cnt, blocks, alive = pl.pallas_call(
        kernel,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # minsup (whole array)
            pl.BlockSpec((1, n_blocks, bw), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, n_blocks, bw), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, n_blocks + 1), lambda p: (p, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, n_blocks, bw), lambda p: (p, 0, 0)),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pairs, n_blocks, bw), jnp.uint32),
            jax.ShapeDtypeStruct((n_pairs,), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs,), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs,), jnp.int32),
        ],
        interpret=interpret,
    )(minsup_arr, U, V, suffix_u.astype(jnp.int32),
      rho_parent.astype(jnp.int32))
    return z, cnt, blocks, alive.astype(jnp.bool_)
