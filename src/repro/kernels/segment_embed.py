"""Pallas TPU kernel: EmbeddingBag (gather + masked segment reduce).

JAX has no native EmbeddingBag; the jnp path (models/recsys.embedding_bag)
materialises the gathered (B, L, D) tensor in HBM before reducing.  This
kernel fuses gather+reduce: each program owns a bag tile, gathers rows
from the (VMEM-resident shard of the) table with dynamic slices and
accumulates in VMEM — the (B, L, D) intermediate never exists.

Grid = (B // bag_block,); combiners: sum / mean.

Validated in interpret mode against kernels/ref.py::embedding_bag_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BAG_BLOCK = 8


def _kernel(combiner: str, ids_ref, mask_ref, table_ref, o_ref):
    """ids/mask: (bb, L) SMEM; table: (V, D) VMEM(whole); o: (bb, D)."""
    bb, L = ids_ref.shape
    D = table_ref.shape[1]

    def bag(i, _):
        def slot(j, carry):
            acc, cnt = carry
            idx = ids_ref[i, j]
            valid = mask_ref[i, j]
            row = table_ref[idx, :].astype(jnp.float32)
            acc = acc + jnp.where(valid != 0, row, 0.0)
            cnt = cnt + jnp.where(valid != 0, 1.0, 0.0)
            return acc, cnt

        acc, cnt = jax.lax.fori_loop(
            0, L, slot, (jnp.zeros((D,), jnp.float32), jnp.float32(0)))
        if combiner == "mean":
            acc = acc / jnp.maximum(cnt, 1.0)
        o_ref[i, :] = acc.astype(o_ref.dtype)
        return ()

    jax.lax.fori_loop(0, bb, bag, ())


@functools.partial(jax.jit, static_argnames=("combiner", "bag_block",
                                             "interpret"))
def embedding_bag(table: jnp.ndarray,        # (V, D)
                  ids: jnp.ndarray,          # (B, L) int32
                  mask: jnp.ndarray,         # (B, L) int32/bool
                  *, combiner: str = "mean",
                  bag_block: int = DEFAULT_BAG_BLOCK,
                  interpret: bool = True) -> jnp.ndarray:
    if combiner not in ("sum", "mean"):
        raise ValueError(combiner)
    B, L = ids.shape
    V, D = table.shape
    bag_block = min(bag_block, B)
    assert B % bag_block == 0

    kernel = functools.partial(_kernel, combiner)
    return pl.pallas_call(
        kernel,
        grid=(B // bag_block,),
        in_specs=[
            pl.BlockSpec((bag_block, L), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bag_block, L), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((V, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bag_block, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), mask.astype(jnp.int32), table)
