"""Pallas TPU kernel: fused causal flash attention (GQA-aware).

The XLA-everywhere path (models/layers.chunked_attention) already avoids
materialising the score matrix via a lax.scan; this kernel is the TPU
hot path that additionally keeps the whole online-softmax state in VMEM
and tiles q/k/v for the MXU (128-aligned BlockSpecs).

Grid = (batch, kv_head, q_blocks); each program owns one q tile of one
(batch, kv-head-group) and walks the KV blocks with a fori_loop, carrying
(m, l, acc) in VMEM scratch.  Causality skips fully-masked KV blocks via
``pl.when`` (the causal analogue of the paper's "don't do provably
useless work").

Validated in interpret mode against kernels/ref.py::flash_attention_ref
(shape/dtype sweeps in tests/test_kernels.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_Q_BLOCK = 128
DEFAULT_KV_BLOCK = 128
NEG_INF = -1e30


def _kernel(causal: bool, scale: float, kv_len: int, kv_block: int,
            q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc):
    """One q tile (1, 1, bq, G, Dh) vs all KV blocks of one kv head.

    q_ref: (1, 1, bq, G, D)   — G = query heads per kv head
    k_ref: (1, 1, Skv, D)
    v_ref: (1, 1, Skv, Dv)
    o_ref: (1, 1, bq, G, Dv)
    scratch: m/l (bq, G), acc (bq, G, Dv) — fp32
    """
    bq = q_ref.shape[2]
    G = q_ref.shape[3]
    Dv = v_ref.shape[3]
    qi = pl.program_id(2)
    q_start = qi * bq

    m_sc[...] = jnp.full((bq, G), NEG_INF, jnp.float32)
    l_sc[...] = jnp.zeros((bq, G), jnp.float32)
    acc_sc[...] = jnp.zeros((bq, G, Dv), jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, G, D)
    n_kv = kv_len // kv_block

    def body(j, _):
        kv_start = j * kv_block

        @pl.when(jnp.logical_or(not causal,
                                kv_start <= q_start + bq - 1))
        def process():
            k = k_ref[0, 0, pl.ds(kv_start, kv_block)].astype(jnp.float32)
            v = v_ref[0, 0, pl.ds(kv_start, kv_block)].astype(jnp.float32)
            s = jnp.einsum("qgd,kd->qgk", q, k,
                           preferred_element_type=jnp.float32)
            if causal:
                q_pos = q_start + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, G, kv_block), 0)
                kv_pos = kv_start + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, G, kv_block), 2)
                s = jnp.where(q_pos >= kv_pos, s, NEG_INF)
            m_prev = m_sc[...]
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_sc[...] = l_sc[...] * corr + p.sum(axis=-1)
            acc_sc[...] = (acc_sc[...] * corr[..., None]
                           + jnp.einsum("qgk,kv->qgv", p, v,
                                        preferred_element_type=jnp.float32))
            m_sc[...] = m_new

        return ()

    jax.lax.fori_loop(0, n_kv, body, ())
    out = acc_sc[...] / jnp.maximum(l_sc[...][..., None], 1e-30)
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(
    q: jnp.ndarray,          # (B, Sq, H, D)
    k: jnp.ndarray,          # (B, Skv, KH, D)
    v: jnp.ndarray,          # (B, Skv, KH, Dv)
    *,
    causal: bool = True,
    softmax_scale: Optional[float] = None,
    q_block: int = DEFAULT_Q_BLOCK,
    kv_block: int = DEFAULT_KV_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Skv, KH, Dv = v.shape
    assert H % KH == 0
    G = H // KH
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0

    qg = q.reshape(B, Sq, KH, G, D).transpose(0, 2, 1, 3, 4)  # B,KH,Sq,G,D
    kt = k.transpose(0, 2, 1, 3)                              # B,KH,Skv,D
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, causal, scale, Skv, kv_block)
    out = pl.pallas_call(
        kernel,
        grid=(B, KH, Sq // q_block),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, G, D),
                         lambda b, h, i: (b, h, i, 0, 0)),
            pl.BlockSpec((1, 1, Skv, D), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Skv, Dv), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, G, Dv),
                               lambda b, h, i: (b, h, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, Sq // q_block * q_block,
                                        G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, G), jnp.float32),
            pltpu.VMEM((q_block, G), jnp.float32),
            pltpu.VMEM((q_block, G, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, Dv)
