"""Public jit'd wrappers around the Pallas kernels.

``backend`` selection:
  * ``"jnp"``      — the pure-jnp reference path (kernels/ref.py).  This is
                     the production path on CPU hosts and the oracle for
                     kernel tests.
  * ``"pallas"``   — the Pallas kernel; interpret mode is picked
                     automatically when no TPU is attached.
  * ``"auto"``     — pallas on TPU, jnp elsewhere (default).

All wrappers keep shapes static-friendly: callers pad pair batches to
bucketed sizes (core/eclat.py::_bucket_pad) so jit caches stay small.
``screen_and_intersect`` is the mining hot path: one dispatch per pair
chunk against the device-resident row store, operand gather and child
row/suffix scatter included.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.bitmap import suffix_popcounts as _suffix_popcounts

from . import ref as _ref
from .bitmap_intersect import bitmap_intersect_es as _pallas_bitmap


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def bitmap_intersect_es(U, V, suffix_u, suffix_v, rho_parent, minsup,
                        *, mode: str = "and", backend: str = "auto",
                        ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                   jnp.ndarray, jnp.ndarray]:
    """Blocked early-stopping intersection.  See kernels/ref.py for the
    exact semantics.  Returns (Z, counts, blocks_done, alive)."""
    b = _resolve(backend)
    if b == "pallas":
        return _pallas_bitmap(U, V, suffix_u, suffix_v, rho_parent, minsup,
                              mode=mode, interpret=not _on_tpu())
    return _ref.bitmap_intersect_es_ref(U, V, suffix_u, suffix_v,
                                        rho_parent, minsup, mode=mode)


@functools.partial(jax.jit, static_argnames=("mode", "backend"),
                   donate_argnums=(0, 1))
def _screen_and_intersect_impl(rows, suffix, ua, vb, slots, rho_parent,
                               minsup, *, mode: str, backend: str):
    U = jnp.take(rows, ua, axis=0)
    V = jnp.take(rows, vb, axis=0)
    su = jnp.take(suffix, ua, axis=0)
    sv = jnp.take(suffix, vb, axis=0)
    if backend == "pallas":
        Z, cnt, blocks, alive = _pallas_bitmap(
            U, V, su, sv, rho_parent, minsup, mode=mode,
            interpret=not _on_tpu())
    else:
        Z, cnt, blocks, alive = _ref.bitmap_intersect_es_ref(
            U, V, su, sv, rho_parent, minsup, mode=mode)
    child_suffix = _suffix_popcounts(Z)
    # Out-of-range slots (pair padding / discarded children) are dropped.
    rows = rows.at[slots].set(Z, mode="drop")
    suffix = suffix.at[slots].set(child_suffix, mode="drop")
    return rows, suffix, cnt, blocks, alive


def screen_and_intersect(rows, suffix, ua, vb, slots, rho_parent, minsup,
                         *, mode: str = "and", backend: str = "auto",
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                    jnp.ndarray, jnp.ndarray]:
    """Fused screen + blocked ES intersection over a device row store.

    One device dispatch per pair chunk: gathers operand rows/suffix tables
    by index from the store, runs the blocked early-stopping intersection
    (block-0 screen included — see ``ref.screen_and_intersect_ref``),
    computes child suffix-popcount tables on device and scatters both into
    the store at ``slots``.

    ``rows``/``suffix`` buffers are DONATED: callers must replace their
    handles with the returned arrays.  Returns
    ``(rows, suffix, counts, blocks_done, alive)`` where
    ``rows[slots[i]]`` holds child ``Z_i`` (bit-exact vs the ref) and
    ``suffix[slots[i]]`` its suffix table.  Slots ``>= capacity`` are
    dropped (used for padding).
    """
    b = _resolve(backend)
    return _screen_and_intersect_impl(
        rows, suffix, jnp.asarray(ua, jnp.int32), jnp.asarray(vb, jnp.int32),
        jnp.asarray(slots, jnp.int32), jnp.asarray(rho_parent, jnp.int32),
        jnp.asarray(minsup, jnp.int32), mode=mode, backend=b)


def bitmap_intersect_full(U, V, *, mode: str = "and",
                          backend: str = "auto"):
    """Fused full intersection (Z, counts) without block metrics."""
    del backend
    return _ref.bitmap_intersect_full_ref(U, V, mode=mode)


def bitmap_count(U, V, *, backend: str = "auto") -> jnp.ndarray:
    """Support counting without ES and without materialising Z."""
    # The jnp path is already a single fused AND+popcount+reduce; the
    # pallas path reuses the ES kernel with minsup=0 (never aborts).
    b = _resolve(backend)
    if b == "pallas":
        n_pairs, n_blocks, _ = U.shape
        zeros = jnp.zeros((n_pairs, n_blocks + 1), jnp.int32)
        rho = jnp.zeros((n_pairs,), jnp.int32)
        _, cnt, _, _ = _pallas_bitmap(U, V, zeros, zeros, rho,
                                      jnp.int32(0), mode="and",
                                      interpret=not _on_tpu())
        return cnt
    return _ref.bitmap_count_ref(U, V)


def screen_pairs(first_u, first_v, suffix1_u, suffix1_v, rho_parent, minsup,
                 *, mode: str = "and", backend: str = "auto"):
    """One-block screening bound (inter-call early stopping)."""
    del backend  # single cheap fused op; jnp path is optimal everywhere
    return _ref.screen_pairs_ref(first_u, first_v, suffix1_u, suffix1_v,
                                 rho_parent, minsup, mode=mode)


def flash_attention(q, k, v, *, causal: bool = True, softmax_scale=None,
                    backend: str = "auto"):
    """Fused attention: Pallas kernel on TPU, dense ref elsewhere."""
    b = _resolve(backend)
    if b == "pallas":
        from .flash_attention import flash_attention as _fa
        return _fa(q, k, v, causal=causal, softmax_scale=softmax_scale,
                   interpret=not _on_tpu())
    return _ref.flash_attention_ref(q, k, v, causal=causal,
                                    softmax_scale=softmax_scale)


def embedding_bag(table, ids, mask, *, combiner: str = "mean",
                  backend: str = "auto"):
    """Fused EmbeddingBag: Pallas on TPU, take+reduce elsewhere."""
    b = _resolve(backend)
    if b == "pallas":
        from .segment_embed import embedding_bag as _eb
        return _eb(table, ids, mask, combiner=combiner,
                   interpret=not _on_tpu())
    return _ref.embedding_bag_ref(table, ids, mask, combiner=combiner)


def nlist_intersect(u_pre, u_post, u_freq, v_pre, v_post, v_freq,
                    u_len, v_len, rho_v, minsup, *, early_stop: bool = True,
                    backend: str = "auto"):
    """Batched padded N-list intersection (PrePost+ device path)."""
    del backend  # sequential merge: the vmapped while_loop IS the kernel
    return _ref.nlist_intersect_ref(u_pre, u_post, u_freq,
                                    v_pre, v_post, v_freq,
                                    u_len, v_len, rho_v, minsup,
                                    early_stop=early_stop)
