"""Public jit'd wrappers around the Pallas kernels.

``backend`` selection:
  * ``"jnp"``      — the pure-jnp reference path (kernels/ref.py).  This is
                     the production path on CPU hosts and the oracle for
                     kernel tests.
  * ``"pallas"``   — the Pallas kernel; interpret mode is picked
                     automatically when no TPU is attached.
  * ``"auto"``     — pallas on TPU, jnp elsewhere (default).

All wrappers keep shapes static-friendly: callers pad pair batches to
bucketed sizes (core/eclat.py::_bucket_pad) so jit caches stay small.
``screen_and_intersect`` is the mining hot path: one dispatch per pair
chunk against the device-resident row store, operand gather and child
row/suffix scatter included.

Compile-cache discipline (ISSUE 7, chunk-width autotuning): the jit
cache under every wrapper is keyed on input *shapes* plus the static
args — for the bitmap family effectively ``(padded pair width, mode,
early_stop, backend)``, for the N-list family ``(padded pair width,
lu, lv, early_stop, backend)``.  The engines keep the variant count
bounded by quantizing BOTH axes through ``core.bitmap``:  pair widths
through ``bucket_pad`` over ``PAIR_CHUNK_BUCKETS`` /
``NL_PAIR_CHUNK_BUCKETS`` and gather widths through ``nl_pad_len``
over ``NL_LEN_BUCKETS``.  Per-bucket autotuned chunk widths
(``chunk_width_for``) stay inside the same tables — autotuning changes
which bucket a chunk lands in, never introduces new shapes — so the
cache holds at most one entry per (width-bucket, op) pair regardless
of the width policy.  (Not asserted here: tests and the roofline
harness call these wrappers directly with arbitrary widths; the
discipline is the engines' contract, enforced by their use of
``bucket_pad``.)

Static-arg audit (ISSUE 10, rule DL003): every ``static_argnames``
entry in this module is a *bounded* static — ``mode`` / ``backend`` /
``early_stop`` are two- or three-valued enums fixed per engine run,
and ``lu`` / ``lv`` are gather widths already quantized through
``nl_pad_len`` onto ``NL_LEN_BUCKETS`` (so the value set is the bucket
table, not the data).  None is fed from a per-call-varying scalar —
that was exactly the PR 5 ``es_minsup`` bug (a traced threshold made
static doubled the cache and cost 1.17 s -> 0.04 s when fixed), and
``tools/devicelint`` now flags the pattern instead of reviewers.

Donation & pipelining (ISSUE 7): ``screen_and_intersect`` /
``screen_and_diff`` donate the rows/suffix slabs and ``nlist_scatter``
donates the codes slab.  The engines may keep several dispatches in
flight (the frontier scheduler's ring) — this is safe because each
dispatch consumes its operands *by value* at enqueue time and PJRT
sequences a donated buffer's aliasing after every outstanding read.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core.bitmap import popcount32 as _popcount32
from repro.core.bitmap import suffix_popcounts as _suffix_popcounts

from . import ref as _ref
from .bitmap_diff import bitmap_diff_es as _pallas_diff
from .bitmap_intersect import bitmap_intersect_es as _pallas_bitmap


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def bitmap_intersect_es(U, V, suffix_u, suffix_v, rho_parent, minsup,
                        *, mode: str = "and", backend: str = "auto",
                        ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                   jnp.ndarray, jnp.ndarray]:
    """Blocked early-stopping intersection.  See kernels/ref.py for the
    exact semantics.  Returns (Z, counts, blocks_done, alive)."""
    b = _resolve(backend)
    if b == "pallas":
        return _pallas_bitmap(U, V, suffix_u, suffix_v, rho_parent, minsup,
                              mode=mode, interpret=not _on_tpu())
    return _ref.bitmap_intersect_es_ref(U, V, suffix_u, suffix_v,
                                        rho_parent, minsup, mode=mode)


# ``es_minsup`` (the scan-abort threshold: the real minsup, or 0 = ES
# disabled) is a TRACED scalar, separate from the scatter-gate
# ``minsup``, so the ES-on and ES-off paths share one compiled kernel
# per shape — a static flag here would double every jit cache entry.
@functools.partial(jax.jit, static_argnames=("mode", "backend"),
                   donate_argnums=(0, 1))
def _screen_and_intersect_impl(rows, suffix, ua, vb, slots, rho_parent,
                               minsup, es_minsup, *, mode: str,
                               backend: str):
    U = jnp.take(rows, ua, axis=0)
    V = jnp.take(rows, vb, axis=0)
    su = jnp.take(suffix, ua, axis=0)
    sv = jnp.take(suffix, vb, axis=0)
    if backend == "pallas":
        Z, cnt, blocks, alive = _pallas_bitmap(
            U, V, su, sv, rho_parent, es_minsup, mode=mode,
            interpret=not _on_tpu())
    else:
        Z, cnt, blocks, alive = _ref.bitmap_intersect_es_ref(
            U, V, su, sv, rho_parent, es_minsup, mode=mode)
    # Survivor-only scatter (ISSUE 5): the count phase above completes
    # before the scatter phase, and gates it — non-survivors' slots are
    # redirected out of range so ``mode="drop"`` discards their writes
    # together with the pair padding.
    keep = _ref._survivor_mask(cnt, alive, rho_parent, minsup, mode=mode)
    slots_eff = jnp.where(keep, slots, jnp.int32(rows.shape[0]))
    child_suffix = _suffix_popcounts(Z)
    rows = rows.at[slots_eff].set(Z, mode="drop")
    suffix = suffix.at[slots_eff].set(child_suffix, mode="drop")
    return rows, suffix, cnt, blocks, alive


def screen_and_intersect(rows, suffix, ua, vb, slots, rho_parent, minsup,
                         *, mode: str = "and", early_stop: bool = True,
                         backend: str = "auto",
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                    jnp.ndarray, jnp.ndarray]:
    """Fused screen + blocked ES intersection over a device row store.

    One device dispatch per pair chunk: gathers operand rows/suffix tables
    by index from the store, runs the blocked early-stopping intersection
    (block-0 screen included — see ``ref.screen_and_intersect_ref``),
    computes child suffix-popcount tables on device and scatters both into
    the store at ``slots`` — **survivor-only**: a child row is written
    only when its support clears ``minsup`` (and, under ES, the pair
    finished its scan alive), so dead candidates cost zero scatter words.
    ``early_stop=False`` disables the in-scan abort but keeps the
    frequency gate (``minsup`` must always be the real threshold).

    ``rows``/``suffix`` buffers are DONATED: callers must replace their
    handles with the returned arrays.  Returns
    ``(rows, suffix, counts, blocks_done, alive)`` where
    ``rows[slots[i]]`` holds child ``Z_i`` for surviving pairs (bit-exact
    vs the ref) and ``suffix[slots[i]]`` its suffix table.  Slots of
    non-survivors and slots ``>= capacity`` (padding) are untouched.
    """
    b = _resolve(backend)
    minsup = jnp.asarray(minsup, jnp.int32)
    es_minsup = minsup if early_stop else jnp.int32(0)
    return _screen_and_intersect_impl(
        rows, suffix, jnp.asarray(ua, jnp.int32), jnp.asarray(vb, jnp.int32),
        jnp.asarray(slots, jnp.int32), jnp.asarray(rho_parent, jnp.int32),
        minsup, es_minsup, mode=mode, backend=b)


@functools.partial(jax.jit, static_argnames=("backend",),
                   donate_argnums=(0, 1))
def _screen_and_diff_impl(rows, suffix, ua, vb, slots, rho_parent,
                          minsup, es_minsup, *, backend: str):
    U = jnp.take(rows, ua, axis=0)
    V = jnp.take(rows, vb, axis=0)
    su = jnp.take(suffix, ua, axis=0)
    if backend == "pallas":
        Z, cnt, blocks, alive = _pallas_diff(
            U, V, su, rho_parent, es_minsup, interpret=not _on_tpu())
    else:
        Z, cnt, blocks, alive = _ref.bitmap_diff_es_ref(
            U, V, su, rho_parent, es_minsup)
    keep = _ref._survivor_mask(cnt, alive, rho_parent, minsup,
                               mode="andnot")
    slots_eff = jnp.where(keep, slots, jnp.int32(rows.shape[0]))
    child_suffix = _suffix_popcounts(Z)
    rows = rows.at[slots_eff].set(Z, mode="drop")
    suffix = suffix.at[slots_eff].set(child_suffix, mode="drop")
    return rows, suffix, cnt, blocks, alive


def screen_and_diff(rows, suffix, ua, vb, slots, rho_parent, minsup,
                    *, early_stop: bool = True, backend: str = "auto",
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                               jnp.ndarray, jnp.ndarray]:
    """Fused screen + blocked dEclat difference over a device row store
    (ISSUE 6) — the diffset sibling of :func:`screen_and_intersect` and
    the fourth ``evaluate_pairs`` dispatch behind the shared client
    protocol.

    One device dispatch per pair chunk: gathers the operand rows (and
    the U suffix table — the zero-block-skip mass source) by index,
    runs the blocked scan on the difference bound ``rho_parent - count``
    (block-0 screen included — see ``ref.screen_and_diff_ref``) and
    scatters surviving children ``Z = U & ~V`` plus their suffix tables
    into the store, survivor-only.  Feed it tidset operands and the
    scattered child is the level-2 diffset ``d(ab) = T(a) & ~T(b)``:
    the adaptive tidset→diffset flip rides the same dispatch.

    ``blocks_done`` charges only nonzero-mass U blocks (diffset sparsity
    is the win on dense data); counts/aliveness/results are bit-exact
    vs ``screen_and_intersect(mode="andnot")``.  Pinned by
    ``ref.screen_and_diff_ref`` on both backends.

    ``rows``/``suffix`` are DONATED: callers must replace their handles.
    Returns ``(rows, suffix, counts, blocks_done, alive)``.
    """
    b = _resolve(backend)
    minsup = jnp.asarray(minsup, jnp.int32)
    es_minsup = minsup if early_stop else jnp.int32(0)
    return _screen_and_diff_impl(
        rows, suffix, jnp.asarray(ua, jnp.int32), jnp.asarray(vb, jnp.int32),
        jnp.asarray(slots, jnp.int32), jnp.asarray(rho_parent, jnp.int32),
        minsup, es_minsup, backend=b)


@functools.lru_cache(maxsize=None)
def make_screen_and_intersect_sharded(mesh: Mesh,
                                      tid_axes: Tuple[str, ...] = (),
                                      mode: str = "and",
                                      early_stop: bool = True,
                                      cls_axes: Tuple[str, ...] = ()):
    """Build the fused sharded dispatch for ``mesh`` (ISSUE 2 tentpole;
    shard-local in-dispatch block ES added by ISSUE 4; 2-D
    ``(block, cls)`` candidate-class sharding added by ISSUE 9).

    Returns a jitted shard_map program
    ``fused(rows, suffix, ua, vb, slots, rho_parent, minsup,
    n_real_blocks=None) -> (rows, suffix, bound, count, blocks,
    alive)`` that is bit-exact against
    ``ref.screen_and_intersect_sharded_ref`` with ``n_shards`` = the
    product of ``tid_axes`` sizes and ``n_cls`` = the product of
    ``cls_axes`` sizes.  ``n_real_blocks`` is the unpadded
    block count: each shard's scan count is clamped to its real blocks
    so ``blocks`` (the word_ops numerator) never charges the all-zero
    pad tail the store adds to divide the shard count.  Layouts (``DeviceRowStore``
    sharded mode): ``rows uint32 (cap, nb, bw)`` block-sharded over
    ``tid_axes`` (replicated over ``cls_axes``); ``suffix int32
    (cap, n_shards*(nb_local+1))`` column-sharded so each block shard
    owns its local suffix table; pair index/rho vectors replicated
    over the block axes and **sharded over** ``cls_axes`` — each cls
    shard evaluates a disjoint contiguous slice of the chunk's pairs.

    One dispatch per pair chunk: gather operands from the block-sharded
    slab, psum the screen's per-pair slack over the **block axes only**
    (mode "and" with ES: one small ``int32[n_pairs / n_cls]``
    collective per cls shard), walk the local blocks with the
    shared blocked-ES scan against the conservative shard-local
    threshold ``minsup - slack`` (each shard aborts mid-scan exactly
    like the single-device path once it has *proven* the pair globally
    infrequent — see the ref docstring for the bound), then one fused
    psum of the per-shard ``(count, blocks, dead, screen-bound)``
    vectors — again over the block axes only, so the per-pair outputs
    come back ``cls``-sharded and the host sees the full chunk in pair
    order — and a **survivor-only** shard-local child scatter: the psum
    completes before the scatter phase and gates it, so candidates
    whose global support misses minsup (or that any shard aborted)
    cost zero scatter words.

    2-D scatter locality: each device writes only its *block* slice of
    each surviving child — scatter traffic never crosses block shards.
    Because the slab is replicated along ``cls``, the per-slice
    survivors ``(Z, slots, child suffix)`` are ``all_gather``-ed along
    the cls axes first (one tiled collective of the chunk's child rows
    per block-shard row of the mesh) so every cls replica performs the
    identical scatter and the slab stays replication-consistent.  The
    scan itself — the O(n_pairs * n_blocks * block_words) term — is
    split ``n_cls`` ways; the all-gather moves each child row once,
    which is the same order as the scatter it feeds.
    ``rows``/``suffix`` are DONATED: callers must replace their
    handles.
    """
    if mode not in ("and", "andnot"):
        raise ValueError(f"bad mode {mode!r}")
    cls_axes = tuple(cls_axes)
    tid_axes = (tuple(tid_axes) if tid_axes else
                tuple(a for a in mesh.axis_names if a not in cls_axes))
    if set(tid_axes) & set(cls_axes):
        raise ValueError(f"tid_axes {tid_axes} and cls_axes {cls_axes} "
                         f"overlap")
    tid_spec = tid_axes if len(tid_axes) > 1 else tid_axes[0]
    rows_spec = P(None, tid_spec, None)
    suffix_spec = P(None, tid_spec)
    n_cls = 1
    for ax in cls_axes:
        n_cls *= mesh.shape[ax]
    if cls_axes:
        vec = P(cls_axes if len(cls_axes) > 1 else cls_axes[0])
    else:
        vec = P(None)

    def fused(rows, suffix, ua, vb, slots, rho_parent, minsup, n_real):
        # Local shapes: rows (cap, nb_local, bw), suffix (cap, nb_local+1).
        n = ua.shape[0]
        U = jnp.take(rows, ua, axis=0)
        V = jnp.take(rows, vb, axis=0)
        su = jnp.take(suffix, ua, axis=0)
        sv = jnp.take(suffix, vb, axis=0)
        rho = rho_parent.astype(jnp.int32)
        minsup = jnp.asarray(minsup, jnp.int32)

        if not early_stop:
            thr = jnp.full((n,), jnp.iinfo(jnp.int32).min, jnp.int32)
        elif mode == "and":
            m = jnp.minimum(su[:, 0], sv[:, 0])     # local achievable mass
            slack = jax.lax.psum(m, tid_axes) - m   # every OTHER shard's
            thr = minsup - slack
        else:
            thr = jnp.broadcast_to(minsup, (n,))

        Z, cnt, blocks, alive = _ref._blocked_es_scan(
            U, V, su, sv, rho, thr, mode=mode)
        nbl = rows.shape[1]
        if mode == "andnot":
            # Diffset work counter (ISSUE 6): charge only the
            # *nonzero-mass* U blocks this shard's scan visited, like
            # the single-device ``_blocked_diff_scan`` — the scan's
            # ``blocks`` counts the alive-visited prefix, so
            # ``k < blocks`` marks visited blocks.  Pad blocks are
            # all-zero (zero mass), so they discount themselves and no
            # real-block clamp is needed.
            umass = su[:, :-1] - su[:, 1:]
            visited = (jnp.arange(nbl, dtype=jnp.int32)[None, :]
                       < blocks[:, None])
            blocks = jnp.logical_and(umass > 0, visited).sum(
                axis=1).astype(jnp.int32)
        else:
            # Discount this shard's all-zero pad tail from the scan
            # count (the store pads the block axis to the shard count;
            # pads never change counts or aliveness) so the psum'd
            # ``blocks`` — the word_ops numerator — is consistently
            # unpadded.
            sidx = jnp.int32(0)
            for ax in tid_axes:
                sidx = sidx * mesh.shape[ax] + jax.lax.axis_index(ax)
            real_local = jnp.clip(n_real.astype(jnp.int32) - sidx * nbl,
                                  0, nbl)
            blocks = jnp.minimum(blocks, real_local)
        zpc = _popcount32(Z).sum(axis=-1)           # (n, nb_local)
        c0 = zpc[:, 0]
        if mode == "and":
            bound_c = c0 + jnp.minimum(su[:, 1], sv[:, 1])
        else:
            bound_c = c0
        count, blocks, dead, bound = jax.lax.psum(
            (cnt, blocks, (~alive).astype(jnp.int32), bound_c), tid_axes)
        if mode == "andnot":
            bound = rho - bound
        alive_g = dead == 0

        # Survivor-only shard-local scatter (ISSUE 5): the psum above is
        # the extra in-dispatch dependency edge — every shard knows the
        # global count/alive before its scatter, so dead candidates'
        # child rows are never written (slots redirected out of range,
        # like the pair padding).
        keep = _ref._survivor_mask(count, alive_g, rho, minsup, mode=mode)
        slots_eff = jnp.where(keep, slots, jnp.int32(rows.shape[0]))
        child_suffix = jnp.concatenate(
            [jnp.cumsum(zpc[:, ::-1], axis=-1)[:, ::-1],
             jnp.zeros((zpc.shape[0], 1), jnp.int32)], axis=-1)
        if cls_axes:
            # 2-D mesh (ISSUE 9): the slab is replicated along cls, so
            # the scatter must be too.  Re-assemble the full chunk's
            # survivors from the per-slice results — one tiled
            # all_gather along the cls axes of each device's *local
            # block slice* of Z (traffic stays within a block-shard row
            # of the mesh; no data ever crosses block shards).  Slices
            # are contiguous in pair order, so the gathered chunk is in
            # the original pair order and every cls replica performs
            # the identical block-shard-local scatter.
            Z = jax.lax.all_gather(Z, cls_axes, axis=0, tiled=True)
            slots_eff = jax.lax.all_gather(slots_eff, cls_axes, axis=0,
                                           tiled=True)
            child_suffix = jax.lax.all_gather(child_suffix, cls_axes,
                                              axis=0, tiled=True)
        rows = rows.at[slots_eff].set(Z, mode="drop")
        suffix = suffix.at[slots_eff].set(child_suffix, mode="drop")
        return rows, suffix, bound, count, blocks, alive_g

    mapped = _shard_map(
        fused, mesh=mesh,
        in_specs=(rows_spec, suffix_spec, vec, vec, vec, vec, P(), P()),
        out_specs=(rows_spec, suffix_spec, vec, vec, vec, vec),
        check_rep=False)
    jitted = jax.jit(mapped, donate_argnums=(0, 1))

    def dispatch(rows, suffix, ua, vb, slots, rho_parent, minsup,
                 n_real_blocks=None):
        if n_real_blocks is None:       # no padding: every block is real
            n_real_blocks = rows.shape[1]
        ua = jnp.asarray(ua, jnp.int32)
        vb = jnp.asarray(vb, jnp.int32)
        slots = jnp.asarray(slots, jnp.int32)
        rho_parent = jnp.asarray(rho_parent, jnp.int32)
        pad = -int(ua.shape[0]) % n_cls
        if pad:
            # The pair chunk must divide the cls axes (shard_map splits
            # axis 0 evenly).  Engine chunks are bucket-padded already
            # (every ``PAIR_CHUNK_BUCKETS`` width divides the practical
            # cls counts); this is the safety net for direct callers.
            # Pad slots point past the slab so the writes drop.
            ua = jnp.concatenate([ua, jnp.zeros(pad, jnp.int32)])
            vb = jnp.concatenate([vb, jnp.zeros(pad, jnp.int32)])
            slots = jnp.concatenate(
                [slots, jnp.full(pad, jnp.int32(rows.shape[0]))])
            rho_parent = jnp.concatenate(
                [rho_parent, jnp.zeros(pad, jnp.int32)])
        return jitted(rows, suffix, ua, vb, slots, rho_parent,
                      jnp.asarray(minsup, jnp.int32),
                      jnp.asarray(n_real_blocks, jnp.int32))

    return dispatch


# No buffer donation here: compaction's whole point is that the output
# slab has a DIFFERENT (smaller) shape, so the input could never be
# reused in place anyway.
@functools.partial(jax.jit, static_argnames=("backend",))
def _compact_rows_impl(rows, suffix, perm, *, backend):
    if backend == "pallas":
        from .compact import compact_gather as _pg
        interp = not _on_tpu()
        return (_pg(rows, perm, interpret=interp),
                _pg(suffix, perm, interpret=interp))
    return (_ref.compact_gather_ref(rows, perm),
            _ref.compact_gather_ref(suffix, perm))


def compact_rows(rows, suffix, perm, *, backend: str = "auto",
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-store compaction: gather live rows + suffix tables to the
    front of a fresh (usually smaller) slab in ONE fused device dispatch.

    ``perm int32 (new_capacity,)`` maps destination slots to source
    slots; negative entries come up zeroed (free slots).  Bit-exact vs
    ``ref.compact_gather_ref`` on both backends.  ``rows``/``suffix``
    are replaced wholesale: callers must swap in the returned slabs."""
    b = _resolve(backend)
    return _compact_rows_impl(rows, suffix, jnp.asarray(perm, jnp.int32),
                              backend=b)


@functools.partial(jax.jit, static_argnames=("backend",))
def _compact_codes_impl(codes, perm, *, backend):
    if backend == "pallas":
        from .compact import compact_gather as _pg
        return _pg(codes, perm, interpret=not _on_tpu())
    return _ref.compact_gather_ref(codes, perm)


def compact_codes(codes, perm, *, backend: str = "auto") -> jnp.ndarray:
    """N-list pool compaction: repack live extents to the front of a
    fresh slab in ONE fused device dispatch (``perm`` carries the
    per-code source index; -1 = zero fill).  Bit-exact vs
    ``ref.compact_gather_ref`` on both backends."""
    b = _resolve(backend)
    return _compact_codes_impl(codes, jnp.asarray(perm, jnp.int32),
                               backend=b)


def bitmap_intersect_full(U, V, *, mode: str = "and",
                          backend: str = "auto"):
    """Fused full intersection (Z, counts) without block metrics."""
    del backend
    return _ref.bitmap_intersect_full_ref(U, V, mode=mode)


def bitmap_count(U, V, *, backend: str = "auto") -> jnp.ndarray:
    """Support counting without ES and without materialising Z."""
    # The jnp path is already a single fused AND+popcount+reduce; the
    # pallas path reuses the ES kernel with minsup=0 (never aborts).
    b = _resolve(backend)
    if b == "pallas":
        n_pairs, n_blocks, _ = U.shape
        zeros = jnp.zeros((n_pairs, n_blocks + 1), jnp.int32)
        rho = jnp.zeros((n_pairs,), jnp.int32)
        _, cnt, _, _ = _pallas_bitmap(U, V, zeros, zeros, rho,
                                      jnp.int32(0), mode="and",
                                      interpret=not _on_tpu())
        return cnt
    return _ref.bitmap_count_ref(U, V)


def screen_pairs(first_u, first_v, suffix1_u, suffix1_v, rho_parent, minsup,
                 *, mode: str = "and", backend: str = "auto"):
    """One-block screening bound (inter-call early stopping)."""
    del backend  # single cheap fused op; jnp path is optimal everywhere
    return _ref.screen_pairs_ref(first_u, first_v, suffix1_u, suffix1_v,
                                 rho_parent, minsup, mode=mode)


def flash_attention(q, k, v, *, causal: bool = True, softmax_scale=None,
                    backend: str = "auto"):
    """Fused attention: Pallas kernel on TPU, dense ref elsewhere."""
    b = _resolve(backend)
    if b == "pallas":
        from .flash_attention import flash_attention as _fa
        return _fa(q, k, v, causal=causal, softmax_scale=softmax_scale,
                   interpret=not _on_tpu())
    return _ref.flash_attention_ref(q, k, v, causal=causal,
                                    softmax_scale=softmax_scale)


def embedding_bag(table, ids, mask, *, combiner: str = "mean",
                  backend: str = "auto"):
    """Fused EmbeddingBag: Pallas on TPU, take+reduce elsewhere."""
    b = _resolve(backend)
    if b == "pallas":
        from .segment_embed import embedding_bag as _eb
        return _eb(table, ids, mask, combiner=combiner,
                   interpret=not _on_tpu())
    return _ref.embedding_bag_ref(table, ids, mask, combiner=combiner)


def nlist_intersect(u_pre, u_post, u_freq, v_pre, v_post, v_freq,
                    u_len, v_len, rho_v, minsup, *, early_stop: bool = True,
                    backend: str = "auto"):
    """Batched padded N-list merge (kernel micro-bench entry point).

    The mining hot path uses :func:`nlist_extend` — this standalone
    variant takes host-materialised padded batches."""
    b = _resolve(backend)
    if b == "pallas":
        from .nlist_merge import nlist_merge as _pallas_merge
        return _pallas_merge(u_pre, u_post, u_freq, v_pre, v_post, v_freq,
                             u_len, v_len, rho_v, minsup,
                             early_stop=early_stop,
                             interpret=not _on_tpu())
    return _ref.nlist_intersect_ref(u_pre, u_post, u_freq,
                                    v_pre, v_post, v_freq,
                                    u_len, v_len, rho_v, minsup,
                                    early_stop=early_stop)


def _nl_merge_backend(codes, u_off, u_len, v_off, v_len, rho_v, minsup,
                      *, lu, lv, early_stop, backend):
    """Shared gather + two-pointer-merge body of the N-list dispatches."""
    u_pre, u_post, u_freq = _ref._nl_gather(codes, u_off, u_len, lu)
    v_pre, v_post, v_freq = _ref._nl_gather(codes, v_off, v_len, lv)
    if backend == "pallas":
        from .nlist_merge import nlist_merge as _pallas_merge
        merged = _pallas_merge(
            u_pre, u_post, u_freq, v_pre, v_post, v_freq,
            u_len, v_len, rho_v, minsup, early_stop=early_stop,
            interpret=not _on_tpu())
    else:
        merged = _ref._nl_merge_vmapped(
            u_pre, u_post, u_freq, v_pre, v_post, v_freq,
            u_len, v_len, rho_v, minsup, early_stop=early_stop)
    return merged, u_freq, v_pre, v_post


@functools.partial(jax.jit,
                   static_argnames=("lu", "lv", "early_stop", "backend"),
                   donate_argnums=(0,))
def _nlist_extend_impl(codes, u_off, u_len, v_off, v_len, out_off, rho_v,
                       minsup, *, lu, lv, early_stop, backend):
    merged, u_freq, v_pre, v_post = _nl_merge_backend(
        codes, u_off, u_len, v_off, v_len, rho_v, minsup,
        lu=lu, lv=lv, early_stop=early_stop, backend=backend)
    out_slot, support, cmps, checks, alive = merged
    # Survivor-only scatter: aborted pairs report support 0, so one
    # frequency gate covers both ES deaths and plain infrequency.
    keep = support >= minsup
    out_off_eff = jnp.where(keep, out_off, jnp.int32(codes.shape[0]))
    codes, child_len = _ref._nl_zmerge_scatter(
        codes, out_slot, u_freq, v_pre, v_post, out_off_eff)
    return codes, child_len, support, cmps, checks, alive


def nlist_extend(codes, u_off, u_len, v_off, v_len, out_off, rho_v, minsup,
                 *, lu: int, lv: int, early_stop: bool = True,
                 backend: str = "auto"):
    """Fused PrePost+ class extension over a device N-list pool.

    The N-list analogue of :func:`screen_and_intersect` (one dispatch per
    pair chunk): gathers both operand N-lists from the ``codes`` slab by
    extent offset, runs the two-pointer merge with the
    ``z_mass + (rho_V - skip)`` ES guard (bit-exact vs
    ``ref.nlist_extend_ref``, comparison counts exactly the oracle's),
    Z-merges consecutive same-ancestor slots on device and scatters the
    compacted child N-lists back into the pool at ``out_off`` — no host
    N-list materialisation between levels.  The scatter is
    **survivor-only** (ISSUE 5): pairs whose support misses minsup write
    nothing.  The mining hot path uses the two-dispatch split
    (:func:`nlist_presize` + :func:`nlist_scatter`) for exact-length
    extents; this one-dispatch form remains the micro-bench API.

    ``codes`` is DONATED: callers must replace their handle with the
    returned slab.  Returns
    ``(codes, child_len, support, comparisons, checks, alive)``.
    """
    b = _resolve(backend)
    return _nlist_extend_impl(
        codes, jnp.asarray(u_off, jnp.int32), jnp.asarray(u_len, jnp.int32),
        jnp.asarray(v_off, jnp.int32), jnp.asarray(v_len, jnp.int32),
        jnp.asarray(out_off, jnp.int32), jnp.asarray(rho_v, jnp.int32),
        jnp.asarray(minsup, jnp.int32), lu=lu, lv=lv,
        early_stop=early_stop, backend=b)


@functools.partial(jax.jit,
                   static_argnames=("lu", "lv", "early_stop", "backend"))
def _nlist_presize_impl(codes, u_off, u_len, v_off, v_len, rho_v,
                        minsup, *, lu, lv, early_stop, backend):
    merged, _, _, _ = _nl_merge_backend(
        codes, u_off, u_len, v_off, v_len, rho_v, minsup,
        lu=lu, lv=lv, early_stop=early_stop, backend=backend)
    out_slot, support, cmps, checks, alive = merged
    _, _, child_len = _ref._nl_group_starts(out_slot)
    return out_slot, child_len, support, cmps, checks, alive


def nlist_presize(codes, u_off, u_len, v_off, v_len, rho_v, minsup,
                  *, lu: int, lv: int, early_stop: bool = True,
                  backend: str = "auto"):
    """Merge-only pre-pass of the two-dispatch PrePost+ extension
    (ISSUE 5 tentpole; pinned by ``ref.nlist_presize_ref``).

    Runs the gather + two-pointer ES merge and the Z-merge group count
    but NO scatter: the host learns each candidate's exact child length,
    support and aliveness, allocates tight extents for the survivors
    only, and hands the device-resident ``out_slot`` match table to
    :func:`nlist_scatter` — the merge loop runs exactly once per
    candidate, and the pool never holds a pessimistic
    ``min(|U|, |V|)`` extent again.  ``codes`` is NOT donated (the
    pre-pass only reads the slab).

    Returns ``(out_slot, child_len, support, comparisons, checks,
    alive)``."""
    b = _resolve(backend)
    return _nlist_presize_impl(
        codes, jnp.asarray(u_off, jnp.int32), jnp.asarray(u_len, jnp.int32),
        jnp.asarray(v_off, jnp.int32), jnp.asarray(v_len, jnp.int32),
        jnp.asarray(rho_v, jnp.int32), jnp.asarray(minsup, jnp.int32),
        lu=lu, lv=lv, early_stop=early_stop, backend=b)


@functools.partial(jax.jit, static_argnames=("lu", "lv"),
                   donate_argnums=(0,))
def _nlist_scatter_impl(codes, out_slot, u_off, u_len, v_off, v_len,
                        out_off, *, lu, lv):
    _, _, u_freq = _ref._nl_gather(codes, u_off, u_len, lu)
    v_pre, v_post, _ = _ref._nl_gather(codes, v_off, v_len, lv)
    return _ref._nl_zmerge_scatter(codes, out_slot, u_freq, v_pre, v_post,
                                   out_off)


def nlist_scatter(codes, out_slot, u_off, u_len, v_off, v_len, out_off,
                  *, lu: int, lv: int, backend: str = "auto"):
    """Scatter pass of the two-dispatch PrePost+ extension (pinned by
    ``ref.nlist_scatter_ref``).

    Re-gathers the operand codes (no merge loop), Z-merges the
    :func:`nlist_presize` match table and scatters the compacted child
    N-lists into their tight extents at ``out_off``; callers pass
    ``out_off >= capacity`` for non-survivors and padding, which makes
    the scatter survivor-only by construction.  Gather/Z-merge/scatter
    are pure vectorized jnp on every backend.  ``codes`` is DONATED:
    callers must replace their handle.  Returns ``(codes, child_len)``.
    """
    del backend
    return _nlist_scatter_impl(
        codes, jnp.asarray(out_slot, jnp.int32),
        jnp.asarray(u_off, jnp.int32), jnp.asarray(u_len, jnp.int32),
        jnp.asarray(v_off, jnp.int32), jnp.asarray(v_len, jnp.int32),
        jnp.asarray(out_off, jnp.int32), lu=lu, lv=lv)
