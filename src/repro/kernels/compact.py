"""Pallas TPU kernel: allocator compaction gather (ISSUE 4).

Both device allocators (``core.rowstore.DeviceRowStore`` and
``core.rowstore.NListPool``) defragment by gathering their live rows /
extents to the front of a smaller slab.  The destination side is
contiguous, so the whole compaction is ONE gather indexed by a
host-built ``perm`` vector: ``out[i] = slab[perm[i]]`` (``perm[i] < 0``
means destination slot ``i`` comes up zeroed/free).

Grid/layout
-----------
grid = (new_capacity,) — one program per destination row.  ``perm`` is a
scalar-prefetch operand (``PrefetchScalarGridSpec``), so the input
BlockSpec's index_map can steer the DMA: program ``i`` pulls source row
``clip(perm[i], 0, cap-1)`` into VMEM and writes it to destination row
``i``, masking to zeros when ``perm[i] < 0``.  One row is
``slab.shape[1:]`` — ``(n_blocks, block_words)`` uint32 for bitmap rows,
``(n_shards*(nb_local+1),)`` int32 for suffix tables, ``(3,)`` int32 for
PPC-code triples — small enough that a row is always far under VMEM.

Semantics are defined by ``kernels/ref.py::compact_gather_ref`` and must
match it bit-for-bit (tests/test_kernels.py sweeps slab ranks, dtypes
and dead-slot patterns).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(perm_ref, slab_ref, out_ref):
    i = pl.program_id(0)
    live = perm_ref[i] >= 0
    blk = slab_ref[...]
    out_ref[...] = jnp.where(live, blk, jnp.zeros_like(blk))


@functools.partial(jax.jit, static_argnames=("interpret",))
def compact_gather(slab: jnp.ndarray, perm: jnp.ndarray, *,
                   interpret: bool = True) -> jnp.ndarray:
    """Pallas compaction gather: ``out[i] = slab[perm[i]]`` or zeros.

    ``slab`` is any (capacity, ...) device slab; ``perm int32
    (new_capacity,)`` maps destination to source rows (-1 = zero fill).
    ``interpret=True`` (the CPU default) runs the kernel body in the
    Pallas interpreter for validation; on TPU pass ``interpret=False``.
    """
    cap = slab.shape[0]
    n_out = perm.shape[0]
    trailing = slab.shape[1:]
    rank = len(trailing)
    zeros = (0,) * rank

    def in_map(i, perm_ref):
        return (jnp.clip(perm_ref[i], 0, cap - 1),) + zeros

    def out_map(i, perm_ref):
        del perm_ref
        return (i,) + zeros

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_out,),
        in_specs=[pl.BlockSpec((1,) + trailing, in_map)],
        out_specs=pl.BlockSpec((1,) + trailing, out_map),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out,) + trailing, slab.dtype),
        interpret=interpret,
    )(jnp.asarray(perm, jnp.int32), slab)
