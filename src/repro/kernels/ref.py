"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the exact semantics its kernel must
reproduce bit-for-bit (integer kernels) or within tolerance (float
kernels).  The refs are also the *production CPU path*: on hosts without
a TPU the miners and models call these (they are fully vectorized jnp),
while ``ops.py`` routes to the Pallas kernels on TPU.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.bitmap import (popcount32, suffix_popcounts,
                               NL_SENTINEL as _NL)

# ---------------------------------------------------------------------------
# Blocked early-stopping bitmap intersection (Eclat "and" / dEclat "andnot")
# ---------------------------------------------------------------------------
#
# Semantics (shared with kernels/bitmap_intersect.py):
#   * blocks are processed in order; a pair is "alive" until its ES bound
#     drops below minsup;
#   * block k's output/count/work are produced iff the pair is alive at the
#     START of block k;
#   * counts freeze at death (a dead pair is *provably* infrequent, its
#     partial count is never interpreted as a support);
#   * mode "and":    Z = U & V,  bound_k = count_k + min(sufU[k+1], sufV[k+1])
#   * mode "andnot": Z = U & ~V, bound_k = rho_parent - count_k
#     (dEclat: support(Pxy) = rho(Px) - |D(Pxy)| decreases as diffs emit)
#   * minsup <= 0 disables early stopping (the non-ES baselines).


def _blocked_es_scan(U, V, suffix_u, suffix_v, rho_parent, thr, *,
                     mode: str):
    """Shared blocked-ES scan with a PER-PAIR threshold vector.

    ``thr int32 (n_pairs,)``: a pair dies when its running bound drops
    below its own threshold.  The single-device path passes the
    broadcast scalar minsup; the sharded path passes the conservative
    shard-local threshold ``minsup - slack`` derived from the screen's
    per-pair slack (see ``screen_and_intersect_sharded_ref``).  A
    threshold of INT32_MIN never kills (bounds are >= 0): that is the
    ES-disabled path.  Returns ``(Z, counts, blocks_done, alive)``."""
    if mode not in ("and", "andnot"):
        raise ValueError(f"bad mode {mode!r}")
    n_pairs = U.shape[0]
    thr = jnp.asarray(thr, jnp.int32)

    u_t = jnp.swapaxes(U, 0, 1)                     # (nb, n_pairs, bw)
    v_t = jnp.swapaxes(V, 0, 1)
    su_next = jnp.swapaxes(suffix_u[:, 1:], 0, 1)   # (nb, n_pairs)
    sv_next = jnp.swapaxes(suffix_v[:, 1:], 0, 1)

    def step(carry, xs):
        cnt, alive, blocks = carry
        u_k, v_k, su_k, sv_k = xs
        z_k = u_k & (v_k if mode == "and" else ~v_k)
        pc = popcount32(z_k).sum(axis=-1)
        cnt_new = jnp.where(alive, cnt + pc, cnt)
        blocks = blocks + alive.astype(jnp.int32)
        if mode == "and":
            bound = cnt_new + jnp.minimum(su_k, sv_k)
        else:
            bound = rho_parent.astype(jnp.int32) - cnt_new
        alive_new = jnp.logical_and(alive, bound >= thr)
        z_out = jnp.where(alive[:, None], z_k, jnp.uint32(0))
        return (cnt_new, alive_new, blocks), z_out

    init = (jnp.zeros((n_pairs,), jnp.int32),
            jnp.ones((n_pairs,), jnp.bool_),
            jnp.zeros((n_pairs,), jnp.int32))
    (cnt, alive, blocks), z_stack = jax.lax.scan(
        step, init, (u_t, v_t, su_next, sv_next))
    Z = jnp.swapaxes(z_stack, 0, 1)
    return Z, cnt, blocks, alive


@functools.partial(jax.jit, static_argnames=("mode",))
def bitmap_intersect_es_ref(
    U: jnp.ndarray,            # uint32 (n_pairs, n_blocks, bw)
    V: jnp.ndarray,            # uint32 (n_pairs, n_blocks, bw)
    suffix_u: jnp.ndarray,     # int32  (n_pairs, n_blocks + 1)
    suffix_v: jnp.ndarray,     # int32  (n_pairs, n_blocks + 1)
    rho_parent: jnp.ndarray,   # int32  (n_pairs,)  (used by "andnot")
    minsup: jnp.ndarray,       # int32  scalar
    *,
    mode: str = "and",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (Z, counts, blocks_done, alive_final)."""
    n_pairs = U.shape[0]
    thr = jnp.broadcast_to(jnp.asarray(minsup, jnp.int32), (n_pairs,))
    return _blocked_es_scan(U, V, suffix_u, suffix_v, rho_parent, thr,
                            mode=mode)


# ---------------------------------------------------------------------------
# Blocked diffset difference with zero-block skipping (dEclat, ISSUE 6)
# ---------------------------------------------------------------------------
#
# The dedicated diffset scan shares Z/count/alive semantics with
# ``_blocked_es_scan(mode="andnot")`` bit-for-bit; only the *work
# counter* differs.  ``Z = U & ~V`` is identically zero on any block
# where the U operand has no set bits, and diffset rows are exactly the
# operands that go sparse on dense data (|d| = sup(parent) - sup(child)
# shrinks as the class deepens), so a diffset engine skips those blocks
# outright — the per-block U mass is free from the suffix table
# (``su[k] - su[k+1]``).  ``blocks_done`` therefore counts only the
# *nonzero-mass* blocks a live pair visits: that is the word_ops
# numerator, the device analogue of the paper's #comparisons for the
# DIFFERENCE_ES path.  Zero-mass blocks contribute no popcount and
# cannot change the bound, so skipping them never perturbs counts,
# aliveness or the scatter.


def _blocked_diff_scan(U, V, suffix_u, rho_parent, thr):
    """Blocked dEclat difference scan with a PER-PAIR threshold vector.

    ``thr int32 (n_pairs,)``: a pair dies when its running difference
    bound ``rho_parent - count`` drops below its own threshold
    (``sup(Pxy) = rho(Px) - |D(Pxy)|`` only decreases as diff words
    emit).  In valid mining ``count <= rho_parent`` always, so a
    threshold of 0 never kills: that is the ES-disabled path.  Returns
    ``(Z, counts, blocks_done, alive)`` where ``blocks_done`` is the
    skip-aware work counter documented above."""
    n_pairs = U.shape[0]
    thr = jnp.asarray(thr, jnp.int32)
    rho = rho_parent.astype(jnp.int32)

    u_t = jnp.swapaxes(U, 0, 1)                     # (nb, n_pairs, bw)
    v_t = jnp.swapaxes(V, 0, 1)
    mass = (suffix_u[:, :-1] - suffix_u[:, 1:]).astype(jnp.int32)
    m_t = jnp.swapaxes(mass, 0, 1)                  # (nb, n_pairs)

    def step(carry, xs):
        cnt, alive, blocks = carry
        u_k, v_k, m_k = xs
        z_k = u_k & ~v_k
        pc = popcount32(z_k).sum(axis=-1)
        cnt_new = jnp.where(alive, cnt + pc, cnt)
        blocks = blocks + jnp.logical_and(alive, m_k > 0).astype(jnp.int32)
        bound = rho - cnt_new
        alive_new = jnp.logical_and(alive, bound >= thr)
        z_out = jnp.where(alive[:, None], z_k, jnp.uint32(0))
        return (cnt_new, alive_new, blocks), z_out

    init = (jnp.zeros((n_pairs,), jnp.int32),
            jnp.ones((n_pairs,), jnp.bool_),
            jnp.zeros((n_pairs,), jnp.int32))
    (cnt, alive, blocks), z_stack = jax.lax.scan(
        step, init, (u_t, v_t, m_t))
    Z = jnp.swapaxes(z_stack, 0, 1)
    return Z, cnt, blocks, alive


@jax.jit
def bitmap_diff_es_ref(
    U: jnp.ndarray,            # uint32 (n_pairs, n_blocks, bw)
    V: jnp.ndarray,            # uint32 (n_pairs, n_blocks, bw)
    suffix_u: jnp.ndarray,     # int32  (n_pairs, n_blocks + 1)
    rho_parent: jnp.ndarray,   # int32  (n_pairs,)
    minsup: jnp.ndarray,       # int32  scalar; <= 0 disables ES
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Blocked dEclat difference ``Z = U & ~V`` on the difference bound
    ``rho_parent - count`` with zero-block skipping.  Z/count/alive are
    bit-identical to ``bitmap_intersect_es_ref(mode="andnot")``; only
    ``blocks_done`` differs (it skips zero-mass U blocks).  Returns
    ``(Z, counts, blocks_done, alive_final)``."""
    n_pairs = U.shape[0]
    thr = jnp.broadcast_to(jnp.asarray(minsup, jnp.int32), (n_pairs,))
    return _blocked_diff_scan(U, V, suffix_u, rho_parent, thr)


def _survivor_mask(cnt, alive, rho_parent, minsup, *, mode: str):
    """The scatter gate shared by every fused dispatch (ISSUE 5).

    A pair's child is materialised iff its exact support clears minsup
    AND it finished its scan alive — a dead pair's count is a frozen
    partial, which in "andnot" mode *overestimates* the support
    (``rho - cnt``), so aliveness is load-bearing, not an optimisation.
    With ES disabled ``alive`` is identically True and the mask reduces
    to plain frequency."""
    support = cnt if mode == "and" else rho_parent.astype(jnp.int32) - cnt
    return jnp.logical_and(alive, support >= jnp.asarray(minsup, jnp.int32))


@functools.partial(jax.jit, static_argnames=("mode", "early_stop"))
def screen_and_intersect_ref(
    rows: jnp.ndarray,         # uint32 (capacity, n_blocks, bw) row store
    suffix: jnp.ndarray,       # int32  (capacity, n_blocks + 1)
    ua: jnp.ndarray,           # int32  (n_pairs,)  U operand row indices
    vb: jnp.ndarray,           # int32  (n_pairs,)  V operand row indices
    slots: jnp.ndarray,        # int32  (n_pairs,)  child dest rows (OOB drop)
    rho_parent: jnp.ndarray,   # int32  (n_pairs,)
    minsup: jnp.ndarray,       # int32  scalar (ES threshold AND scatter gate)
    *,
    mode: str = "and",
    early_stop: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """Fused screen + blocked ES intersection over a device row store —
    the full single-device dispatch oracle, scatter included.

    Operands are *gathered by row index* from ``rows``/``suffix`` instead of
    being materialised by the host.  The one-block screen of the old
    two-dispatch path is exactly the ``k = 0`` iteration of the blocked ES
    scan — after block 0 the running bound equals the screen bound
    ``|U0 op V0| (+ min(sufU[1], sufV[1]) | rho - c0)`` — so fusing them
    changes the dispatch count, never the semantics: a screened-out pair is
    simply one that dies with ``blocks_done == 1``.

    The child scatter is **survivor-only** (ISSUE 5): the count phase of
    the dispatch completes first and gates the scatter phase — a child
    row and its suffix table are written at ``slots[i]`` only when pair
    ``i``'s support clears ``minsup`` (and, under ES, it finished its
    scan alive).  Dead candidates cost zero scatter words; their slots
    (and slots ``>= capacity`` — pair padding) are left untouched.
    ``early_stop=False`` disables the in-scan abort but NOT the
    frequency gate.

    Returns ``(rows, suffix, counts, blocks_done, alive)``.
    """
    U = jnp.take(rows, ua, axis=0)
    V = jnp.take(rows, vb, axis=0)
    su = jnp.take(suffix, ua, axis=0)
    sv = jnp.take(suffix, vb, axis=0)
    es_minsup = minsup if early_stop else jnp.int32(0)
    Z, cnt, blocks, alive = bitmap_intersect_es_ref(
        U, V, su, sv, rho_parent, es_minsup, mode=mode)
    keep = _survivor_mask(cnt, alive, rho_parent, minsup, mode=mode)
    cap = rows.shape[0]
    slots_eff = jnp.where(keep, slots, jnp.int32(cap))
    child_suffix = suffix_popcounts(Z)
    rows = rows.at[slots_eff].set(Z, mode="drop")
    suffix = suffix.at[slots_eff].set(child_suffix, mode="drop")
    return rows, suffix, cnt, blocks, alive


@functools.partial(jax.jit, static_argnames=("early_stop",))
def screen_and_diff_ref(
    rows: jnp.ndarray,         # uint32 (capacity, n_blocks, bw) row store
    suffix: jnp.ndarray,       # int32  (capacity, n_blocks + 1)
    ua: jnp.ndarray,           # int32  (n_pairs,)  U operand row indices
    vb: jnp.ndarray,           # int32  (n_pairs,)  V operand row indices
    slots: jnp.ndarray,        # int32  (n_pairs,)  child dest rows (OOB drop)
    rho_parent: jnp.ndarray,   # int32  (n_pairs,)  parent support
    minsup: jnp.ndarray,       # int32  scalar (ES threshold AND scatter gate)
    *,
    early_stop: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """Fused screen + blocked dEclat difference over a device row store —
    the diffset dispatch oracle (ISSUE 6), scatter included.

    The diffset sibling of :func:`screen_and_intersect_ref`: operands are
    gathered by row index, the blocked scan runs on the *difference*
    bound ``rho_parent - count`` (block 0 IS the one-block screen — the
    bound after block 0 equals the screen bound ``rho - |U0 & ~V0|``),
    and the child scatter is survivor-only under exactly the same mask.
    Because ``support = rho - count`` the very same dispatch converts a
    tidset subtree to diffsets: pass tidset operands ``U = T(a),
    V = T(b)`` and the scattered child ``Z = T(a) & ~T(b)`` is the
    level-2 diffset ``d(ab)`` (complement against the parent), so an
    adaptive representation flip costs no extra round trip.

    ``blocks_done`` is the skip-aware work counter of
    :func:`bitmap_diff_es_ref`: only *nonzero-mass* U blocks a live pair
    visits are charged (zero-mass blocks can never change the output —
    that sparsity is exactly why diffsets win on dense data).
    Z/count/alive — and therefore the result set — stay bit-identical
    to ``screen_and_intersect_ref(mode="andnot")`` on the same
    operands.

    Returns ``(rows, suffix, counts, blocks_done, alive)``.
    """
    U = jnp.take(rows, ua, axis=0)
    V = jnp.take(rows, vb, axis=0)
    su = jnp.take(suffix, ua, axis=0)
    es_minsup = minsup if early_stop else jnp.int32(0)
    Z, cnt, blocks, alive = bitmap_diff_es_ref(
        U, V, su, rho_parent, es_minsup)
    keep = _survivor_mask(cnt, alive, rho_parent, minsup, mode="andnot")
    cap = rows.shape[0]
    slots_eff = jnp.where(keep, slots, jnp.int32(cap))
    child_suffix = suffix_popcounts(Z)
    rows = rows.at[slots_eff].set(Z, mode="drop")
    suffix = suffix.at[slots_eff].set(child_suffix, mode="drop")
    return rows, suffix, cnt, blocks, alive


@functools.partial(jax.jit,
                   static_argnames=("n_shards", "n_cls", "mode",
                                    "early_stop"))
def screen_and_intersect_sharded_ref(
    rows: jnp.ndarray,         # uint32 (capacity, n_blocks, bw) row store
    suffix: jnp.ndarray,       # int32  (capacity, n_shards*(nb_local+1))
    ua: jnp.ndarray,           # int32  (n_pairs,)  U operand row indices
    vb: jnp.ndarray,           # int32  (n_pairs,)  V operand row indices
    slots: jnp.ndarray,        # int32  (n_pairs,)  child dest rows (OOB drop)
    rho_parent: jnp.ndarray,   # int32  (n_pairs,)  parent support ("andnot")
    minsup: jnp.ndarray,       # int32  scalar (in-dispatch ES threshold)
    n_real_blocks=None,        # int32  scalar: unpadded block count
    *,
    n_shards: int,
    n_cls: int = 1,
    mode: str = "and",
    early_stop: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray, jnp.ndarray]:
    """Oracle for the sharded fused dispatch (ISSUE 2 unification,
    in-dispatch block ES added by ISSUE 4).

    Pins the exact semantics ``ops.make_screen_and_intersect_sharded``
    must reproduce bit-for-bit when the block axis of ``rows`` is sharded
    into ``n_shards`` contiguous shards of ``nb_local = n_blocks //
    n_shards`` blocks each, and ``suffix`` holds the per-shard local
    suffix tables concatenated along axis 1 (shard ``s`` owns columns
    ``[s*(nbl+1), (s+1)*(nbl+1))`` — ``DeviceRowStore``'s sharded
    layout).  One dispatch per pair chunk computes, per pair:

    * ``count`` — the psum of per-shard popcounts of ``Z = U op V``
      (the exact global support whenever the pair stayed alive);
    * ``bound`` — the *two-level distributed screen*: each shard refines
      with its own block 0, so the global bound is the psum of per-shard
      one-block bounds — mode "and":
      ``sum_s (|U0_s op V0_s| + min(sufU_s[1], sufV_s[1]))``
      (sum of per-shard minima <= minimum of sums: tighter than the
      centralized screen), mode "andnot": ``rho_parent - sum_s |U0_s &
      ~V0_s|``;
    * **shard-local block ES** (``early_stop=True``): each shard walks
      its local blocks with the shared blocked-ES scan, but against the
      conservative threshold ``thr_s = minsup - slack_s`` where
      ``slack_s = sum_{s' != s} min(sufU_s'[0], sufV_s'[0])`` is the
      screen's per-pair slack — the mass every OTHER shard could still
      contribute.  A shard whose local bound drops below ``thr_s`` has
      *proven* the pair globally infrequent and stops scanning
      mid-dispatch (the sharded instantiation of the paper's
      INTERSECT_ES); its count freezes and the blocks past the abort
      point scatter as zeros.  For "andnot" the local bound
      ``rho_parent - cnt_s`` already dominates the global support, so
      ``thr_s = minsup`` with no slack term.

    and scatters the child rows plus their per-shard suffix tables into
    the store at ``slots`` — **survivor-only** (ISSUE 5): the psum'd
    count/alive phase of the dispatch completes first and gates the
    shard-local scatter phase, so a child is written only when its
    exact global support clears minsup and every shard finished its
    scan alive.  A pair whose ``bound`` misses minsup, or that any
    shard aborted, is provably infrequent: it costs zero scatter words
    and the host never materialises its class (its slot, like slots
    ``>= capacity`` — pair padding — is left untouched).

    Returns ``(rows, suffix, bound, count, blocks, alive)`` where
    ``blocks`` is the total *real* local blocks scanned across shards —
    the distributed word-op numerator.  The store pads its block axis up
    to the shard count, and a viable pair scans its shard's all-zero
    pad tail (pads can never change counts or aliveness: their operand
    mass is zero); ``n_real_blocks`` (default: no padding) lets the
    dispatch clamp each shard's scan count to its real blocks, so
    ``word_ops`` and ``word_ops_full`` are consistently unpadded and
    an ES-off run reports exactly ``word_ops == word_ops_full``.
    In mode "andnot" ``blocks`` is instead the skip-aware diffset work
    counter (ISSUE 6): only the *nonzero-mass* U blocks each shard's
    scan visited are charged, matching :func:`bitmap_diff_es_ref` —
    pads are zero-mass, so they discount themselves.
    ``alive`` is True iff every shard finished its scan alive.

    2-D ``(block, cls)`` mesh (ISSUE 9): ``n_cls`` pins the cls-split
    semantics.  The pair chunk is cut into ``n_cls`` *contiguous*
    slices (``n_pairs`` must divide); slice ``c`` is evaluated by cls
    shard ``c`` over every block shard, with the slack/count psums
    running over the **block axis only** — the per-pair math never
    crosses a slice boundary, so every per-pair output (bound, count,
    blocks, alive) and every scattered child is bit-for-bit identical
    to the ``n_cls=1`` run.  That invariance IS the contract: this ref
    evaluates the slices separately and concatenates, so a 2-D
    ``ops.make_screen_and_intersect_sharded`` program that all-gathers
    its per-slice survivors along ``cls`` before the block-shard-local
    scatter is pinned against it at any mesh shape.
    """
    if mode not in ("and", "andnot"):
        raise ValueError(f"bad mode {mode!r}")
    n_pairs = ua.shape[0]
    cap, nb, bw = rows.shape
    nbl = nb // n_shards
    minsup = jnp.asarray(minsup, jnp.int32)
    if n_cls < 1 or n_pairs % n_cls:
        raise ValueError(
            f"pair chunk of {n_pairs} does not divide n_cls={n_cls}")
    if n_real_blocks is None:
        n_real_blocks = nb

    def eval_slice(ua_s, vb_s, rho_s):
        """One cls shard's pair slice: everything up to (but excluding)
        the scatter, exactly the 1-D per-pair math."""
        n_loc = ua_s.shape[0]
        U = jnp.take(rows, ua_s, axis=0).reshape(n_loc, n_shards, nbl, bw)
        V = jnp.take(rows, vb_s, axis=0).reshape(n_loc, n_shards, nbl, bw)
        su = jnp.take(suffix, ua_s, axis=0).reshape(n_loc, n_shards,
                                                    nbl + 1)
        sv = jnp.take(suffix, vb_s, axis=0).reshape(n_loc, n_shards,
                                                    nbl + 1)

        if not early_stop:
            thr = jnp.full((n_loc, n_shards), jnp.iinfo(jnp.int32).min,
                           jnp.int32)
        elif mode == "and":
            m = jnp.minimum(su[:, :, 0], sv[:, :, 0])  # (n, S) local mass
            slack = m.sum(axis=1, keepdims=True) - m   # psum(m, block) - m
            thr = minsup - slack
        else:
            thr = jnp.broadcast_to(minsup, (n_loc, n_shards))

        flat = (n_loc * n_shards,)
        Zf, cnt_f, blocks_f, alive_f = _blocked_es_scan(
            U.reshape(flat + (nbl, bw)), V.reshape(flat + (nbl, bw)),
            su.reshape(flat + (nbl + 1,)), sv.reshape(flat + (nbl + 1,)),
            jnp.repeat(rho_s.astype(jnp.int32), n_shards),
            thr.reshape(flat), mode=mode)
        Z = Zf.reshape(n_loc, n_shards, nbl, bw)
        zpc = popcount32(Z).sum(axis=-1)            # (n, S, nbl)
        count = cnt_f.reshape(n_loc, n_shards).sum(axis=1)
        if mode == "andnot":
            # Diffset work counter (ISSUE 6): charge only the
            # *nonzero-mass* U blocks each shard's scan visited, like
            # the single-device ``_blocked_diff_scan``.  ``blocks_f``
            # counts the alive-visited prefix, so ``k < blocks_f``
            # marks visited local blocks; pad blocks are all-zero
            # (zero mass) and discount themselves, so no real-block
            # clamp is needed.
            umass = su[:, :, :-1] - su[:, :, 1:]    # (n, S, nbl)
            visited = (jnp.arange(nbl, dtype=jnp.int32)[None, None, :]
                       < blocks_f.reshape(n_loc, n_shards)[:, :, None])
            blocks = jnp.logical_and(umass > 0, visited).sum(
                axis=(1, 2)).astype(jnp.int32)
        else:
            # Pad blocks live at each tail shard's local END (the global
            # pad is the tail of the block axis), so clamping a shard's
            # scan count to its real-block count discounts them exactly.
            real_local = jnp.clip(
                jnp.asarray(n_real_blocks, jnp.int32)
                - jnp.arange(n_shards, dtype=jnp.int32) * nbl, 0, nbl)
            blocks = jnp.minimum(blocks_f.reshape(n_loc, n_shards),
                                 real_local[None, :]).sum(axis=1)
        alive = alive_f.reshape(n_loc, n_shards).all(axis=1)
        c0 = zpc[:, :, 0]                           # (n, S) per-shard blk 0
        if mode == "and":
            bound = (c0 + jnp.minimum(su[:, :, 1],
                                      sv[:, :, 1])).sum(axis=1)
        else:
            bound = rho_s.astype(jnp.int32) - c0.sum(axis=1)
        child_suffix = jnp.concatenate(
            [jnp.cumsum(zpc[:, :, ::-1], axis=-1)[:, :, ::-1],
             jnp.zeros((n_loc, n_shards, 1), jnp.int32)],
            axis=-1).reshape(n_loc, n_shards * (nbl + 1))
        return (Z.reshape(n_loc, nb, bw), child_suffix, bound, count,
                blocks, alive)

    n_loc = n_pairs // n_cls
    parts = [eval_slice(ua[c * n_loc:(c + 1) * n_loc],
                        vb[c * n_loc:(c + 1) * n_loc],
                        rho_parent[c * n_loc:(c + 1) * n_loc])
             for c in range(n_cls)]
    Z, child_suffix, bound, count, blocks, alive = (
        jnp.concatenate([p[i] for p in parts]) for i in range(6))

    keep = _survivor_mask(count, alive, rho_parent, minsup, mode=mode)
    slots_eff = jnp.where(keep, slots, jnp.int32(cap))
    rows = rows.at[slots_eff].set(Z, mode="drop")
    suffix = suffix.at[slots_eff].set(child_suffix, mode="drop")
    return rows, suffix, bound, count, blocks, alive


@jax.jit
def bitmap_count_ref(U: jnp.ndarray, V: jnp.ndarray) -> jnp.ndarray:
    """Plain AND + popcount support counting (no ES, no Z materialised)."""
    return popcount32(U & V).reshape(U.shape[0], -1).sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("mode",))
def bitmap_intersect_full_ref(U: jnp.ndarray, V: jnp.ndarray,
                              *, mode: str = "and",
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused full intersection: one AND/ANDNOT + popcount pass, no block
    scan.  The fast production path when per-block work metrics are not
    being collected (the screen still provides the ES savings)."""
    Z = U & (V if mode == "and" else ~V)
    cnt = popcount32(Z).reshape(U.shape[0], -1).sum(axis=-1)
    return Z, cnt


@functools.partial(jax.jit, static_argnames=("mode",))
def screen_pairs_ref(first_u: jnp.ndarray, first_v: jnp.ndarray,
                     suffix1_u: jnp.ndarray, suffix1_v: jnp.ndarray,
                     rho_parent: jnp.ndarray, minsup: jnp.ndarray,
                     *, mode: str = "and",
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inter-call screening: one-block refinement of the support bound.

    ``first_*``  : uint32 (n_pairs, bw)  — block 0 of each operand
    ``suffix1_*``: int32  (n_pairs,)     — popcount mass from block 1 on
    ``rho_parent``: int32 (n_pairs,)     — parent support ("andnot" mode)

    mode "and":    bound = |U0 & V0|  + min(sufU[1], sufV[1])
    mode "andnot": bound = rho_parent - |U0 & ~V0|

    Returns (bound, alive): pairs with ``bound < minsup`` are provably
    infrequent and are never materialised for full intersection.  This is
    the batched analogue of the paper's "detect infrequent candidates
    early" applied *before* work is scheduled."""
    if mode == "and":
        c0 = popcount32(first_u & first_v).sum(axis=-1)
        bound = c0 + jnp.minimum(suffix1_u, suffix1_v)
    elif mode == "andnot":
        c0 = popcount32(first_u & ~first_v).sum(axis=-1)
        bound = rho_parent.astype(jnp.int32) - c0
    else:
        raise ValueError(f"bad mode {mode!r}")
    return bound, bound >= jnp.asarray(minsup, jnp.int32)


@jax.jit
def compact_gather_ref(slab: jnp.ndarray, perm: jnp.ndarray) -> jnp.ndarray:
    """Allocator compaction gather: ``new[i] = slab[perm[i]]`` for
    ``0 <= perm[i] < capacity``, zeros elsewhere.

    ``perm int32 (new_capacity,)`` maps each *destination* slot to its
    source slot (-1 marks slots that come up free/zeroed).  Because the
    destination side is contiguous, the scatter half of the
    gather-scatter is the identity — one fused gather IS the whole
    compaction dispatch.  Works for any leading-axis slab: bitmap rows
    ``uint32 (cap, nb, bw)``, suffix tables ``int32 (cap, S)``, N-list
    code slabs ``int32 (cap, 3)``.  The OOB handling is spelled out
    (clip + mask) rather than relying on ``jnp.take`` fill-mode
    semantics so the result is identical across JAX versions."""
    cap = slab.shape[0]
    idx = jnp.clip(perm, 0, cap - 1)
    g = jnp.take(slab, idx, axis=0)
    ok = jnp.logical_and(perm >= 0, perm < cap)
    ok = ok.reshape((perm.shape[0],) + (1,) * (slab.ndim - 1))
    return jnp.where(ok, g, jnp.zeros((), slab.dtype))


# ---------------------------------------------------------------------------
# flash attention + embedding bag oracles
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention_ref(q, k, v, *, causal: bool = True,
                        softmax_scale=None) -> jnp.ndarray:
    """Dense reference attention (fp32 softmax), GQA by kv-head repeat."""
    B, Sq, H, D = q.shape
    _, Skv, KH, Dv = v.shape
    G = H // KH
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskv->bqkgv", a, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dv).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("combiner",))
def embedding_bag_ref(table, ids, mask, *, combiner: str = "mean"):
    e = jnp.take(table, ids, axis=0)                 # (B, L, D)
    m = mask.astype(jnp.float32)[..., None]
    s = (e.astype(jnp.float32) * m).sum(axis=-2)
    if combiner == "mean":
        s = s / jnp.maximum(m.sum(axis=-2), 1.0)
    return s.astype(table.dtype)


# ---------------------------------------------------------------------------
# N-list intersection (PrePost+) — device variant
# ---------------------------------------------------------------------------
#
# Padded two-pointer merge per pair (vmap over pairs).  PP-codes are stored
# as three parallel int32 arrays (pre, post, freq) padded with PRE=INT32_MAX
# sentinels.  Early stopping uses the *corrected* criterion
# z_mass + (rho_V - skip) < minsup (see core/oracle.py erratum note).

NL_SENTINEL = _NL


def _nl_merge_vmapped(u_pre, u_post, u_freq, v_pre, v_post, v_freq,
                      u_len, v_len, rho_v, minsup, *, early_stop: bool):
    """Batched two-pointer NL merge with the ``rho_V - skip`` ES guard.

    Shared body of :func:`nlist_intersect_ref` and
    :func:`nlist_extend_ref`; the Pallas kernel
    (``kernels/nlist_merge.py``) must reproduce it bit-for-bit.
    Returns ``(out_slot, support, comparisons, checks, alive)`` where
    slot ``i`` of ``out_slot`` holds the V index matched by U code ``i``
    (or sentinel), in U order.  ``checks`` counts skip-branch
    (j-advance) iterations — exactly the oracle's ``es_checks`` when ES
    is on (the bound is evaluated once per skipped V code)."""
    minsup = jnp.asarray(minsup, jnp.int32)
    _, Lu = u_pre.shape

    def one_pair(up, upost, uf, vp, vpost, vf, nu, nv, rv):
        def cond(st):
            i, j, _, _, _, _, alive, _ = st
            return jnp.logical_and(jnp.logical_and(i < nu, j < nv), alive)

        def body(st):
            i, j, z_mass, skip, cmps, checks, alive, out_slot = st
            cmps = cmps + 1
            xi_pre, xi_post, xi_f = up[i], upost[i], uf[i]
            yj_pre, yj_post, yj_f = vp[j], vpost[j], vf[j]
            is_desc = jnp.logical_and(xi_pre > yj_pre, xi_post < yj_post)
            adv_i_nomatch = xi_pre <= yj_pre
            adv_i = jnp.logical_or(is_desc, adv_i_nomatch)
            # match: record ancestor code at slot i, advance i
            out_slot = out_slot.at[i].set(
                jnp.where(is_desc, j, out_slot[i]))
            z_mass = z_mass + jnp.where(is_desc, xi_f, 0)
            skip = skip + jnp.where(adv_i, 0, yj_f)
            checks = checks + jnp.where(adv_i, 0, 1)
            if early_stop:
                alive = jnp.logical_and(
                    alive, z_mass + (rv - skip) >= minsup)
            i = i + jnp.where(adv_i, 1, 0)
            j = j + jnp.where(adv_i, 0, 1)
            return i, j, z_mass, skip, cmps, checks, alive, out_slot

        init = (jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
                jnp.int32(0), jnp.int32(0), jnp.bool_(True),
                jnp.full((Lu,), NL_SENTINEL, jnp.int32))
        (i, j, z_mass, skip, cmps, checks, alive,
         out_slot) = jax.lax.while_loop(cond, body, init)
        support = jnp.where(alive, z_mass, 0)  # aborted => certified < minsup
        return out_slot, support, cmps, checks, alive

    return jax.vmap(one_pair)(
        u_pre, u_post, u_freq, v_pre, v_post, v_freq,
        u_len.astype(jnp.int32), v_len.astype(jnp.int32),
        rho_v.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("early_stop",))
def nlist_intersect_ref(
    u_pre: jnp.ndarray, u_post: jnp.ndarray, u_freq: jnp.ndarray,  # (P, Lu)
    v_pre: jnp.ndarray, v_post: jnp.ndarray, v_freq: jnp.ndarray,  # (P, Lv)
    u_len: jnp.ndarray, v_len: jnp.ndarray,                        # (P,)
    rho_v: jnp.ndarray, minsup: jnp.ndarray,
    *, early_stop: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """Padded-batch NL merge: returns (out_slot, support, comparisons,
    checks, alive).  Kernel-bench entry point; the mining hot path uses
    :func:`nlist_extend_ref` / ``ops.nlist_extend`` which add the pool
    gather, Z-merge compaction and scatter around this merge."""
    return _nl_merge_vmapped(u_pre, u_post, u_freq, v_pre, v_post, v_freq,
                             u_len, v_len, rho_v, minsup,
                             early_stop=early_stop)


def _nl_gather(codes, off, length, width: int):
    """Gather padded (pre, post, freq) rows from the pool slab.

    ``codes int32 (cap, 3)``, ``off/length int32 (P,)`` -> three
    ``(P, width)`` arrays, sentinel-padded past each row's length."""
    cap = codes.shape[0]
    idx = off[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    mask = jnp.arange(width, dtype=jnp.int32)[None, :] < length[:, None]
    g = jnp.take(codes, jnp.minimum(idx, cap - 1), axis=0)
    pre = jnp.where(mask, g[..., 0], NL_SENTINEL)
    post = jnp.where(mask, g[..., 1], 0)
    freq = jnp.where(mask, g[..., 2], 0)
    return pre, post, freq


def _nl_group_starts(out_slot):
    """Z-merge group detection (Alg. 3 line 31) shared by the scatter
    and the presize pre-pass.  ``out_slot`` is non-decreasing over
    matched slots (two-pointer order), so group starts are exactly the
    positions where the slot value exceeds the running maximum of
    previous matched slots.  Returns ``(valid, start, child_len)``."""
    P, _ = out_slot.shape
    valid = out_slot != NL_SENTINEL
    js = jnp.where(valid, out_slot, -1)
    running = jax.lax.cummax(js, axis=1)
    prev = jnp.concatenate(
        [jnp.full((P, 1), -1, js.dtype), running[:, :-1]], axis=1)
    start = jnp.logical_and(valid, out_slot != prev)
    child_len = jnp.sum(start.astype(jnp.int32), axis=1)
    return valid, start, child_len


def _nl_zmerge_scatter(codes, out_slot, u_freq, v_pre, v_post, out_off):
    """Device Z-merge (Alg. 3 line 31) + child scatter into the pool.

    Consecutive U slots matching the same V ancestor code are one child
    element whose frequency is the group's U-frequency mass (see
    :func:`_nl_group_starts`).  Children are compacted to the front of
    their extents at ``out_off`` (offsets past the slab capacity are
    dropped — pair padding / non-survivors).

    Returns ``(codes, child_len)``."""
    P, Lu = out_slot.shape
    cap = codes.shape[0]
    valid, start, child_len = _nl_group_starts(out_slot)
    gid = jnp.cumsum(start.astype(jnp.int32), axis=1) - 1

    rows = jnp.broadcast_to(jnp.arange(P)[:, None], (P, Lu))
    # per-group U-frequency mass (scatter-add; invalid slots -> dropped)
    zfreq = jnp.zeros((P, Lu), jnp.int32).at[
        rows, jnp.where(valid, gid, Lu)].add(
        jnp.where(valid, u_freq, 0), mode="drop")
    # per-group representative V slot (unique per group: set at starts)
    rep = jnp.zeros((P, Lu), jnp.int32).at[
        rows, jnp.where(start, gid, Lu)].set(
        jnp.where(start, out_slot, 0), mode="drop")
    zpre = jnp.take_along_axis(v_pre, rep, axis=1)
    zpost = jnp.take_along_axis(v_post, rep, axis=1)

    k = jnp.arange(Lu, dtype=jnp.int32)[None, :]
    dest = jnp.where(k < child_len[:, None], out_off[:, None] + k, cap)
    child = jnp.stack([zpre, zpost, zfreq], axis=-1)
    codes = codes.at[dest].set(child, mode="drop")
    return codes, child_len


@functools.partial(jax.jit, static_argnames=("lu", "lv", "early_stop"))
def nlist_presize_ref(
    codes: jnp.ndarray,        # int32 (capacity, 3) N-list pool slab
    u_off: jnp.ndarray, u_len: jnp.ndarray,    # int32 (P,)
    v_off: jnp.ndarray, v_len: jnp.ndarray,    # int32 (P,)
    rho_v: jnp.ndarray,        # int32 (P,) sibling supports (ES bound)
    minsup: jnp.ndarray,       # int32 scalar
    *, lu: int, lv: int, early_stop: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray, jnp.ndarray]:
    """Merge-only pre-pass: the bound/count phase of the PrePost+ class
    extension WITHOUT the scatter (ISSUE 5 tentpole).

    Gathers both operand N-lists by extent offset and runs the
    two-pointer merge with the corrected ``z_mass + (rho_V - skip)`` ES
    guard — comparison counts are exactly the oracle's — plus the
    Z-merge group count, so the host learns each surviving child's
    *exact* length (and support) before allocating its extent.  The
    match table ``out_slot`` stays on device and feeds
    :func:`nlist_scatter_ref`, which re-derives the Z-merge from it —
    the merge loop runs exactly once per candidate.

    Returns ``(out_slot, child_len, support, comparisons, checks,
    alive)``; aborted pairs report support 0 (certified infrequent)."""
    u_pre, u_post, u_freq = _nl_gather(codes, u_off, u_len, lu)
    v_pre, v_post, v_freq = _nl_gather(codes, v_off, v_len, lv)
    out_slot, support, cmps, checks, alive = _nl_merge_vmapped(
        u_pre, u_post, u_freq, v_pre, v_post, v_freq,
        u_len, v_len, rho_v, minsup, early_stop=early_stop)
    _, _, child_len = _nl_group_starts(out_slot)
    return out_slot, child_len, support, cmps, checks, alive


@functools.partial(jax.jit, static_argnames=("lu", "lv"))
def nlist_scatter_ref(
    codes: jnp.ndarray,        # int32 (capacity, 3) N-list pool slab
    out_slot: jnp.ndarray,     # int32 (P, lu) presize match table
    u_off: jnp.ndarray, u_len: jnp.ndarray,    # int32 (P,)
    v_off: jnp.ndarray, v_len: jnp.ndarray,    # int32 (P,)
    out_off: jnp.ndarray,      # int32 (P,) child extents (OOB -> dropped)
    *, lu: int, lv: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter pass of the two-dispatch PrePost+ extension (ISSUE 5).

    Re-gathers the operand codes (cheap — no merge loop), Z-merges
    consecutive same-ancestor slots of ``out_slot`` and scatters the
    compacted child N-lists into the pool at ``out_off``.  Callers pass
    ``out_off >= capacity`` for every non-survivor (and for pair
    padding), so dead candidates cost zero scatter words and the pool
    only ever receives children whose tight extents were allocated from
    their exact pre-pass lengths.

    ``lu``/``lv`` must be the presize dispatch's gather widths.
    Returns ``(codes, child_len)``."""
    _, _, u_freq = _nl_gather(codes, u_off, u_len, lu)
    v_pre, v_post, _ = _nl_gather(codes, v_off, v_len, lv)
    return _nl_zmerge_scatter(codes, out_slot, u_freq, v_pre, v_post,
                              jnp.asarray(out_off, jnp.int32))


@functools.partial(jax.jit, static_argnames=("lu", "lv", "early_stop"))
def nlist_extend_ref(
    codes: jnp.ndarray,        # int32 (capacity, 3) N-list pool slab
    u_off: jnp.ndarray, u_len: jnp.ndarray,    # int32 (P,)
    v_off: jnp.ndarray, v_len: jnp.ndarray,    # int32 (P,)
    out_off: jnp.ndarray,      # int32 (P,) child extents (OOB -> dropped)
    rho_v: jnp.ndarray,        # int32 (P,) sibling supports (ES bound)
    minsup: jnp.ndarray,       # int32 scalar
    *, lu: int, lv: int, early_stop: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray, jnp.ndarray]:
    """Fused PrePost+ class extension over a device N-list pool.

    The exact semantics ``ops.nlist_extend`` must reproduce bit-for-bit
    (jnp and pallas backends) — the N-list analogue of
    :func:`screen_and_intersect_ref`.  One dispatch per pair chunk:

      * gather both operand N-lists from ``codes`` by extent offset
        (``lu``/``lv`` are the bucketed gather widths, static);
      * run the two-pointer merge with the corrected
        ``z_mass + (rho_V - skip) < minsup`` ES guard (see
        core/oracle.py erratum note) — comparison counts are exactly the
        oracle's;
      * Z-merge consecutive same-ancestor slots on device and scatter the
        compacted child N-lists into the pool at ``out_off`` —
        **survivor-only** (ISSUE 5): the merge phase completes first and
        gates the scatter, so a child is written only when its support
        clears minsup (aborted pairs report support 0, so ES deaths are
        covered by the same gate).

    The mining hot path uses the two-dispatch split
    (:func:`nlist_presize_ref` + :func:`nlist_scatter_ref`) so extents
    can be allocated from exact child lengths; this one-dispatch
    composition remains the micro-bench / pessimistic-extent API.

    Returns ``(codes, child_len, support, comparisons, checks, alive)``;
    non-survivors report ``child_len`` from the merge but scatter
    nothing."""
    u_pre, u_post, u_freq = _nl_gather(codes, u_off, u_len, lu)
    v_pre, v_post, v_freq = _nl_gather(codes, v_off, v_len, lv)
    out_slot, support, cmps, checks, alive = _nl_merge_vmapped(
        u_pre, u_post, u_freq, v_pre, v_post, v_freq,
        u_len, v_len, rho_v, minsup, early_stop=early_stop)
    keep = support >= jnp.asarray(minsup, jnp.int32)
    cap = codes.shape[0]
    out_off_eff = jnp.where(keep, jnp.asarray(out_off, jnp.int32),
                            jnp.int32(cap))
    codes, child_len = _nl_zmerge_scatter(
        codes, out_slot, u_freq, v_pre, v_post, out_off_eff)
    return codes, child_len, support, cmps, checks, alive
