"""Pallas TPU kernel: blocked bitmap intersection with early stopping.

This is the paper's contribution lowered to the TPU execution model
(DESIGN.md §2): TID-lists are packed ``uint32`` bitmap rows, intersection
is ``AND`` (+ ``ANDNOT`` for dEclat diffsets) + SWAR popcount on the VPU,
and the Early-Stopping criterion is evaluated once per *block* using
precomputed suffix-popcount tables.  A pair that is provably infrequent
stops consuming VPU cycles at the next block boundary.

Grid/layout
-----------
grid = (n_pairs,) — one program per candidate pair.  Each program pulls
its two operand rows ``(1, n_blocks, block_words)`` into VMEM (BlockSpec),
walks the blocks with a ``lax.while_loop`` carrying
``(block_idx, count, alive)``, writes the intersection blocks it actually
processed, and publishes ``count`` / ``blocks_done`` through SMEM outputs.

``block_words`` is 128 by default so each block is a lane-aligned
``(8, 128)``-tileable uint32 slab of 4096 transactions.

VMEM budget: 3 rows x n_blocks x block_words x 4B; at the default block
size a 1M-transaction database is ~3 x 125KB — far under the ~16MB/core
VMEM of v5e.  For larger databases the TID axis is sharded across the mesh
first (count distribution, core/distributed.py), so per-device rows stay
small; the kernel never needs an HBM-resident row.

Semantics are defined by ``kernels/ref.py::bitmap_intersect_es_ref`` and
must match it bit-for-bit (tests/test_kernels.py sweeps shapes, modes and
minsup values, including minsup<=0 == ES disabled).

Fused dispatch contract
-----------------------
The mining hot path no longer calls this kernel on host-materialised
operand batches.  ``ops.screen_and_intersect`` wraps it in a single jit
with a store-index gather in front and a child-row + suffix-table
scatter behind, so that one ``pallas_call`` plus its surrounding
gather/scatter lowers to ONE device dispatch per pair chunk and all row
traffic stays in HBM/VMEM.  The block-0 iteration of the while_loop IS
the old one-block screen (the bound after block 0 equals the screen
bound), which is why no separate screen kernel exists anymore.  The
scatter half of that contract is **survivor-only** (ISSUE 5): the
kernel's count/alive outputs are produced first and gate the scatter —
a pair this kernel killed (or that finished below minsup) has its
child-slot write dropped, on both the Pallas and jnp backends, so dead
candidates stop consuming scatter bandwidth the same way they already
stopped consuming VPU cycles.  Inside the kernel nothing changes: the
while_loop already writes only the blocks it actually processed.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _popcount_sum(z: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount of a uint32 block, summed to a scalar int32."""
    x = z.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    pc = ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
    return pc.sum()


def _kernel(mode: str, n_blocks: int,
            minsup_ref, u_ref, v_ref, su_ref, sv_ref, rho_ref,
            z_ref, cnt_ref, blocks_ref):
    """One candidate pair: blocked ES intersection.

    minsup_ref: (1,) SMEM     — scalar-prefetch style threshold
    u_ref/v_ref: (1, nb, bw)  VMEM operand rows
    su_ref/sv_ref: (1, nb+1)  SMEM suffix popcount rows
    rho_ref: (1,) SMEM        — parent support (andnot mode)
    z_ref: (1, nb, bw) VMEM   — intersection/diffset row (zeros past abort)
    cnt_ref, blocks_ref: (1,) SMEM outputs
    """
    minsup = minsup_ref[0]

    # Dead blocks must read back as zero: clear the output row first.
    z_ref[0] = jnp.zeros_like(z_ref[0])

    def cond(carry):
        k, _, alive = carry
        return jnp.logical_and(k < n_blocks, alive)

    def body(carry):
        k, cnt, alive = carry
        u_k = u_ref[0, k]
        v_k = v_ref[0, k]
        z_k = u_k & (v_k if mode == "and" else ~v_k)
        z_ref[0, k] = z_k
        cnt = cnt + _popcount_sum(z_k)
        if mode == "and":
            bound = cnt + jnp.minimum(su_ref[0, k + 1], sv_ref[0, k + 1])
        else:
            bound = rho_ref[0] - cnt
        alive = bound >= minsup
        return k + 1, cnt, alive

    k_end, cnt, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.int32(0), jnp.bool_(True)))
    cnt_ref[0] = cnt
    blocks_ref[0] = k_end


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def bitmap_intersect_es(
    U: jnp.ndarray,           # uint32 (n_pairs, n_blocks, bw)
    V: jnp.ndarray,           # uint32 (n_pairs, n_blocks, bw)
    suffix_u: jnp.ndarray,    # int32  (n_pairs, n_blocks + 1)
    suffix_v: jnp.ndarray,    # int32  (n_pairs, n_blocks + 1)
    rho_parent: jnp.ndarray,  # int32  (n_pairs,)
    minsup: jnp.ndarray,      # int32  scalar; <= 0 disables ES
    *,
    mode: str = "and",
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pallas ES intersection.  Returns (Z, counts, blocks_done, alive).

    ``interpret=True`` (the CPU default here) runs the kernel body in the
    Pallas interpreter for validation; on TPU pass ``interpret=False``.
    """
    if mode not in ("and", "andnot"):
        raise ValueError(f"bad mode {mode!r}")
    n_pairs, n_blocks, bw = U.shape
    minsup_arr = jnp.reshape(jnp.asarray(minsup, jnp.int32), (1,))

    kernel = functools.partial(_kernel, mode, n_blocks)
    z, cnt, blocks = pl.pallas_call(
        kernel,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # minsup (whole array)
            pl.BlockSpec((1, n_blocks, bw), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, n_blocks, bw), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, n_blocks + 1), lambda p: (p, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n_blocks + 1), lambda p: (p, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, n_blocks, bw), lambda p: (p, 0, 0)),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda p: (p,), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pairs, n_blocks, bw), jnp.uint32),
            jax.ShapeDtypeStruct((n_pairs,), jnp.int32),
            jax.ShapeDtypeStruct((n_pairs,), jnp.int32),
        ],
        interpret=interpret,
    )(minsup_arr, U, V, suffix_u.astype(jnp.int32),
      suffix_v.astype(jnp.int32), rho_parent.astype(jnp.int32))
    # Recover the ref's ``alive`` flag: a pair that processed every block is
    # alive iff its *final* bound clears minsup (the final "and" bound is
    # exactly ``cnt`` since the suffix table ends in 0); a pair that exited
    # early is certified dead.
    if mode == "and":
        final_ok = cnt >= jnp.asarray(minsup, jnp.int32)
    else:
        final_ok = (rho_parent.astype(jnp.int32) - cnt) >= jnp.asarray(
            minsup, jnp.int32)
    alive = jnp.logical_and(blocks >= n_blocks, final_ok)
    return z, cnt, blocks, alive
