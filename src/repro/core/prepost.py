"""Device PrePost+: batched N-list intersection with early stopping.

The PPC-tree build is inherently sequential host preprocessing (one pass
over the reordered transactions — same category as tokenisation) and is
shared with the oracle (``oracle.PPCTree``).  The search itself batches all
extensions of one class member into a single vmapped two-pointer merge on
the device (kernels/ops.nlist_intersect), carrying the paper's
``rho_V - skip`` early-stopping criterion (with the Z-mass erratum fix, see
core/oracle.py) inside the ``lax.while_loop`` guard.

N-lists are short by construction — that is PrePost+'s selling point — so
the padded-batch layout wastes little and the sequential merge depth is
small.  Comparison counts reported by the device path are exactly the
oracle's (same merge, same abort points); tests assert equality.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, FrozenSet, Hashable, List, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.oracle import PPCTree, MiningStats
from repro.kernels import ops
from repro.core.bitmap import NL_SENTINEL

ItemsetSupports = Dict[FrozenSet[Hashable], int]

_LEN_BUCKETS = (8, 32, 128, 512, 2048, 8192, 32768)


def _pad_len(n: int) -> int:
    for b in _LEN_BUCKETS:
        if n <= b:
            return b
    raise ValueError(f"N-list of length {n} exceeds largest bucket")


@dataclass
class _Member:
    itemset: Tuple[Hashable, ...]
    pre: np.ndarray    # int32 (len,)
    post: np.ndarray
    freq: np.ndarray
    support: int


class DevicePrePost:
    """PrePost+ with device-batched NL intersection."""

    def __init__(self, early_stop: bool = True, pair_chunk: int = 8192,
                 backend: str = "auto"):
        self.early_stop = early_stop
        self.pair_chunk = pair_chunk
        self.backend = backend

    def mine(self, db: Sequence[Sequence[Hashable]], minsup: int,
             ) -> Tuple[ItemsetSupports, MiningStats]:
        if minsup < 1:
            raise ValueError("minsup must be an absolute count >= 1")
        stats = MiningStats()
        t0 = time.perf_counter()

        tree = PPCTree(db, minsup)
        order_asc = list(reversed(tree.order_desc))
        out: ItemsetSupports = {}
        members: List[_Member] = []
        for it in order_asc:
            codes = tree.nlists[it]
            out[frozenset((it,))] = tree.item_support[it]
            stats.nodes += 1
            arr = np.asarray(codes, np.int32).reshape(-1, 3)
            members.append(_Member(
                itemset=(it,), pre=arr[:, 0], post=arr[:, 1],
                freq=arr[:, 2], support=tree.item_support[it]))

        self._minsup = minsup
        self._traverse(members, out, stats)
        stats.runtime_s = time.perf_counter() - t0
        return out, stats

    def _traverse(self, klass: List[_Member], out: ItemsetSupports,
                  stats: MiningStats) -> None:
        for a in range(len(klass)):
            siblings = klass[a + 1:]
            if not siblings:
                continue
            children: List[_Member] = []
            for lo in range(0, len(siblings), self.pair_chunk):
                children.extend(self._extend_chunk(
                    klass[a], siblings[lo:lo + self.pair_chunk], stats))
            for ch in children:
                out[frozenset(ch.itemset)] = ch.support
                stats.nodes += 1
            if children:
                self._traverse(children, out, stats)

    def _extend_chunk(self, xs: _Member, chunk: List[_Member],
                      stats: MiningStats) -> List[_Member]:
        n = len(chunk)
        stats.candidates += n
        lu = _pad_len(len(xs.pre))
        lv = _pad_len(max(len(s.pre) for s in chunk))

        def pad(vec: np.ndarray, L: int, fill: int) -> np.ndarray:
            o = np.full((L,), fill, np.int32)
            o[:len(vec)] = vec
            return o

        u_pre = np.broadcast_to(pad(xs.pre, lu, NL_SENTINEL), (n, lu))
        u_post = np.broadcast_to(pad(xs.post, lu, 0), (n, lu))
        u_freq = np.broadcast_to(pad(xs.freq, lu, 0), (n, lu))
        v_pre = np.stack([pad(s.pre, lv, NL_SENTINEL) for s in chunk])
        v_post = np.stack([pad(s.post, lv, 0) for s in chunk])
        v_freq = np.stack([pad(s.freq, lv, 0) for s in chunk])
        u_len = np.full((n,), len(xs.pre), np.int32)
        v_len = np.array([len(s.pre) for s in chunk], np.int32)
        rho_v = np.array([s.support for s in chunk], np.int32)

        out_slot, support, cmps, alive = ops.nlist_intersect(
            jnp.asarray(u_pre), jnp.asarray(u_post), jnp.asarray(u_freq),
            jnp.asarray(v_pre), jnp.asarray(v_post), jnp.asarray(v_freq),
            jnp.asarray(u_len), jnp.asarray(v_len), jnp.asarray(rho_v),
            jnp.int32(self._minsup), early_stop=self.early_stop,
            backend=self.backend)
        out_slot = np.asarray(out_slot)
        support = np.asarray(support)
        stats.comparisons += int(np.asarray(cmps).sum())
        stats.es_aborts += int((~np.asarray(alive)).sum())

        children: List[_Member] = []
        for b in range(n):
            if support[b] < self._minsup:
                continue
            # Reconstruct the child N-list: slot i of U matched V-code
            # out_slot[b, i]; merge consecutive slots sharing a V-code
            # (Alg. 3 line 31 "merge elements in Z").
            slots = out_slot[b, :len(xs.pre)]
            matched = slots != NL_SENTINEL
            js = slots[matched]
            fs = xs.freq[:len(xs.pre)][matched]
            if js.size == 0:
                continue
            # group-by consecutive equal j (js is non-decreasing: two-pointer)
            boundaries = np.nonzero(np.diff(js))[0] + 1
            groups = np.split(np.arange(js.size), boundaries)
            z_pre = np.array([v_pre[b, js[g[0]]] for g in groups], np.int32)
            z_post = np.array([v_post[b, js[g[0]]] for g in groups], np.int32)
            z_freq = np.array([fs[g].sum() for g in groups], np.int32)
            children.append(_Member(
                itemset=xs.itemset + (chunk[b].itemset[-1],),
                pre=z_pre, post=z_post, freq=z_freq,
                support=int(support[b])))
        return children


def mine_prepost_device(db, minsup, early_stop: bool = True, **kw):
    return DevicePrePost(early_stop=early_stop, **kw).mine(db, minsup)
