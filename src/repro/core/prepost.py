"""Device-resident PrePost+: N-lists live in a pooled device slab.

The PPC-tree build is inherently sequential host preprocessing (one pass
over the reordered transactions — same category as tokenisation) and is
shared with the oracle (``oracle.PPCTree``).  Everything after it is
device-resident (the ISSUE 3 unification — third engine on the shared
allocator): every N-list the DFS can still touch is an extent of one
persistent ``int32[capacity, 3]`` PPC-code slab
(``core.rowstore.NListPool``), and the host only ever moves row indices
and small int vectors around.  Each sibling pair chunk is TWO fused
device dispatches since ISSUE 5 (survivor-only, allocation-tight
materialization):

  * pre-pass (``kernels.ops.nlist_presize``): gather both operand
    N-lists out of the slab by extent offset, run the vmapped
    two-pointer merge carrying the paper's ``rho_V - skip``
    early-stopping criterion (with the Z-mass erratum fix, see
    core/oracle.py) inside the ``lax.while_loop`` guard, and count the
    Z-merge groups — the host learns every candidate's exact child
    length and support while the match table stays on device;
  * scatter pass (``kernels.ops.nlist_scatter``): Z-merge consecutive
    slots sharing a V ancestor code (Alg. 3 line 31) and write the
    compacted child N-lists straight into *tight* extents allocated
    for the surviving children only — dead candidates cost zero
    scatter words and zero pool mass, and a chunk with no survivors
    skips this dispatch entirely.

Comparison counts reported by the device path are exactly the oracle's
(same merge, same abort points); tests assert equality (invariant I4).
``backend`` selects the merge implementation: pure-jnp ``while_loop``
("jnp", the CPU production path) or the Pallas kernel
(``kernels/nlist_merge.py``, "pallas"/"auto"-on-TPU), both bit-exact vs
``kernels.ref.nlist_extend_ref``.

Since ISSUE 4 the DFS is the shared ``core.frontier.FrontierScheduler``
(the same cross-class drain-group batching as the bitmap engines), so
deep DFS regions no longer dispatch per class member, and the pool is
compacted/re-bucketed at drain-group boundaries.  Comparison counts are
batching-invariant (each pair's merge is independent), so they remain
exactly the oracle's (I4).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, FrozenSet, Hashable, List, Sequence, Tuple

import numpy as np

from repro.core.guards import host_sync
from repro.core.oracle import PPCTree, MiningStats
from repro.core.frontier import (Child, ClassNode, EngineAccounting,
                                 FrontierScheduler)
from repro.core.rowstore import NListPool
from repro.core.bitmap import (NL_LEN_BUCKETS, NL_PAIR_CHUNK_BUCKETS,
                               NL_REF_LEN, bucket_pad, chunk_width_for,
                               nl_pad_len, nl_pad_len_np)
from repro.kernels import ops

ItemsetSupports = Dict[FrozenSet[Hashable], int]

# Canonical table lives in core.bitmap next to bucket_pad (ISSUE 5
# consolidation) so the pair-chunk clamp and the pad logic cannot drift.
_PAIR_BUCKETS = NL_PAIR_CHUNK_BUCKETS


def _pad_len(n: int) -> int:
    """Bucketed N-list gather width (power-of-two fallback past the
    largest tuned bucket — huge N-lists must not be a hard error)."""
    return nl_pad_len(n)


class PendingMergeResult:
    """Lazy result handle for one N-list ``evaluate_pairs`` chunk
    (ISSUE 7 pipeline): the merge pre-pass has been *launched*; the
    blocking readbacks of child_len/support/cmps/checks/alive, the
    tight survivor extent allocation AND the scatter dispatch are all
    deferred to ``resolve()`` at group retirement.

    Deferring the scatter past other groups' dispatches is sound
    because ``ops.nlist_scatter`` re-gathers its operand windows from
    the *current* slab by offset and the device match table
    (``out_slot``) is window-relative: this group's operand extents are
    live until its own retirement, other groups only scatter into
    freshly allocated extents, and pool growth preserves offsets.  A
    compaction landing while the group is in flight DOES move extents —
    pool row ids are stable (``remap`` is a no-op) but offsets are not,
    so ``resolve()`` re-resolves every offset through the pool's host
    tables at scatter time instead of caching dispatch-time values."""

    __slots__ = ("_miner", "_n", "_u_row", "_v_row", "_u_len", "_v_len",
                 "_lu", "_lv", "_raw")

    def __init__(self, miner: "DevicePrePost", n: int,
                 u_row: np.ndarray, v_row: np.ndarray,
                 u_len: np.ndarray, v_len: np.ndarray,
                 lu: int, lv: int, raw: Tuple):
        self._miner = miner
        self._n = n
        self._u_row, self._v_row = u_row, v_row
        self._u_len, self._v_len = u_len, v_len
        self._lu, self._lv = lu, lv
        self._raw = raw

    def remap(self, mapping) -> None:
        """Pool row ids are compaction-stable; nothing to rewrite."""

    def resolve(self) -> List[Tuple[int, int, int, Any]]:
        miner = self._miner
        pool, stats = miner._pool, miner._stats
        n = self._n
        out_slot, child_len, support, cmps, checks, alive = self._raw
        # host-sync: the audited group-retirement readback (PR 7) — one
        # deliberate d2h per retired merge dispatch, deferred via the
        # handle so in-flight groups overlap
        with host_sync("group-retirement accounting readback"):
            child_len = np.asarray(child_len[:n])
            support = np.asarray(support[:n])
            alive = np.asarray(alive[:n])
            cmps_total = int(np.asarray(cmps[:n]).sum())
            checks_total = int(np.asarray(checks[:n]).sum())
        stats.comparisons += cmps_total
        if miner.early_stop:
            # One ES bound evaluation per skipped V code — exactly the
            # oracle's es_checks, and aborts are only attributed when
            # the guard was actually armed (the non-ES merge must
            # report zero deaths).
            stats.es_checks += checks_total
            stats.es_aborts += int((~alive).sum())

        freq = support >= miner._minsup  # aborted pairs report support 0
        kept = np.nonzero(freq)[0]
        if kept.size == 0:
            return []

        # HOST-SYNC (load-bearing): the tight survivor-extent
        # allocation is *data-dependent* — extent sizes are the
        # pre-pass's exact child lengths, so the host must block on the
        # ``child_len`` readback above before it can size ``alloc_rows``
        # (and grow the pool) for the scatter below.  This is why the
        # presize->scatter pair cannot be fused into one launch and why
        # the scatter rides the retire path.
        child_rows = pool.alloc_rows(child_len[kept])
        out_off = np.full(n, pool.capacity, np.int32)   # default: dropped
        out_off[kept] = pool.offsets(child_rows)
        # Offsets re-resolved at scatter time (NOT dispatch time): an
        # in-flight compaction may have moved every live extent.
        u_off = pool.offsets(self._u_row)
        v_off = pool.offsets(self._v_row)

        def pad(arr, fill=0):
            return bucket_pad(arr, n, _PAIR_BUCKETS, fill)
        pool.codes, _ = ops.nlist_scatter(
            pool.codes, out_slot, pad(u_off), pad(self._u_len),
            pad(v_off), pad(self._v_len),
            pad(out_off, fill=pool.capacity),
            lu=self._lu, lv=self._lv, backend=miner.backend)
        stats.device_calls += 1
        stats.child_scatters += int(kept.size)
        stats.scatter_words += 3 * int(child_len[kept].sum())
        self._raw = None                             # drop device refs
        return [(int(b), int(row), int(support[b]), int(child_len[b]))
                for b, row in zip(kept, child_rows, strict=True)]


@dataclass
class DevicePrePostStats(MiningStats, EngineAccounting):
    """Oracle-compatible counters plus the shared device-engine
    accounting struct (``frontier.EngineAccounting``)."""

    # Legacy names kept as read-only views of the shared accounting.
    @property
    def pool_grows(self) -> int:
        return self.grows

    @property
    def peak_codes(self) -> int:
        return self.peak_live

    @property
    def deaths(self) -> int:
        return self.es_aborts

    def as_dict(self) -> Dict[str, float]:
        d = super().as_dict()
        d.update(pool_grows=self.pool_grows, peak_codes=self.peak_codes,
                 **self.accounting_dict())
        return d


class DevicePrePost:
    """PrePost+ over a device-resident N-list pool with a fused
    merge pre-pass + survivor-only scatter pass per pair chunk.

    The DFS is ``core.frontier.FrontierScheduler`` — the same work-stack
    + cross-class drain-group batching as the bitmap engines, so deep
    DFS regions no longer issue one dispatch per class member's sibling
    window: pairs from MANY classes (with heterogeneous U operands —
    the dispatches take per-pair extents) fill each chunk, and
    :meth:`chunk_sort_key` keeps each chunk's gather widths homogeneous
    by length bucket.  Child extents are allocated from the pre-pass's
    *exact* lengths for *survivors only* — the pool never holds a
    pessimistic ``min(|U|, |V|)`` extent.  ``compact_occupancy``: see
    ``BitmapMiner``; 0 disables.
    """

    def __init__(self, early_stop: bool = True, pair_chunk: int = 8192,
                 backend: str = "auto", compact_occupancy: float = 0.25,
                 inflight: int = 2, autotune_chunk: bool = False):
        self.early_stop = early_stop
        self.pair_chunk = min(pair_chunk, _PAIR_BUCKETS[-1])
        self.backend = backend
        self.compact_occupancy = compact_occupancy
        # Dispatch-pipeline knobs (ISSUE 7): ring depth and per-bucket
        # chunk-width autotuning (short-operand chunks dispatch wider at
        # equal VMEM footprint; see core.bitmap.chunk_width_for).
        self.inflight = max(1, int(inflight))
        self.autotune_chunk = bool(autotune_chunk)
        self._widths: Dict[int, int] = {}

    def mine(self, db: Sequence[Sequence[Hashable]], minsup: int,
             ) -> Tuple[ItemsetSupports, DevicePrePostStats]:
        if minsup < 1:
            raise ValueError("minsup must be an absolute count >= 1")
        stats = DevicePrePostStats()
        t0 = time.perf_counter()

        tree = PPCTree(db, minsup)
        order_asc = list(reversed(tree.order_desc))
        out: ItemsetSupports = {}
        arrays: List[np.ndarray] = []
        for it in order_asc:
            out[frozenset((it,))] = tree.item_support[it]
            stats.nodes += 1
            # host-sync: pack-time host PPC-tree N-lists; no device value
            arrays.append(np.asarray(tree.nlists[it], np.int32).reshape(-1, 3))

        pool = NListPool(capacity=max(
            64, 2 * sum(nl_pad_len(max(len(a), 1)) for a in arrays)))
        rows = pool.alloc_rows([len(a) for a in arrays])
        if len(arrays):
            pool.write_rows(rows, arrays)
        root = ClassNode(
            itemsets=[(it,) for it in order_asc],
            # host-sync: pack-time host metadata; no device value touched
            rows=np.asarray(rows, np.int32),
            supports=np.asarray([tree.item_support[it] for it in order_asc],
                                np.int32),
            payload=np.asarray([len(a) for a in arrays], np.int32))

        self._minsup = minsup
        self._pool = pool
        self._out = out
        self._stats = stats
        # The widest autotuned chunk is the smallest bucket's width;
        # draining that many pairs keeps wide chunks full.
        drain_target = (self._width_for_bucket(NL_LEN_BUCKETS[0])
                        if self.autotune_chunk else None)
        sched = FrontierScheduler(self, self.pair_chunk,
                                  inflight=self.inflight,
                                  drain_target=drain_target)
        sched.run(root)
        stats.note_allocator(pool)
        stats.note_scheduler(sched)
        stats.runtime_s = time.perf_counter() - t0
        return out, stats

    # -- FrontierScheduler client protocol ----------------------------------

    def pair_columns(self, klass: ClassNode, ia: np.ndarray,
                     ib: np.ndarray) -> Dict[str, np.ndarray]:
        lens = klass.payload               # per-member exact N-list lengths
        return {"u_row": klass.rows[ia].astype(np.int32),
                "v_row": klass.rows[ib].astype(np.int32),
                "u_len": lens[ia].astype(np.int32),
                "v_len": lens[ib].astype(np.int32),
                "rho_v": klass.supports[ib].astype(np.int32)}

    def chunk_sort_key(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        """Length-aware drain-group composition (ISSUE 5): the scheduler
        stably sorts drained pairs by the bucket of their longest
        operand before chunk slicing, so one huge N-list widens the
        ``lu``/``lv`` gather only for its own (homogeneous) chunk."""
        return nl_pad_len_np(np.maximum(cols["u_len"], cols["v_len"]))

    def _width_for_bucket(self, bucket: int) -> int:
        """Autotuned chunk width for one operand length bucket: a pair
        whose longest operand sits in bucket ``b`` moves ~3*b code
        words, so short-operand chunks widen proportionally (floored at
        ``pair_chunk`` — autotuning never narrows a chunk)."""
        w = self._widths.get(bucket)
        if w is None:
            w = chunk_width_for(3 * bucket, self.pair_chunk,
                                _PAIR_BUCKETS, 3 * NL_REF_LEN)
            self._widths[bucket] = w
        return w

    def chunk_widths(self, cols: Dict[str, np.ndarray],
                     ) -> "np.ndarray | None":
        """Per-pair chunk-width cap (ISSUE 7), evaluated on the sorted
        columns: pairs are already ordered by length bucket
        (``chunk_sort_key``), so the caps are non-increasing and the
        scheduler's greedy slicer packs each bucket at its own width."""
        if not self.autotune_chunk:
            return None
        buckets = nl_pad_len_np(np.maximum(cols["u_len"], cols["v_len"]))
        widths = np.empty(buckets.size, np.int64)
        for b in np.unique(buckets):
            widths[buckets == b] = self._width_for_bucket(int(b))
        return widths

    def evaluate_pairs(self, cols: Dict[str, np.ndarray],
                       ) -> PendingMergeResult:
        """One pair-chunk slice -> merge pre-pass + survivor-only
        scatter (ISSUE 5: two dispatches instead of one, pessimistic
        extents for none).

        The pre-pass (``ops.nlist_presize``) runs the gather + ES merge
        and returns each candidate's exact child length, support and
        aliveness — the merge loop runs exactly once, so comparison
        counts stay exactly the oracle's (I4).  The host then allocates
        extents for the *survivors only*, sized by their *actual*
        lengths (the pessimistic ``min(|U|, |V|)`` allocation is gone),
        and the scatter pass (``ops.nlist_scatter``) Z-merges the
        device-resident match table into those tight extents.  A chunk
        with no survivors skips the scatter dispatch entirely.

        Pipelined (ISSUE 7): only the pre-pass *launches* here.  The
        readbacks, the tight allocation (which must block on the exact
        child lengths) and the scatter dispatch live in the returned
        :class:`PendingMergeResult` and run at group retirement, whose
        ``resolve()`` yields the frequent children as
        ``(ki, row, support, length)`` tuples.  Operand U/V extents
        vary per pair (cross-class chunk): the gather widths are the
        buckets of the chunk maxima, kept homogeneous by
        :meth:`chunk_sort_key`."""
        pool, stats = self._pool, self._stats
        u_len, v_len = cols["u_len"], cols["v_len"]
        n = int(u_len.size)
        stats.candidates += n
        lu = nl_pad_len(int(u_len.max()))
        lv = nl_pad_len(int(v_len.max()))
        u_off = pool.offsets(cols["u_row"])
        v_off = pool.offsets(cols["v_row"])

        def pad(arr, fill=0):
            return bucket_pad(arr, n, _PAIR_BUCKETS, fill)
        raw = ops.nlist_presize(
            pool.codes, pad(u_off), pad(u_len), pad(v_off), pad(v_len),
            pad(cols["rho_v"]), np.int32(self._minsup),
            lu=lu, lv=lv, early_stop=self.early_stop,
            backend=self.backend)
        stats.device_calls += 1
        return PendingMergeResult(self, n, cols["u_row"], cols["v_row"],
                                  u_len, v_len, lu, lv, raw)

    def make_class(self, parent: ClassNode,
                   children: List[Child]) -> ClassNode:
        del parent
        return ClassNode(
            itemsets=[c.itemset for c in children],
            # host-sync: host child metadata; no device value touched
            rows=np.asarray([c.row for c in children], np.int32),
            supports=np.asarray([c.support for c in children], np.int32),
            payload=np.asarray([c.extra for c in children], np.int32))

    def emit(self, itemset: Tuple[Hashable, ...], support: int) -> None:
        self._out[frozenset(itemset)] = support
        self._stats.nodes += 1

    def release(self, klass: ClassNode) -> None:
        self._pool.free_rows(klass.rows)

    def maybe_compact(self, reserve: int) -> None:
        """Drain-group boundary hook.  Pool row ids are stable across
        compaction (offsets are indirected through the host tables), so
        the scheduler never needs to remap — always returns None.

        ``reserve`` arrives as the WHOLE drain group's pair count
        (ISSUE 5: a group's chunks allocate children cumulatively, so
        reserving one chunk's worth caused compact/grow thrash).  Child
        extents are now tight (exact lengths, survivors only), so the
        mean live extent size converts pairs into a *generous* code
        estimate; the would-halve hysteresis absorbs the remaining
        error."""
        pool = self._pool
        avg_extent = pool.live_codes // max(pool.n_live_rows, 1)
        pool.compact_if_sparse(self.compact_occupancy,
                               reserve=reserve * max(avg_extent, 1),
                               backend=self.backend)
        return None


def mine_prepost_device(db, minsup, early_stop: bool = True, **kw):
    return DevicePrePost(early_stop=early_stop, **kw).mine(db, minsup)
