"""Device-resident PrePost+: N-lists live in a pooled device slab.

The PPC-tree build is inherently sequential host preprocessing (one pass
over the reordered transactions — same category as tokenisation) and is
shared with the oracle (``oracle.PPCTree``).  Everything after it is
device-resident (the ISSUE 3 unification — third engine on the shared
allocator): every N-list the DFS can still touch is an extent of one
persistent ``int32[capacity, 3]`` PPC-code slab
(``core.rowstore.NListPool``), and the host only ever moves row indices
and small int vectors around.  Each sibling pair chunk is exactly ONE
fused device dispatch (``kernels.ops.nlist_extend``):

  * gather: both operand N-lists are picked out of the slab by extent
    offset (no host padding, no re-upload);
  * merge: the vmapped two-pointer merge carries the paper's
    ``rho_V - skip`` early-stopping criterion (with the Z-mass erratum
    fix, see core/oracle.py) inside the ``lax.while_loop`` guard;
  * Z-merge + scatter: consecutive slots sharing a V ancestor code are
    combined on device (Alg. 3 line 31) and the compacted child N-lists
    are written straight into preallocated extents of the same slab.

Comparison counts reported by the device path are exactly the oracle's
(same merge, same abort points); tests assert equality (invariant I4).
``backend`` selects the merge implementation: pure-jnp ``while_loop``
("jnp", the CPU production path) or the Pallas kernel
(``kernels/nlist_merge.py``, "pallas"/"auto"-on-TPU), both bit-exact vs
``kernels.ref.nlist_extend_ref``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, FrozenSet, Hashable, List, Sequence, Tuple

import numpy as np

from repro.core.oracle import PPCTree, MiningStats
from repro.core.rowstore import NListPool
from repro.core.bitmap import bucket_pad, nl_pad_len
from repro.kernels import ops

ItemsetSupports = Dict[FrozenSet[Hashable], int]

_PAIR_BUCKETS = (64, 256, 1024, 4096, 8192, 32768)


def _pad_len(n: int) -> int:
    """Bucketed N-list gather width (power-of-two fallback past the
    largest tuned bucket — huge N-lists must not be a hard error)."""
    return nl_pad_len(n)


@dataclass
class DevicePrePostStats(MiningStats):
    """Oracle-compatible counters plus device-engine accounting."""

    device_calls: int = 0      # fused nlist_extend dispatches
    pool_grows: int = 0        # code-slab reallocations
    peak_codes: int = 0        # peak live pool extent mass (code triples)

    def as_dict(self) -> Dict[str, float]:
        d = super().as_dict()
        d.update(device_calls=self.device_calls,
                 pool_grows=self.pool_grows, peak_codes=self.peak_codes)
        return d


@dataclass
class _Member:
    """One equivalence-class member: the host handle to a pooled N-list.

    ``row`` is an ``NListPool`` row id — code contents never leave the
    device."""

    itemset: Tuple[Hashable, ...]
    row: int
    length: int
    support: int


class DevicePrePost:
    """PrePost+ over a device-resident N-list pool with one fused
    gather→merge→Z-merge→scatter dispatch per pair chunk."""

    def __init__(self, early_stop: bool = True, pair_chunk: int = 8192,
                 backend: str = "auto"):
        self.early_stop = early_stop
        self.pair_chunk = min(pair_chunk, _PAIR_BUCKETS[-1])
        self.backend = backend

    def mine(self, db: Sequence[Sequence[Hashable]], minsup: int,
             ) -> Tuple[ItemsetSupports, DevicePrePostStats]:
        if minsup < 1:
            raise ValueError("minsup must be an absolute count >= 1")
        stats = DevicePrePostStats()
        t0 = time.perf_counter()

        tree = PPCTree(db, minsup)
        order_asc = list(reversed(tree.order_desc))
        out: ItemsetSupports = {}
        arrays: List[np.ndarray] = []
        for it in order_asc:
            out[frozenset((it,))] = tree.item_support[it]
            stats.nodes += 1
            arrays.append(np.asarray(tree.nlists[it], np.int32).reshape(-1, 3))

        pool = NListPool(capacity=max(
            64, 2 * sum(nl_pad_len(max(len(a), 1)) for a in arrays)))
        rows = pool.alloc_rows([len(a) for a in arrays])
        if len(arrays):
            pool.write_rows(rows, arrays)
        members = [
            _Member(itemset=(it,), row=int(r), length=len(a),
                    support=tree.item_support[it])
            for it, r, a in zip(order_asc, rows, arrays)]

        self._minsup = minsup
        self._traverse(pool, members, out, stats)
        stats.pool_grows = pool.grows
        stats.peak_codes = pool.peak_codes
        stats.runtime_s = time.perf_counter() - t0
        return out, stats

    def _traverse(self, pool: NListPool, klass: List[_Member],
                  out: ItemsetSupports, stats: DevicePrePostStats) -> None:
        for a in range(len(klass)):
            siblings = klass[a + 1:]
            if not siblings:
                pool.free_rows([klass[a].row])  # served as V only: spent
                continue
            children: List[_Member] = []
            for lo in range(0, len(siblings), self.pair_chunk):
                children.extend(self._extend_chunk(
                    pool, klass[a], siblings[lo:lo + self.pair_chunk],
                    stats))
            # klass[a] is U here and V only for earlier members: spent.
            pool.free_rows([klass[a].row])
            for ch in children:
                out[frozenset(ch.itemset)] = ch.support
                stats.nodes += 1
            if children:
                self._traverse(pool, children, out, stats)

    def _extend_chunk(self, pool: NListPool, xs: _Member,
                      chunk: List[_Member],
                      stats: DevicePrePostStats) -> List[_Member]:
        n = len(chunk)
        stats.candidates += n
        lu = nl_pad_len(xs.length)
        v_len = pool.lengths([s.row for s in chunk])
        lv = nl_pad_len(int(v_len.max()))

        # Pessimistic child extents: |child| <= min(|U|, |V|); extents of
        # dead candidates are recycled right after the dispatch, so
        # infrequent pairs cost free-list bookkeeping only.
        child_rows = pool.alloc_rows(np.minimum(xs.length, v_len))

        u_off = np.full((n,), pool.offsets([xs.row])[0], np.int32)
        u_len = np.full((n,), xs.length, np.int32)
        v_off = pool.offsets([s.row for s in chunk])
        out_off = pool.offsets(child_rows)
        rho_v = np.asarray([s.support for s in chunk], np.int32)

        def pad(arr, fill=0):
            return bucket_pad(arr, n, _PAIR_BUCKETS, fill)
        (pool.codes, child_len, support, cmps, checks,
         alive) = ops.nlist_extend(
            pool.codes, pad(u_off), pad(u_len), pad(v_off), pad(v_len),
            pad(out_off, fill=pool.capacity),   # OOB pad -> dropped
            pad(rho_v), np.int32(self._minsup),
            lu=lu, lv=lv, early_stop=self.early_stop, backend=self.backend)
        stats.device_calls += 1
        child_len = np.asarray(child_len[:n])
        support = np.asarray(support[:n])
        alive = np.asarray(alive[:n])
        stats.comparisons += int(np.asarray(cmps[:n]).sum())
        if self.early_stop:
            # One ES bound evaluation per skipped V code — exactly the
            # oracle's es_checks (the non-ES merge evaluates none).
            stats.es_checks += int(np.asarray(checks[:n]).sum())
        stats.es_aborts += int((~alive).sum())

        freq = support >= self._minsup   # aborted pairs report support 0
        pool.free_rows(child_rows[~freq])
        children: List[_Member] = []
        for b in np.nonzero(freq)[0]:
            pool.set_length(child_rows[b], child_len[b])
            children.append(_Member(
                itemset=xs.itemset + (chunk[b].itemset[-1],),
                row=int(child_rows[b]), length=int(child_len[b]),
                support=int(support[b])))
        return children


def mine_prepost_device(db, minsup, early_stop: bool = True, **kw):
    return DevicePrePost(early_stop=early_stop, **kw).mine(db, minsup)
