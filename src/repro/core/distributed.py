"""Distributed mining: count distribution over the TID axis.

Scaling story (DESIGN.md §2.4): transactions (TID bitmap *blocks*) are
sharded across the mesh's (``pod``, ``data``) axes; candidate pairs are
sharded across ``model``.  Each device computes partial popcounts over its
block shard; one ``psum`` of an ``int32[n_pairs]`` vector produces global
supports.  The transaction data never moves — the only cross-device
traffic is the per-candidate count vector, which is why the scheme scales
to thousands of chips.

Early stopping distributes as a *two-level* criterion:

  * screen round (the distributed ES): each shard computes its block-0
    partial count plus its local suffix bound; the psum of per-shard
    bounds is a *tighter* global bound than the centralized one (sum of
    per-shard minima <= minimum of sums).  Pairs whose global bound misses
    minsup are dropped on the host before any full intersection runs.
  * in-kernel block ES (TPU): within each shard the Pallas kernel walks
    its local blocks with the shard-local criterion.  A shard-local abort
    needs the global threshold to be distributed conservatively; we use
    the screen round's per-pair slack for that (see ``_local_threshold``).

Three jitted shard_map programs make up one mining round:
  screen_round  -> bounds                      (cheap, 1 collective)
  count_round   -> exact supports of survivors (1 collective)
  materialize   -> child bitmaps of frequent pairs written into the
                   device-resident row store (no collective)
The host orchestrates DFS order, row allocation and free-listing.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Dict, FrozenSet, Hashable, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.bitmap import BitmapDB, DEFAULT_BLOCK_WORDS, popcount32

ItemsetSupports = Dict[FrozenSet[Hashable], int]


# ---------------------------------------------------------------------------
# shard_map round programs
# ---------------------------------------------------------------------------

def _local_suffix(bitmaps: jnp.ndarray) -> jnp.ndarray:
    """Suffix popcounts over the LOCAL block shard: (rows, nb_local+1)."""
    per_block = popcount32(bitmaps).sum(axis=-1).astype(jnp.int32)
    rev = jnp.cumsum(per_block[:, ::-1], axis=1)[:, ::-1]
    zeros = jnp.zeros((bitmaps.shape[0], 1), jnp.int32)
    return jnp.concatenate([rev, zeros], axis=1)


def make_round_fns(mesh: Mesh, *, tid_axes: Tuple[str, ...] = ("data",),
                   pair_axis: str = "model", mode: str = "and"):
    """Build the three jitted round programs for a given mesh.

    Array layouts (global shapes):
      store:  uint32 (n_rows, n_blocks, bw)   sharded P(None, tid_axes, None)
      pairs:  int32  (n_pairs, 2)             sharded P(pair_axis, None)
      rho:    int32  (n_pairs,)               sharded P(pair_axis)
      counts: int32  (n_pairs,)               sharded P(pair_axis)
      slots:  int32  (n_pairs,)  destination rows for materialize
    """
    if mode not in ("and", "andnot"):
        raise ValueError(mode)
    tid_spec = tid_axes if len(tid_axes) > 1 else tid_axes[0]
    store_spec = P(None, tid_spec, None)
    pair_spec = P(pair_axis, None)
    vec_spec = P(pair_axis)

    def _combine(u, v):
        return u & (v if mode == "and" else ~v)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(store_spec, pair_spec, vec_spec),
        out_specs=vec_spec, check_rep=False)
    def screen_round(store, pairs, rho):
        u = store[pairs[:, 0]]            # (np_l, nb_l, bw)
        v = store[pairs[:, 1]]
        z0 = _combine(u[:, 0], v[:, 0])
        c0 = popcount32(z0).sum(axis=-1)
        if mode == "and":
            su = _local_suffix(u)[:, 1]
            sv = _local_suffix(v)[:, 1]
            local_bound = c0 + jnp.minimum(su, sv)
            return jax.lax.psum(local_bound, tid_axes)
        # andnot: global bound = rho - psum(local diff count of block 0)
        return rho - jax.lax.psum(c0, tid_axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(store_spec, pair_spec),
        out_specs=vec_spec, check_rep=False)
    def count_round(store, pairs):
        u = store[pairs[:, 0]]
        v = store[pairs[:, 1]]
        local = popcount32(_combine(u, v)).sum(axis=(-1, -2))
        return jax.lax.psum(local, tid_axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(store_spec, pair_spec, vec_spec, vec_spec),
        out_specs=store_spec, check_rep=False)
    def materialize(store, pairs, slots, keep):
        # Write child rows Z into the store at `slots` (masked by `keep`).
        # Runs entirely shard-local: every tid shard updates its own block
        # columns of the destination rows.  Pairs are replicated here
        # (pair_axis gathers happen on the host side by passing the same
        # pairs to every model shard via P(None, ...) when n is small); to
        # stay sharded we scatter with mode="drop" on masked slots.
        u = store[pairs[:, 0]]
        v = store[pairs[:, 1]]
        z = _combine(u, v)
        slots = jnp.where(keep > 0, slots, store.shape[0])  # OOB -> dropped
        return store.at[slots].set(z, mode="drop")

    screen_j = jax.jit(screen_round)
    count_j = jax.jit(count_round)

    # materialize writes to rows of the (replicated-over-pair_axis) store;
    # pairs/slots must be replicated for it, so it gets its own specs:
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(store_spec, P(None, None), P(None), P(None)),
        out_specs=store_spec, check_rep=False)
    def materialize_rep(store, pairs, slots, keep):
        u = store[pairs[:, 0]]
        v = store[pairs[:, 1]]
        z = _combine(u, v)
        slots = jnp.where(keep > 0, slots, store.shape[0])
        return store.at[slots].set(z, mode="drop")

    mat_j = jax.jit(materialize_rep, donate_argnums=(0,))
    del materialize
    return screen_j, count_j, mat_j


def make_mining_round(mesh: Mesh, *, pair_chunk: int = 2048):
    """Fused screen+count round used by the dry-run/roofline harness.

    Pure Count Distribution (Agrawal & Shafer '96 adapted to Eclat): the
    bitmap store's BLOCK axis is sharded across EVERY mesh axis (a 1.07B-
    transaction store is 4GB/chip on 256 chips); the candidate pair list
    is replicated; each chip computes partial popcounts + local suffix
    screen bounds on its block shard, and one psum of two int32[n_pairs]
    vectors produces global bounds/counts.  The transaction data never
    moves.

    Pairs are processed in ``pair_chunk``-sized slices with ``lax.scan``
    so per-chunk gather buffers are provably reused (an unrolled loop let
    the scheduler keep every chunk's 3GB gather alive).  cost_analysis
    counts the scan body once; the dry-run reconstructs totals from two
    reduced-pair compiles (same fit as the LM cells)."""
    all_axes = tuple(mesh.axis_names)
    tid_spec = all_axes if len(all_axes) > 1 else all_axes[0]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, tid_spec, None), P(None, None), P(None)),
        out_specs=(P(None), P(None)), check_rep=False)
    def mining_round(store, pairs, rho):
        del rho
        n = pairs.shape[0]
        chunk = min(pair_chunk, n)
        pc = pairs.reshape(n // chunk, chunk, 2)

        def body(_, p):
            u = store[p[:, 0]]            # (chunk, nb_local, bw)
            v = store[p[:, 1]]
            z0 = u[:, 0] & v[:, 0]
            c0 = popcount32(z0).sum(axis=-1)
            su = _local_suffix(u)[:, 1]
            sv = _local_suffix(v)[:, 1]
            bound_c = c0 + jnp.minimum(su, sv)
            count_c = popcount32(u & v).sum(axis=(-1, -2))
            return None, (bound_c, count_c)

        _, (bounds, counts) = jax.lax.scan(body, None, pc)
        bound = jax.lax.psum(bounds.reshape(n), all_axes)
        count = jax.lax.psum(counts.reshape(n), all_axes)
        return bound, count

    return mining_round


def make_mining_round_v2(mesh: Mesh, *, pair_chunk: int = 2048):
    """Optimised mining round (hillclimb variant, EXPERIMENTS.md §Perf).

    Two changes over ``make_mining_round``, both beyond-paper engineering
    on top of the paper's criterion:

      1. PRECOMPUTED shard-local suffix masses: the baseline recomputes
         each operand's suffix popcounts from its full gathered row (per
         pair!).  The mass "popcount of blocks 1.. on shard s" is a
         per-(row, shard) invariant maintained when rows materialise, so
         the round takes it as ``suffix1 (rows, n_shards)`` (each shard
         owns its column) and the screen touches only block 0 + one
         scalar per operand.
      2. SHARED-``a`` chunking: the host already batches sibling pairs of
         one class member 'a'; with ``pairs[c, :, 0]`` constant per chunk
         the u-row is gathered ONCE per chunk instead of per pair
         (u-traffic / pair_chunk).
    """
    all_axes = tuple(mesh.axis_names)
    tid_spec = all_axes if len(all_axes) > 1 else all_axes[0]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, tid_spec, None), P(None, tid_spec), P(None, None),
                  P(None)),
        out_specs=(P(None), P(None)), check_rep=False)
    def mining_round(store, suffix1, pairs, rho):
        del rho
        n = pairs.shape[0]
        chunk = min(pair_chunk, n)
        pc = pairs.reshape(n // chunk, chunk, 2)

        def body(_, p):
            a_row = p[0, 0]               # shared-'a' chunk
            u = store[a_row]              # (nb_local, bw) — ONE gather
            su = suffix1[a_row, 0]        # local column of this shard
            v = store[p[:, 1]]            # (chunk, nb_local, bw)
            sv = suffix1[p[:, 1], 0]
            z0 = u[0][None] & v[:, 0]
            c0 = popcount32(z0).sum(axis=-1)
            bound_c = c0 + jnp.minimum(su, sv)
            count_c = popcount32(u[None] & v).sum(axis=(-1, -2))
            return None, (bound_c, count_c)

        _, (bounds, counts) = jax.lax.scan(body, None, pc)
        bound = jax.lax.psum(bounds.reshape(n), all_axes)
        count = jax.lax.psum(counts.reshape(n), all_axes)
        return bound, count

    return mining_round


# ---------------------------------------------------------------------------
# Host orchestrator
# ---------------------------------------------------------------------------

@dataclass
class DistributedStats:
    candidates: int = 0
    nodes: int = 0
    screened_out: int = 0
    rounds: int = 0
    runtime_s: float = 0.0

    def as_dict(self):
        return dict(candidates=self.candidates, nodes=self.nodes,
                    screened_out=self.screened_out, rounds=self.rounds,
                    runtime_s=round(self.runtime_s, 6))


class DistributedMiner:
    """Count-distribution Eclat over a device mesh.

    The row store is a device-resident sharded ``uint32`` array with a
    host-side free-list allocator; DFS recursion, slot bookkeeping and the
    screen/count/materialize round sequencing run on the host.
    """

    def __init__(self, mesh: Mesh, *, tid_axes: Tuple[str, ...] = ("data",),
                 pair_axis: str = "model", early_stop: bool = True,
                 capacity: int = 4096, pair_chunk: int = 4096,
                 block_words: int = DEFAULT_BLOCK_WORDS):
        self.mesh = mesh
        self.tid_axes = tid_axes
        self.pair_axis = pair_axis
        self.early_stop = early_stop
        self.capacity = capacity
        self.pair_chunk = pair_chunk
        self.block_words = block_words
        self.screen_j, self.count_j, self.mat_j = make_round_fns(
            mesh, tid_axes=tid_axes, pair_axis=pair_axis, mode="and")
        tid_spec = tid_axes if len(tid_axes) > 1 else tid_axes[0]
        self._store_sharding = NamedSharding(mesh, P(None, tid_spec, None))
        self._pair_sharding = NamedSharding(mesh, P(pair_axis, None))
        self._vec_sharding = NamedSharding(mesh, P(pair_axis))
        self._rep_pair = NamedSharding(mesh, P(None, None))
        self._rep_vec = NamedSharding(mesh, P(None))

    # -- helpers ------------------------------------------------------------

    def _pad_pairs(self, n: int) -> int:
        """Pair batches padded to a multiple of the pair-axis size."""
        m = self.mesh.shape[self.pair_axis] * 64
        return max(m, ((n + m - 1) // m) * m)

    def mine(self, db: Sequence[Sequence[Hashable]], minsup: int,
             ) -> Tuple[ItemsetSupports, DistributedStats]:
        if minsup < 1:
            raise ValueError("minsup must be an absolute count >= 1")
        stats = DistributedStats()
        t0 = time.perf_counter()

        bdb = BitmapDB.from_db(db, minsup, self.block_words)
        n_items, nb, bw = bdb.bitmaps.shape
        # Pad the block axis so it divides the tid mesh axes.
        tid_size = 1
        for ax in self.tid_axes:
            tid_size *= self.mesh.shape[ax]
        nb_pad = ((nb + tid_size - 1) // tid_size) * tid_size
        cap = max(self.capacity, n_items + self.pair_chunk)
        store_np = np.zeros((cap, nb_pad, bw), np.uint32)
        store_np[:n_items, :nb] = bdb.bitmaps
        store = jax.device_put(store_np, self._store_sharding)
        del store_np

        free: List[int] = list(range(cap - 1, n_items - 1, -1))
        out: ItemsetSupports = {}
        supports: Dict[int, int] = {}
        for r, item in enumerate(bdb.items):
            out[frozenset((item,))] = int(bdb.supports[r])
            supports[r] = int(bdb.supports[r])
            stats.nodes += 1

        minsup_i = minsup

        def run_class(members: List[Tuple[Tuple[Hashable, ...], int]]):
            # members: list of (itemset, store_row); already frequent.
            for a in range(len(members)):
                sibs = members[a + 1:]
                if not sibs:
                    continue
                children: List[Tuple[Tuple[Hashable, ...], int]] = []
                for lo in range(0, len(sibs), self.pair_chunk):
                    chunk = sibs[lo:lo + self.pair_chunk]
                    children.extend(self._round(
                        store_ref, members[a], chunk, supports, out,
                        free, stats, minsup_i))
                if children:
                    run_class(children)
                    for _, row in children:
                        free.append(row)
                        supports.pop(row, None)

        # Small indirection so _round can swap the donated store handle.
        store_ref = [store]
        run_class([((it,), r) for r, it in enumerate(bdb.items)])
        stats.runtime_s = time.perf_counter() - t0
        return out, stats

    def _round(self, store_ref, px, chunk, supports, out, free, stats,
               minsup) -> List[Tuple[Tuple[Hashable, ...], int]]:
        store = store_ref[0]
        n = len(chunk)
        stats.candidates += n
        stats.rounds += 1
        a_row = px[1]
        pairs_np = np.zeros((self._pad_pairs(n), 2), np.int32)
        pairs_np[:n, 0] = a_row
        pairs_np[:n, 1] = [row for _, row in chunk]
        rho_np = np.zeros((pairs_np.shape[0],), np.int32)
        rho_np[:n] = supports[a_row]

        pairs = jax.device_put(pairs_np, self._pair_sharding)
        rho = jax.device_put(rho_np, self._vec_sharding)

        if self.early_stop:
            bound = np.asarray(self.screen_j(store, pairs, rho))[:n]
            alive = bound >= minsup
            stats.screened_out += int((~alive).sum())
            if not alive.any():
                return []
        else:
            alive = np.ones((n,), bool)

        counts = np.asarray(self.count_j(store, pairs))[:n]
        freq_mask = np.logical_and(alive, counts >= minsup)
        freq_idx = np.nonzero(freq_mask)[0]
        if freq_idx.size == 0:
            return []

        if len(free) < freq_idx.size:
            raise RuntimeError(
                f"row store exhausted ({self.capacity} rows): raise capacity")
        slots = np.array([free.pop() for _ in freq_idx], np.int32)
        keep_np = np.zeros((pairs_np.shape[0],), np.int32)
        keep_np[freq_idx] = 1
        slots_np = np.zeros((pairs_np.shape[0],), np.int32)
        slots_np[freq_idx] = slots

        store = self.mat_j(
            store,
            jax.device_put(pairs_np, self._rep_pair),
            jax.device_put(slots_np, self._rep_vec),
            jax.device_put(keep_np, self._rep_vec))
        store_ref[0] = store

        children = []
        for s, bi in zip(slots, freq_idx):
            child_set = px[0] + (chunk[int(bi)][0][-1],)
            sup = int(counts[bi])
            out[frozenset(child_set)] = sup
            supports[int(s)] = sup
            stats.nodes += 1
            children.append((child_set, int(s)))
        return children
