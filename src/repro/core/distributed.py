"""Distributed mining: count distribution over the TID axis.

Scaling story (DESIGN.md §2.4): transactions (TID bitmap *blocks*) are
sharded across the mesh axes; candidate pairs are replicated.  Each
device computes partial popcounts over its block shard; one ``psum`` of
``int32[n_pairs]`` vectors produces global bounds/supports.  The
transaction data never moves — the only cross-device traffic is the
per-candidate count vectors, which is why the scheme scales to
thousands of chips (Count Distribution, Agrawal & Shafer '96, adapted
to Eclat).

Early stopping distributes twice over (the sharded instantiation of
the paper's INTERSECT_ES).  Between dispatches it is the *two-level
screen*: each shard computes its block-0 partial count plus its local
suffix bound; the psum of per-shard bounds is a *tighter* global bound
than the centralized one (sum of per-shard minima <= minimum of sums),
and pairs whose global bound misses minsup are never expanded.  Inside
a dispatch it is *shard-local block ES* (ISSUE 4): the screen's
per-pair slack — the mass every OTHER shard could still contribute —
is psum'd up front, and each shard walks its local blocks against the
conservative threshold ``minsup - slack``, aborting mid-scan the
moment the pair is provably infrequent globally, exactly like the
single-device blocked scan.

Since ISSUE 2 the ``DistributedMiner`` is a thin subclass of
``core.eclat.BitmapMiner``: both engines share one allocator
(``core.rowstore.DeviceRowStore``, block-sharded here) and one fused
gather→screen→intersect→scatter dispatch per pair chunk
(``kernels.ops.make_screen_and_intersect_sharded``, bit-exact against
``kernels.ref.screen_and_intersect_sharded_ref``).  The legacy three
round programs (screen/count/materialize — 3 dispatches + 2
collectives per round, with their own ad-hoc slab and duplicated
free-list plumbing) are gone; a mining round is ONE dispatch with ONE
psum, and the row store grows on demand instead of dead-ending in a
"row store exhausted" error.  Since ISSUE 5 the psum is also the
dispatch's internal dependency edge for **survivor-only
materialization**: every shard knows the global count/alive before its
shard-local scatter phase, so a candidate the screen or scan killed is
never written to the slab — child scatter traffic scales with frequent
children, not candidates (``stats.child_scatters``).

``make_mining_round`` / ``make_mining_round_v2`` remain: they are the
standalone round programs used by the dry-run/roofline harness (cost
analysis wants an isolated lowerable SPMD program, not a live miner).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.bitmap import DEFAULT_BLOCK_WORDS, BitmapDB, popcount32
from repro.core.guards import host_sync
from repro.core.eclat import (BitmapMiner, DeviceMiningStats, _bucket_pad,
                              ItemsetSupports)  # noqa: F401 (re-export)
from repro.core.rowstore import DeviceRowStore
from repro.kernels import ops

# Back-compat alias: the unified engine reports the same stats object as
# the single-device miner (``rounds`` became ``device_calls``).
DistributedStats = DeviceMiningStats


# ---------------------------------------------------------------------------
# Standalone round programs (dry-run / roofline harness)
# ---------------------------------------------------------------------------

def _local_suffix(bitmaps: jnp.ndarray) -> jnp.ndarray:
    """Suffix popcounts over the LOCAL block shard: (rows, nb_local+1)."""
    per_block = popcount32(bitmaps).sum(axis=-1).astype(jnp.int32)
    rev = jnp.cumsum(per_block[:, ::-1], axis=1)[:, ::-1]
    zeros = jnp.zeros((bitmaps.shape[0], 1), jnp.int32)
    return jnp.concatenate([rev, zeros], axis=1)


def make_mining_round(mesh: Mesh, *, pair_chunk: int = 2048):
    """Fused screen+count round used by the dry-run/roofline harness.

    Pure Count Distribution (Agrawal & Shafer '96 adapted to Eclat): the
    bitmap store's BLOCK axis is sharded across EVERY mesh axis (a 1.07B-
    transaction store is 4GB/chip on 256 chips); the candidate pair list
    is replicated; each chip computes partial popcounts + local suffix
    screen bounds on its block shard, and one psum of two int32[n_pairs]
    vectors produces global bounds/counts.  The transaction data never
    moves.

    Pairs are processed in ``pair_chunk``-sized slices with ``lax.scan``
    so per-chunk gather buffers are provably reused (an unrolled loop let
    the scheduler keep every chunk's 3GB gather alive).  cost_analysis
    counts the scan body once; the dry-run reconstructs totals from two
    reduced-pair compiles (same fit as the LM cells)."""
    all_axes = tuple(mesh.axis_names)
    tid_spec = all_axes if len(all_axes) > 1 else all_axes[0]

    def mining_round(store, pairs, rho):
        del rho
        n = pairs.shape[0]
        chunk = min(pair_chunk, n)
        pc = pairs.reshape(n // chunk, chunk, 2)

        def body(_, p):
            u = store[p[:, 0]]            # (chunk, nb_local, bw)
            v = store[p[:, 1]]
            z0 = u[:, 0] & v[:, 0]
            c0 = popcount32(z0).sum(axis=-1)
            su = _local_suffix(u)[:, 1]
            sv = _local_suffix(v)[:, 1]
            bound_c = c0 + jnp.minimum(su, sv)
            count_c = popcount32(u & v).sum(axis=(-1, -2))
            return None, (bound_c, count_c)

        _, (bounds, counts) = jax.lax.scan(body, None, pc)
        bound = jax.lax.psum(bounds.reshape(n), all_axes)
        count = jax.lax.psum(counts.reshape(n), all_axes)
        return bound, count

    return shard_map(
        mining_round, mesh=mesh,
        in_specs=(P(None, tid_spec, None), P(None, None), P(None)),
        out_specs=(P(None), P(None)), check_rep=False)


def make_mining_round_v2(mesh: Mesh, *, pair_chunk: int = 2048):
    """Optimised mining round (hillclimb variant, EXPERIMENTS.md §Perf).

    Two changes over ``make_mining_round``, both beyond-paper engineering
    on top of the paper's criterion:

      1. PRECOMPUTED shard-local suffix masses: the baseline recomputes
         each operand's suffix popcounts from its full gathered row (per
         pair!).  The mass "popcount of blocks 1.. on shard s" is a
         per-(row, shard) invariant maintained when rows materialise, so
         the round takes it as ``suffix1 (rows, n_shards)`` (each shard
         owns its column) and the screen touches only block 0 + one
         scalar per operand.
      2. SHARED-``a`` chunking: the host already batches sibling pairs of
         one class member 'a'; with ``pairs[c, :, 0]`` constant per chunk
         the u-row is gathered ONCE per chunk instead of per pair
         (u-traffic / pair_chunk).
    """
    all_axes = tuple(mesh.axis_names)
    tid_spec = all_axes if len(all_axes) > 1 else all_axes[0]

    def mining_round(store, suffix1, pairs, rho):
        del rho
        n = pairs.shape[0]
        chunk = min(pair_chunk, n)
        pc = pairs.reshape(n // chunk, chunk, 2)

        def body(_, p):
            a_row = p[0, 0]               # shared-'a' chunk
            u = store[a_row]              # (nb_local, bw) — ONE gather
            su = suffix1[a_row, 0]        # local column of this shard
            v = store[p[:, 1]]            # (chunk, nb_local, bw)
            sv = suffix1[p[:, 1], 0]
            z0 = u[0][None] & v[:, 0]
            c0 = popcount32(z0).sum(axis=-1)
            bound_c = c0 + jnp.minimum(su, sv)
            count_c = popcount32(u[None] & v).sum(axis=(-1, -2))
            return None, (bound_c, count_c)

        _, (bounds, counts) = jax.lax.scan(body, None, pc)
        bound = jax.lax.psum(bounds.reshape(n), all_axes)
        count = jax.lax.psum(counts.reshape(n), all_axes)
        return bound, count

    return shard_map(
        mining_round, mesh=mesh,
        in_specs=(P(None, tid_spec, None), P(None, tid_spec), P(None, None),
                  P(None)),
        out_specs=(P(None), P(None)), check_rep=False)


# ---------------------------------------------------------------------------
# Unified distributed miner
# ---------------------------------------------------------------------------

class DistributedMiner(BitmapMiner):
    """Count-distribution Eclat / dEclat / adaptive over a device mesh.

    The host/DFS split, drain-group batching, free-list bookkeeping,
    allocator compaction scheduling, representation policy
    (``scheme``/``diff_density``/``diff_hysteresis`` — ISSUE 6) and
    stats all come from ``BitmapMiner`` driving
    ``core.frontier.FrontierScheduler``; this class only swaps in

      * a block-sharded ``DeviceRowStore`` (slab + per-shard suffix
        tables under ``NamedSharding``s, growing on demand), and
      * the fused shard_map dispatches — one device call and one psum
        per pair chunk (per representation present in the chunk), no
        separate screen/count/materialize programs.  Tidset chunks run
        the ``mode="and"`` program; diffset chunks the ``mode="andnot"``
        program, whose shard-local scan walks the difference bound
        ``rho - count`` and charges only nonzero-mass U blocks.

    ``tid_axes`` defaults to every mesh axis not named by ``cls_axes``
    (maximum block parallelism); ``cls_axes`` defaults to ``("cls",)``
    when the mesh has an axis of that name (the ``make_mining_mesh``
    convention) and to none otherwise.  ``capacity`` is an initial-size
    hint only: the slab grows instead of raising.  ``pair_axis`` is
    accepted for backward compatibility and ignored — pairs are
    replicated over the block axes; under a 2-D mesh (ISSUE 9) each
    cls-shard evaluates its contiguous slice of the chunk's pair
    vectors, so the psum'd per-pair vectors shrink by n_cls and the
    frontier scan itself parallelizes.
    """

    def __init__(self, mesh: Mesh, *,
                 tid_axes: Tuple[str, ...] = None,
                 cls_axes: Tuple[str, ...] = None,
                 pair_axis: str = None,
                 scheme: str = "eclat",
                 early_stop: bool = True,
                 capacity: int = 4096, pair_chunk: int = 4096,
                 block_words: int = DEFAULT_BLOCK_WORDS,
                 compact_occupancy: float = 0.25,
                 diff_density: "float | None" = None,
                 diff_hysteresis: float = 0.05, inflight: int = 2,
                 autotune_chunk: bool = False):
        super().__init__(scheme=scheme, early_stop=early_stop,
                         block_words=block_words, pair_chunk=pair_chunk,
                         backend="jnp",
                         compact_occupancy=compact_occupancy,
                         diff_density=diff_density,
                         diff_hysteresis=diff_hysteresis,
                         inflight=inflight,
                         autotune_chunk=autotune_chunk)
        del pair_axis
        self.mesh = mesh
        if cls_axes is None:
            # make_mining_mesh names its pair axis "cls"; honour that by
            # default so callers don't have to thread axis tuples.
            cls_axes = ("cls",) if (tid_axes is None
                                    and "cls" in mesh.axis_names) else ()
        self.cls_axes = tuple(cls_axes)
        if tid_axes is None:
            tid_axes = tuple(a for a in mesh.axis_names
                             if a not in self.cls_axes)
        self.tid_axes = tuple(tid_axes)
        if set(self.tid_axes) & set(self.cls_axes):
            raise ValueError("tid_axes and cls_axes overlap")
        self.n_cls = 1
        for ax in self.cls_axes:
            self.n_cls *= mesh.shape[ax]
        # Chunk slices must land on cls-shard boundaries so each shard's
        # pair slice is a contiguous, bucket-sorted run (core.frontier
        # reads this attribute).
        self.chunk_quantum = self.n_cls
        self.capacity = capacity
        # Two fused shard_map programs share the factory's lru_cache:
        # ``_fused`` ("and") extends tidset classes — it keeps its
        # pre-ISSUE-6 name so call-counting harnesses that wrap the
        # attribute still see every tidset dispatch — and
        # ``_fused_diff`` ("andnot") is the diffset difference with the
        # skip-aware work counter.
        self._fused = ops.make_screen_and_intersect_sharded(
            mesh, tid_axes=self.tid_axes, mode="and",
            early_stop=early_stop, cls_axes=self.cls_axes)
        self._fused_diff = ops.make_screen_and_intersect_sharded(
            mesh, tid_axes=self.tid_axes, mode="andnot",
            early_stop=early_stop, cls_axes=self.cls_axes)

    def _autotune_words_per_pair(self, bdb: BitmapDB) -> int:
        # Each cls-shard holds 1/n_cls of the chunk's gathered rows, so
        # the per-device VMEM budget divides by n_cls (satellite 6) —
        # ceil so the width never overshoots the budget.
        return -(-(bdb.n_blocks * self.block_words) // self.n_cls)

    def _make_store(self, bdb: BitmapDB) -> DeviceRowStore:
        return DeviceRowStore(
            bdb.bitmaps,
            capacity=max(self.capacity,
                         bdb.n_items + min(self.pair_chunk, 4096)),
            mesh=self.mesh, tid_axes=self.tid_axes)

    def _dispatch_launch(self, store: DeviceRowStore, ua: np.ndarray,
                         vb: np.ndarray, slots: np.ndarray,
                         rho: np.ndarray, mode: str) -> Tuple:
        """Launch the fused shard_map dispatch; NO host sync (the
        blocking readbacks live in ``_dispatch_resolve``, ISSUE 7)."""
        # "and" -> tidset intersect program, "diff" -> diffset
        # difference program (ISSUE 6: declat/adaptive schemes route
        # their diff chunks here; both programs were built in __init__).
        fused = self._fused if mode == "and" else self._fused_diff
        n = int(ua.size)
        cap = store.capacity
        (store.rows, store.suffix, bound, count, blocks,
         scan_alive) = fused(
            store.rows, store.suffix,
            _bucket_pad(ua, n), _bucket_pad(vb, n),
            _bucket_pad(slots, n, fill=cap),   # OOB pad -> dropped
            _bucket_pad(rho, n), np.int32(self._minsup),
            np.int32(self._n_blocks))   # real (unpadded) block count
        self._stats.device_calls += 1
        return bound, count, blocks, scan_alive

    def _dispatch_resolve(self, raw: Tuple, n: int,
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Blocking readback of one sharded dispatch + attribution."""
        stats = self._stats
        bound, count, blocks, scan_alive = raw
        # host-sync: the audited group-retirement readback (PR 7) — one
        # deliberate d2h per retired sharded dispatch
        with host_sync("group-retirement accounting readback"):
            bound = np.asarray(bound[:n])
            count = np.asarray(count[:n])
            blocks = np.asarray(blocks[:n])
            scan_alive = np.asarray(scan_alive[:n])
        # In-dispatch shard-local block ES (ISSUE 4): each shard walks its
        # local blocks against the conservative threshold
        # ``minsup - slack`` (slack = the screen mass every OTHER shard
        # could still contribute) and aborts mid-scan once the pair is
        # provably infrequent globally.  ``blocks`` is the psum of REAL
        # local blocks scanned (the dispatch discounts the store's
        # all-zero block padding — ISSUE 5), so word_ops and
        # word_ops_full are consistently unpadded: an ES-off run reports
        # word_ops == word_ops_full and saved_frac is never negative.
        stats.word_ops += int(blocks.sum()) * self.block_words
        if self.early_stop:
            screen_alive = bound >= self._minsup
            alive = np.logical_and(screen_alive, scan_alive)
            # Attribution: the psum'd two-level screen claims its deaths
            # first; pairs it passed but a shard's scan aborted are
            # in-dispatch kernel aborts.
            stats.screened_out += int((~screen_alive).sum())
            stats.kernel_aborts += int(
                np.logical_and(screen_alive, ~scan_alive).sum())
        else:
            alive = np.ones(n, bool)
        return count, alive
