"""Mining CLI.

    python -m repro.core.cli --dataset retail-like --scheme eclat --es
    python -m repro.core.cli --input basket.dat --minsup 0.01 --engine bitmap

``--input`` reads FIMI format (one transaction per line, space-separated
item ids); ``--dataset`` uses a built-in replica.  ``--minsup`` < 1 is
relative, >= 1 absolute.  Engines: ``oracle`` (paper Algorithms 1-3) or
``bitmap`` (the device engine).
"""

from __future__ import annotations

import argparse
import json
import sys


def read_fimi(path: str):
    db = []
    with open(path) as f:
        for line in f:
            t = line.split()
            if t:
                db.append([int(x) for x in t])
    return db


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--dataset", help="built-in replica name")
    src.add_argument("--input", help="FIMI-format transaction file")
    ap.add_argument("--minsup", type=float, default=0.01,
                    help="<1: relative; >=1: absolute count")
    ap.add_argument("--scheme",
                    choices=("eclat", "declat", "adaptive", "prepost"),
                    default="eclat")
    ap.add_argument("--diff-density", type=float, default=None,
                    help="adaptive scheme: density threshold for the "
                         "tidset->diffset flip (default 0.5)")
    ap.add_argument("--diff-hysteresis", type=float, default=None,
                    help="adaptive scheme: band above the threshold "
                         "the flip must clear (default 0.05)")
    ap.add_argument("--block-words", type=int, default=8,
                    help="bitmap engine: words per ES block")
    ap.add_argument("--engine", choices=("oracle", "bitmap"),
                    default="bitmap")
    ap.add_argument("--es", action="store_true", default=True,
                    help="early stopping (default on)")
    ap.add_argument("--no-es", dest="es", action="store_false")
    ap.add_argument("--top", type=int, default=10,
                    help="print the N most frequent itemsets")
    ap.add_argument("--json-out", default="",
                    help="write all frequent itemsets to a JSON file")
    args = ap.parse_args()

    if args.dataset:
        from repro.data import make_dataset
        db, _ = make_dataset(args.dataset)
    else:
        db = read_fimi(args.input)
    minsup = (int(args.minsup) if args.minsup >= 1
              else max(1, int(round(args.minsup * len(db)))))
    print(f"|DB|={len(db)} transactions, minSup={minsup} "
          f"({minsup / len(db):.4%}), scheme={args.scheme}, "
          f"engine={args.engine}, ES={'on' if args.es else 'off'}",
          file=sys.stderr)

    if args.engine == "bitmap":
        if args.scheme == "prepost":
            from repro.core.prepost import mine_prepost_device
            out, stats = mine_prepost_device(db, minsup,
                                             early_stop=args.es)
        else:
            from repro.core.eclat import mine_bitmap
            kw = {}
            if args.diff_density is not None:
                kw["diff_density"] = args.diff_density
            if args.diff_hysteresis is not None:
                kw["diff_hysteresis"] = args.diff_hysteresis
            out, stats = mine_bitmap(db, minsup, scheme=args.scheme,
                                     early_stop=args.es,
                                     block_words=args.block_words, **kw)
    else:
        from repro.core.oracle import mine
        # The oracle has no adaptive mode; the result set is
        # scheme-invariant, so eclat is the reference for it.
        scheme = "eclat" if args.scheme == "adaptive" else args.scheme
        out, stats = mine(db, minsup, scheme, early_stop=args.es)

    print(f"frequent itemsets: {len(out)}", file=sys.stderr)
    print(json.dumps(stats.as_dict(), indent=1), file=sys.stderr)

    top = sorted(out.items(), key=lambda kv: (-kv[1], sorted(map(str,
                                                                 kv[0]))))
    for itemset, support in top[:args.top]:
        print(f"{support}\t{{{','.join(str(i) for i in sorted(itemset, key=str))}}}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({",".join(str(i) for i in sorted(s, key=str)): c
                       for s, c in out.items()}, f)
        print(f"wrote {len(out)} itemsets to {args.json_out}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
