"""Device-resident bitmap row store with a host-managed free list.

The frontier engine's hot-path data structure (DESIGN.md §2, ISSUE 1):
every TID bitmap / diffset row that the DFS can still touch lives in one
preallocated device slab ``uint32[capacity, n_blocks, block_words]`` with
a parallel suffix-popcount slab.  The host never sees row *contents* — it
only moves row *indices* around:

  * ``alloc(k)`` hands out ``k`` free slots (growing the slab on demand);
  * the fused kernel (``kernels.ops.screen_and_intersect`` or its
    shard_map variant) gathers operands by index and scatters children
    back by slot index;
  * ``free(ids)`` returns slots of dead candidates / expanded classes.

Both mining engines allocate from this class (ISSUE 2 unification):

* **Single-device** (``mesh=None``): ``suffix`` is the global suffix
  table ``int32[capacity, n_blocks + 1]`` (``core.bitmap``'s layout).
* **Sharded** (``mesh`` given): the block axis of ``rows`` is sharded
  across ``tid_axes`` under a ``NamedSharding`` (``n_blocks`` is padded
  up to a multiple of the shard count), and ``suffix`` holds the
  *per-shard* suffix tables concatenated along axis 1 —
  ``int32[capacity, n_shards * (local_blocks + 1)]``, column-sharded so
  each shard owns exactly its own ``(local_blocks + 1)``-wide local
  suffix table.  With one shard the two layouts coincide.

Growth doubles capacity (device concat of a zero slab, re-placed under
the store's sharding).  Capacities are rounded to the next power of two
so the jit cache sees few distinct store shapes.  Exhaustion can no
longer happen: ``alloc`` grows instead of raising.
"""

from __future__ import annotations

from typing import Iterable, List, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bitmap import popcount32_np, suffix_popcounts


def _round_capacity(n: int) -> int:
    cap = 64
    while cap < n:
        cap *= 2
    return cap


def _local_suffix_tables(rows_np: np.ndarray, n_shards: int) -> np.ndarray:
    """Per-shard suffix tables, concatenated: (n, n_shards*(nb_local+1)).

    Shard ``s`` owns columns ``[s*(nbl+1), (s+1)*(nbl+1))`` — its local
    analogue of :func:`repro.core.bitmap.suffix_popcounts_np`."""
    n, nb, _ = rows_np.shape
    nbl = nb // n_shards
    per_block = popcount32_np(rows_np).sum(axis=-1).astype(np.int32)
    pb = per_block.reshape(n, n_shards, nbl)
    suf = np.zeros((n, n_shards, nbl + 1), np.int32)
    suf[:, :, :-1] = pb[:, :, ::-1].cumsum(axis=-1)[:, :, ::-1]
    return suf.reshape(n, n_shards * (nbl + 1))


class DeviceRowStore:
    """Slab of bitmap rows + suffix tables resident on device.

    ``mesh``/``tid_axes``: when given, the block axis is sharded across
    the product of those mesh axes and both slabs live under
    ``NamedSharding``s (see module docstring for the suffix layout).
    """

    def __init__(self, rows_np: np.ndarray, *, capacity: int = 0,
                 mesh: Optional[Mesh] = None,
                 tid_axes: Optional[Tuple[str, ...]] = None):
        n, nb, bw = rows_np.shape
        cap = _round_capacity(max(capacity, n, 1))

        self.mesh = mesh
        self._rows_sharding = None
        self._suffix_sharding = None
        if mesh is None:
            self.n_shards = 1
        else:
            tid_axes = tuple(tid_axes) if tid_axes else tuple(mesh.axis_names)
            self.tid_axes = tid_axes
            self.n_shards = 1
            for ax in tid_axes:
                self.n_shards *= mesh.shape[ax]
            # Pad the block axis so it divides the tid shard count.
            nb = -(-nb // self.n_shards) * self.n_shards
            tid_spec: Union[str, Tuple[str, ...]] = (
                tid_axes if len(tid_axes) > 1 else tid_axes[0])
            self._rows_sharding = NamedSharding(mesh, P(None, tid_spec, None))
            self._suffix_sharding = NamedSharding(mesh, P(None, tid_spec))

        slab = np.zeros((cap, nb, bw), np.uint32)
        slab[:n, :rows_np.shape[1]] = rows_np
        self.n_blocks = nb
        self.local_blocks = nb // self.n_shards
        self.block_words = bw
        if mesh is None:
            self.rows = jnp.asarray(slab)             # uint32 (cap, nb, bw)
            self.suffix = suffix_popcounts(self.rows)  # int32 (cap, nb+1)
        else:
            self.rows = jax.device_put(slab, self._rows_sharding)
            self.suffix = jax.device_put(
                _local_suffix_tables(slab, self.n_shards),
                self._suffix_sharding)
        self._free: List[int] = list(range(cap - 1, n - 1, -1))
        self.grows = 0
        self.peak_live = n

    @property
    def capacity(self) -> int:
        return int(self.rows.shape[0])

    @property
    def n_live(self) -> int:
        return self.capacity - len(self._free)

    def alloc(self, k: int) -> np.ndarray:
        """Pop ``k`` free slots (int32), growing the slab if needed."""
        if len(self._free) < k:
            self._grow(self.n_live + k)
        slots = np.asarray([self._free.pop() for _ in range(k)], np.int32)
        self.peak_live = max(self.peak_live, self.n_live)
        return slots

    def free(self, ids: Iterable[int]) -> None:
        self._free.extend(int(i) for i in ids)

    def _grow(self, need: int) -> None:
        old = self.capacity
        new = _round_capacity(max(2 * old, need))
        rows = jnp.concatenate(
            [self.rows,
             jnp.zeros((new - old, self.n_blocks, self.block_words),
                       jnp.uint32)])
        suffix = jnp.concatenate(
            [self.suffix,
             jnp.zeros((new - old, self.suffix.shape[1]), jnp.int32)])
        if self._rows_sharding is not None:
            # Re-place explicitly: concat of a sharded slab with fresh
            # zeros must stay block-sharded for the shard_map dispatch.
            rows = jax.device_put(rows, self._rows_sharding)
            suffix = jax.device_put(suffix, self._suffix_sharding)
        self.rows = rows
        self.suffix = suffix
        self._free.extend(range(new - 1, old - 1, -1))
        self.grows += 1
