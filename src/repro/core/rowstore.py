"""Device-resident bitmap row store with a host-managed free list.

The frontier engine's hot-path data structure (DESIGN.md §2, ISSUE 1):
every TID bitmap / diffset row that the DFS can still touch lives in one
preallocated device slab ``uint32[capacity, n_blocks, block_words]`` with
a parallel suffix-popcount slab ``int32[capacity, n_blocks + 1]``.  The
host never sees row *contents* — it only moves row *indices* around:

  * ``alloc(k)`` hands out ``k`` free slots (growing the slab on demand);
  * the fused kernel (``kernels.ops.screen_and_intersect``) gathers
    operands by index and scatters children back by slot index;
  * ``free(ids)`` returns slots of dead candidates / expanded classes.

This is the same design the count-distribution miner sketches in
``core/distributed.py`` (host free-list + device ``.at[slots].set``
materialisation); it lives here so both engines can converge on one
implementation (ROADMAP open item).

Growth doubles capacity (device concat of a zero slab).  Capacities are
rounded to the next power of two so the jit cache sees few distinct
store shapes.
"""

from __future__ import annotations

from typing import Iterable, List

import numpy as np

import jax.numpy as jnp

from repro.core.bitmap import suffix_popcounts


def _round_capacity(n: int) -> int:
    cap = 64
    while cap < n:
        cap *= 2
    return cap


class DeviceRowStore:
    """Slab of bitmap rows + suffix tables resident on device."""

    def __init__(self, rows_np: np.ndarray, *, capacity: int = 0):
        n, nb, bw = rows_np.shape
        cap = _round_capacity(max(capacity, n, 1))
        slab = np.zeros((cap, nb, bw), np.uint32)
        slab[:n] = rows_np
        self.rows = jnp.asarray(slab)                 # uint32 (cap, nb, bw)
        self.suffix = suffix_popcounts(self.rows)     # int32  (cap, nb+1)
        self.n_blocks = nb
        self.block_words = bw
        self._free: List[int] = list(range(cap - 1, n - 1, -1))
        self.grows = 0
        self.peak_live = n

    @property
    def capacity(self) -> int:
        return int(self.rows.shape[0])

    @property
    def n_live(self) -> int:
        return self.capacity - len(self._free)

    def alloc(self, k: int) -> np.ndarray:
        """Pop ``k`` free slots (int32), growing the slab if needed."""
        if len(self._free) < k:
            self._grow(self.n_live + k)
        slots = np.asarray([self._free.pop() for _ in range(k)], np.int32)
        self.peak_live = max(self.peak_live, self.n_live)
        return slots

    def free(self, ids: Iterable[int]) -> None:
        self._free.extend(int(i) for i in ids)

    def _grow(self, need: int) -> None:
        old = self.capacity
        new = _round_capacity(max(2 * old, need))
        self.rows = jnp.concatenate(
            [self.rows,
             jnp.zeros((new - old, self.n_blocks, self.block_words),
                       jnp.uint32)])
        self.suffix = jnp.concatenate(
            [self.suffix, jnp.zeros((new - old, self.n_blocks + 1),
                                    jnp.int32)])
        self._free.extend(range(new - 1, old - 1, -1))
        self.grows += 1
