"""Device-resident bitmap row store with a host-managed free list.

The frontier engine's hot-path data structure (DESIGN.md §2, ISSUE 1):
every TID bitmap / diffset row that the DFS can still touch lives in one
preallocated device slab ``uint32[capacity, n_blocks, block_words]`` with
a parallel suffix-popcount slab.  The host never sees row *contents* — it
only moves row *indices* around:

  * ``alloc(k)`` hands out ``k`` free slots (growing the slab on demand);
  * the fused kernels (``kernels.ops.screen_and_intersect``,
    ``kernels.ops.screen_and_diff`` or the shard_map variants) gather
    operands by index and scatter children back by slot index;
  * ``free(ids)`` returns slots of dead candidates / expanded classes.

The allocator is representation-agnostic (ISSUE 6): tidset and diffset
rows are both ``uint32`` bitmap rows with suffix tables, so one slab,
one free list and one compaction path serve both — what a row *means*
is tracked per class by the frontier's ``ClassNode.representation``
tag, never here.  Compaction's old->new mapping renumbers ``rows``
handles only, so representation tags survive compaction untouched.

Both mining engines allocate from this class (ISSUE 2 unification):

* **Single-device** (``mesh=None``): ``suffix`` is the global suffix
  table ``int32[capacity, n_blocks + 1]`` (``core.bitmap``'s layout).
* **Sharded** (``mesh`` given): the block axis of ``rows`` is sharded
  across ``tid_axes`` under a ``NamedSharding`` (``n_blocks`` is padded
  up to a multiple of the shard count), and ``suffix`` holds the
  *per-shard* suffix tables concatenated along axis 1 —
  ``int32[capacity, n_shards * (local_blocks + 1)]``, column-sharded so
  each shard owns exactly its own ``(local_blocks + 1)``-wide local
  suffix table.  With one shard the two layouts coincide.

Growth doubles capacity (device concat of a zero slab, re-placed under
the store's sharding).  Capacities are rounded to the next power of two
so the jit cache sees few distinct store shapes.  Exhaustion can no
longer happen: ``alloc`` grows instead of raising.

Compaction (ISSUE 4) is the inverse of growth: when occupancy drops
below a threshold, ``compact`` gathers the live rows / extents to the
front of a smaller slab in one fused device dispatch
(``kernels.ops.compact_rows`` / ``compact_codes``, pinned bit-exact by
``kernels.ref.compact_gather_ref``, Pallas variant available) and hands
the freed capacity back.  Row-store compaction *renumbers* slots and
returns an old->new mapping the frontier scheduler applies to every
live handle; N-list pool compaction keeps row ids stable (offsets are
indirected through the host tables) and additionally shrinks each
extent to the bucket of its *actual* length.  Both engines trigger
compaction only at drain-group boundaries (``core.frontier``), the one
point where the live row set is exactly the frontier.

Materialization is survivor-only since ISSUE 5: the fused dispatches
write a child row / extent only when its support cleared minsup, so a
freed slot of a dead candidate was never written (pure host
bookkeeping), and the N-list engine allocates child extents from the
pre-pass's *exact* lengths — the pessimistic ``min(|U|, |V|)`` extents
that compaction used to re-bucket away no longer exist, leaving
re-bucketing as a defragmentation detail (level-1 uploads and
``set_length`` users still benefit).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.guards import host_sync
from repro.core.bitmap import (NL_LEN_BUCKETS, nl_pad_len, popcount32_np,
                               suffix_popcounts)


def _round_capacity(n: int) -> int:
    cap = 64
    while cap < n:
        cap *= 2
    return cap


def _largest_bucket_le(n: int) -> int:
    """Largest N-list bucket size <= ``n`` (``n`` >= the smallest bucket).

    Every bucket is a multiple of the smallest one, so splitting a free
    extent greedily with this always decomposes the tail exactly."""
    best = NL_LEN_BUCKETS[0]
    for b in NL_LEN_BUCKETS:
        if b <= n:
            best = b
    b = NL_LEN_BUCKETS[-1]
    while b * 2 <= n:                 # power-of-two fallback region
        b *= 2
        best = b
    return best


def _local_suffix_tables(rows_np: np.ndarray, n_shards: int) -> np.ndarray:
    """Per-shard suffix tables, concatenated: (n, n_shards*(nb_local+1)).

    Shard ``s`` owns columns ``[s*(nbl+1), (s+1)*(nbl+1))`` — its local
    analogue of :func:`repro.core.bitmap.suffix_popcounts_np`."""
    n, nb, _ = rows_np.shape
    nbl = nb // n_shards
    per_block = popcount32_np(rows_np).sum(axis=-1).astype(np.int32)
    pb = per_block.reshape(n, n_shards, nbl)
    suf = np.zeros((n, n_shards, nbl + 1), np.int32)
    suf[:, :, :-1] = pb[:, :, ::-1].cumsum(axis=-1)[:, :, ::-1]
    return suf.reshape(n, n_shards * (nbl + 1))


class DeviceRowStore:
    """Slab of bitmap rows + suffix tables resident on device.

    ``mesh``/``tid_axes``: when given, the block axis is sharded across
    the product of those mesh axes and both slabs live under
    ``NamedSharding``s (see module docstring for the suffix layout).
    """

    def __init__(self, rows_np: np.ndarray, *, capacity: int = 0,
                 mesh: Optional[Mesh] = None,
                 tid_axes: Optional[Tuple[str, ...]] = None):
        n, nb, bw = rows_np.shape
        cap = _round_capacity(max(capacity, n, 1))

        self.mesh = mesh
        self._rows_sharding = None
        self._suffix_sharding = None
        if mesh is None:
            self.n_shards = 1
        else:
            tid_axes = tuple(tid_axes) if tid_axes else tuple(mesh.axis_names)
            self.tid_axes = tid_axes
            self.n_shards = 1
            for ax in tid_axes:
                self.n_shards *= mesh.shape[ax]
            # Pad the block axis so it divides the tid shard count.
            nb = -(-nb // self.n_shards) * self.n_shards
            tid_spec: Union[str, Tuple[str, ...]] = (
                tid_axes if len(tid_axes) > 1 else tid_axes[0])
            self._rows_sharding = NamedSharding(mesh, P(None, tid_spec, None))
            self._suffix_sharding = NamedSharding(mesh, P(None, tid_spec))

        slab = np.zeros((cap, nb, bw), np.uint32)
        slab[:n, :rows_np.shape[1]] = rows_np
        self.n_blocks = nb
        self.local_blocks = nb // self.n_shards
        self.block_words = bw
        if mesh is None:
            self.rows = jnp.asarray(slab)             # uint32 (cap, nb, bw)
            self.suffix = suffix_popcounts(self.rows)  # int32 (cap, nb+1)
        else:
            self.rows = jax.device_put(slab, self._rows_sharding)
            self.suffix = jax.device_put(
                _local_suffix_tables(slab, self.n_shards),
                self._suffix_sharding)
        self._free: List[int] = list(range(cap - 1, n - 1, -1))
        self.grows = 0
        self.compactions = 0
        self.last_compaction_occupancy = 0.0
        self.peak_live = n
        self.peak_capacity = cap

    @property
    def capacity(self) -> int:
        return int(self.rows.shape[0])

    @property
    def words_per_row(self) -> int:
        """uint32 words one slab row pins on device (bitmap row + its
        suffix-table row)."""
        return self.n_blocks * self.block_words + int(self.suffix.shape[1])

    @property
    def peak_device_words(self) -> int:
        """High-water device footprint of the slab in uint32 words,
        summed over every shard (compaction can shrink the LIVE slab but
        not this peak).  Divide by ``jax.process_count()`` for the bench
        tier's per-host figure — the slab is sharded evenly over the
        block axis."""
        return self.peak_capacity * self.words_per_row

    @property
    def n_live(self) -> int:
        return self.capacity - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.n_live / max(self.capacity, 1)

    def alloc(self, k: int) -> np.ndarray:
        """Pop ``k`` free slots (int32), growing the slab if needed."""
        if len(self._free) < k:
            self._grow(self.n_live + k)
        # host-sync: host-side free-list pop; no device value touched
        slots = np.asarray([self._free.pop() for _ in range(k)], np.int32)
        self.peak_live = max(self.peak_live, self.n_live)
        return slots

    def free(self, ids: Iterable[int]) -> None:
        self._free.extend(int(i) for i in ids)

    def _grow(self, need: int) -> None:
        old = self.capacity
        new = _round_capacity(max(2 * old, need))
        rows = jnp.concatenate(
            [self.rows,
             jnp.zeros((new - old, self.n_blocks, self.block_words),
                       jnp.uint32)])
        suffix = jnp.concatenate(
            [self.suffix,
             jnp.zeros((new - old, self.suffix.shape[1]), jnp.int32)])
        if self._rows_sharding is not None:
            # Re-place explicitly: concat of a sharded slab with fresh
            # zeros must stay block-sharded for the shard_map dispatch.
            rows = jax.device_put(rows, self._rows_sharding)
            suffix = jax.device_put(suffix, self._suffix_sharding)
        self.rows = rows
        self.suffix = suffix
        self._free.extend(range(new - 1, old - 1, -1))
        self.grows += 1
        self.peak_capacity = max(self.peak_capacity, new)

    def compact(self, *, reserve: int = 0, backend: str = "jnp",
                ) -> np.ndarray:
        """Defragment: gather live rows to the front of a (usually
        smaller) slab in one fused device dispatch.

        Live rows keep their relative order and are preserved bit-for-bit
        (rows AND suffix tables); the slab shrinks to
        ``_round_capacity(n_live + reserve)`` and, under a mesh, is
        re-placed under the store's ``NamedSharding`` — this is what lets
        long sharded runs *shrink* again after a growth spike.

        Returns the old->new slot mapping ``int32[old_capacity]`` (-1 for
        slots that were free): callers MUST remap every live handle.

        HOST-SYNC (load-bearing, ISSUE 7 audit): the mapping is derived
        from the *host* free list (no device readback), but it must be
        applied to every frontier handle — stack, drain group AND
        in-flight pipeline handles — before the next group's columns
        are assembled, so compaction is a hard host-serialization point
        that cannot ride the pipeline ring.  Only the bookkeeping
        blocks: the ``ops.compact_rows`` gather itself is async and
        overlaps in-flight dispatches safely (they hold their operand
        values through the donation data-dependency chain).
        """
        from repro.kernels import ops

        old_cap = self.capacity
        free_mask = np.zeros(old_cap, bool)
        # host-sync: host-side free-list mask; no device value touched
        free_mask[np.asarray(self._free, np.int64)] = True
        live = np.nonzero(~free_mask)[0].astype(np.int32)
        n_live = int(live.size)
        new_cap = _round_capacity(max(n_live + reserve, 1))

        perm = np.full(new_cap, -1, np.int32)       # dest slot -> src slot
        perm[:n_live] = live
        rows, suffix = ops.compact_rows(self.rows, self.suffix, perm,
                                        backend=backend)
        if self._rows_sharding is not None:
            rows = jax.device_put(rows, self._rows_sharding)
            suffix = jax.device_put(suffix, self._suffix_sharding)
        self.rows = rows
        self.suffix = suffix
        self._free = list(range(new_cap - 1, n_live - 1, -1))
        self.compactions += 1
        self.last_compaction_occupancy = n_live / max(new_cap, 1)

        mapping = np.full(old_cap, -1, np.int32)
        mapping[live] = np.arange(n_live, dtype=np.int32)
        return mapping

    def compact_if_sparse(self, occupancy_threshold: float, *,
                          reserve: int = 0, backend: str = "jnp",
                          ) -> Optional[np.ndarray]:
        """Compact when occupancy fell below ``occupancy_threshold`` AND
        the slab would shrink to at most half its size (hysteresis: a
        compaction that the next drain group would immediately regrow is
        worse than useless).  Returns the slot mapping, or ``None``."""
        if occupancy_threshold <= 0.0:
            return None
        new_cap = _round_capacity(max(self.n_live + reserve, 1))
        if (self.occupancy < occupancy_threshold
                and new_cap <= self.capacity // 2):
            return self.compact(reserve=reserve, backend=backend)
        return None


class NListPool:
    """Device-resident ragged pool of PPC codes (the PrePost+ analogue of
    the bitmap slab above).

    ``codes`` is one persistent ``int32[capacity, 3]`` device slab of
    ``(pre, post, freq)`` triples.  An N-list *row* is an extent
    ``[off, off + cap_len)`` of the slab, with ``cap_len`` bucketed to
    :func:`repro.core.bitmap.nl_pad_len` sizes; the host keeps the
    per-row offset/length tables plus one free list of extents per
    bucket size, and never sees code *contents* — the fused dispatch
    (``kernels.ops.nlist_extend``) gathers operand rows by offset and
    scatters child rows back by offset, all inside one jit.

    Growth mirrors ``DeviceRowStore``: capacity doubles (device concat
    of a zero slab, power-of-two rounded) and live extents are preserved
    bit-for-bit; exhaustion cannot happen.
    """

    def __init__(self, capacity: int = 4096):
        cap = _round_capacity(max(capacity, 1))
        self.codes = jnp.zeros((cap, 3), jnp.int32)
        self._free: Dict[int, List[int]] = {}   # bucket size -> extent offs
        self._bump = 0                          # slab high-water mark
        self.grows = 0
        self.compactions = 0
        self.last_compaction_occupancy = 0.0
        self._row_off: List[int] = []
        self._row_len: List[int] = []           # actual (exact) lengths
        self._row_cap: List[int] = []           # bucketed extent sizes
        self._free_rows: List[int] = []
        self.live_codes = 0                     # sum of live extent sizes
        self.peak_codes = 0
        self.total_alloc_codes = 0              # cumulative extent mass

    @property
    def capacity(self) -> int:
        return int(self.codes.shape[0])

    @property
    def n_live_rows(self) -> int:
        return len(self._row_off) - len(self._free_rows)

    @property
    def occupancy(self) -> float:
        return self.live_codes / max(self.capacity, 1)

    @property
    def peak_live(self) -> int:
        """Uniform allocator-accounting alias (``EngineAccounting``)."""
        return self.peak_codes

    def _alloc_extent(self, bucket: int) -> int:
        stack = self._free.get(bucket)
        if stack:
            return stack.pop()
        # No exact-size extent: recycle a LARGER free extent by splitting
        # it — head becomes the requested bucket, the tail is released
        # back to smaller bucket free lists (greedy largest-bucket-first
        # decomposition; every bucket size is a multiple of the smallest,
        # so the tail always decomposes exactly).  Without this, capacity
        # freed in big buckets — e.g. the pessimistic extents a
        # compaction epoch shrinks away — could never serve the small
        # allocations that dominate deep in the DFS, and the slab leaked.
        bigger = sorted(b for b, s in self._free.items() if b > bucket and s)
        if bigger:
            src = bigger[0]                  # smallest sufficient extent
            off = self._free[src].pop()
            tail_off, rem = off + bucket, src - bucket
            while rem > 0:
                piece = _largest_bucket_le(rem)
                self._free.setdefault(piece, []).append(tail_off)
                tail_off += piece
                rem -= piece
            return off
        off = self._bump
        if off + bucket > self.capacity:
            self._grow(off + bucket)
        self._bump = off + bucket
        return off

    def alloc_rows(self, lengths: Sequence[int]) -> np.ndarray:
        """One row per requested length (its max capacity); returns int32
        row ids.  Actual lengths are refined later via set_length.

        HOST-SYNC (load-bearing, ISSUE 7 audit): on the mining hot path
        ``lengths`` are the presize pass's exact child lengths, so the
        caller must have blocked on that readback before this runs —
        extent placement (and any ``_grow``) is host bookkeeping that
        cannot be sized without the data.  This is why the N-list
        engine's scatter is a retire-time action, not a dispatch-time
        one (see ``core.prepost.PendingMergeResult``); the ``_grow``
        device concat itself stays async."""
        rows = np.empty(len(lengths), np.int32)
        for k, ln in enumerate(lengths):
            ln = int(ln)
            bucket = nl_pad_len(max(ln, 1))
            off = self._alloc_extent(bucket)
            if self._free_rows:
                r = self._free_rows.pop()
                self._row_off[r] = off
                self._row_len[r] = ln
                self._row_cap[r] = bucket
            else:
                r = len(self._row_off)
                self._row_off.append(off)
                self._row_len.append(ln)
                self._row_cap.append(bucket)
            self.live_codes += bucket
            self.total_alloc_codes += bucket
            rows[k] = r
        self.peak_codes = max(self.peak_codes, self.live_codes)
        return rows

    def free_rows(self, rows: Iterable[int]) -> None:
        for r in rows:
            r = int(r)
            bucket = self._row_cap[r]
            self._free.setdefault(bucket, []).append(self._row_off[r])
            self._free_rows.append(r)
            self.live_codes -= bucket

    def set_length(self, row: int, length: int) -> None:
        self._row_len[int(row)] = int(length)

    def offsets(self, rows: Sequence[int]) -> np.ndarray:
        # host-sync: host extent-table lookup; no device value touched
        return np.asarray([self._row_off[int(r)] for r in rows], np.int32)

    def lengths(self, rows: Sequence[int]) -> np.ndarray:
        # host-sync: host extent-table lookup; no device value touched
        return np.asarray([self._row_len[int(r)] for r in rows], np.int32)

    def write_rows(self, rows: Sequence[int],
                   code_arrays: Sequence[np.ndarray]) -> None:
        """Upload row contents from host (packing time only: the level-1
        N-lists come out of the PPC-tree build).  One scatter."""
        idx = np.concatenate([
            np.arange(self._row_off[int(r)],
                      self._row_off[int(r)] + len(a), dtype=np.int64)
            for r, a in zip(rows, code_arrays, strict=True)])
        # host-sync: pack-time host staging for the one h2d scatter below
        vals = np.concatenate([np.asarray(a, np.int32).reshape(-1, 3)
                               for a in code_arrays])
        self.codes = self.codes.at[jnp.asarray(idx)].set(jnp.asarray(vals))

    def read_row(self, row: int) -> np.ndarray:
        """Row contents as ``int32 (len, 3)`` — tests/debug only (the
        mining hot path never materialises N-lists on host)."""
        off = self._row_off[int(row)]
        ln = self._row_len[int(row)]
        # host-sync: genuine d2h readback, tests/debug only — the
        # mining hot path never calls read_row
        with host_sync("test/debug N-list readback"):
            return np.asarray(self.codes[off:off + ln])

    def _grow(self, need: int) -> None:
        old = self.capacity
        new = _round_capacity(max(2 * old, need))
        self.codes = jnp.concatenate(
            [self.codes, jnp.zeros((new - old, 3), jnp.int32)])
        self.grows += 1

    def _tight_mass(self) -> int:
        """Total bucketed mass after shrinking every live extent to the
        bucket of its actual length (what a compaction would leave)."""
        free_rows = set(self._free_rows)
        return sum(nl_pad_len(max(self._row_len[r], 1))
                   for r in range(len(self._row_off))
                   if r not in free_rows)

    def compact(self, *, reserve: int = 0, backend: str = "jnp") -> None:
        """Repack live extents to the front of a (usually smaller) slab
        in one fused device dispatch, shrinking each extent to the bucket
        of its *actual* length — this undoes the pessimistic
        ``min(|U|, |V|)`` child allocation for long-lived classes.

        Live code triples are preserved bit-for-bit and row ids stay
        stable (callers hold row ids, not offsets, so no remap is
        needed).  Free lists and the bump pointer are rebuilt from
        scratch: everything past the packed region is virgin capacity.
        """
        from repro.kernels import ops

        free_rows = set(self._free_rows)
        live = sorted((r for r in range(len(self._row_off))
                       if r not in free_rows),
                      key=lambda r: self._row_off[r])
        idx_parts: List[np.ndarray] = []
        bump = 0
        new_off: List[Tuple[int, int, int]] = []    # (row, off, bucket)
        for r in live:
            ln = self._row_len[r]
            bucket = nl_pad_len(max(ln, 1))
            idx = np.full(bucket, -1, np.int32)
            idx[:ln] = np.arange(self._row_off[r], self._row_off[r] + ln,
                                 dtype=np.int32)
            idx_parts.append(idx)
            new_off.append((r, bump, bucket))
            bump += bucket
        new_cap = _round_capacity(max(bump + reserve, 1))
        perm = np.full(new_cap, -1, np.int32)
        if bump:
            perm[:bump] = np.concatenate(idx_parts)
        self.codes = ops.compact_codes(self.codes, perm, backend=backend)
        for r, off, bucket in new_off:
            self._row_off[r] = off
            self._row_cap[r] = bucket
        self._bump = bump
        self._free = {}
        self.live_codes = bump
        self.compactions += 1
        self.last_compaction_occupancy = bump / max(new_cap, 1)

    def compact_if_sparse(self, occupancy_threshold: float, *,
                          reserve: int = 0, backend: str = "jnp") -> bool:
        """Compact when occupancy fell below ``occupancy_threshold`` AND
        the slab would shrink to at most half its size (same hysteresis
        as ``DeviceRowStore.compact_if_sparse``)."""
        if occupancy_threshold <= 0.0:
            return False
        if self.occupancy >= occupancy_threshold:
            return False
        new_cap = _round_capacity(max(self._tight_mass() + reserve, 1))
        if new_cap > self.capacity // 2:
            return False
        self.compact(reserve=reserve, backend=backend)
        return True
