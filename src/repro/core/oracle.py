"""Paper-faithful reference miners (pure Python, no JAX).

Implements Algorithms 1-3 of "Boosting Frequent Itemset Mining via Early
Stopping Intersections" (Nguyen, 2019) exactly as printed, including the
Early-Stopping (ES) variants, with per-call comparison counters so the
paper's headline metric (#comparisons) is reproducible bit-for-bit.

These are the ground-truth oracles for the TPU bitmap engine in
``repro.core.eclat`` / ``repro.core.declat`` / ``repro.core.prepost`` and
the source of the benchmark numbers in EXPERIMENTS.md §Paper.

Conventions
-----------
* A database is a list of transactions; a transaction is an iterable of
  hashable items.
* ``minsup`` is an absolute count (the paper uses relative thresholds in
  the tables; callers convert).
* Itemsets are reported as frozensets mapped to their absolute support.
* Eclat/dEclat sort items in *increasing* frequency; PrePost+ builds its
  PPC-tree on *decreasing* frequency and searches in the reverse
  (increasing) order — exactly the paper's §II-A choices.
"""

from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Hashable, List, Sequence, Tuple

Item = Hashable
Transaction = Sequence[Item]
Database = Sequence[Transaction]
ItemsetSupports = Dict[FrozenSet[Item], int]


@dataclass
class MiningStats:
    """Counters matching the paper's Table IV / Figures 7-15 metrics."""

    candidates: int = 0        # proposed candidate itemsets (pairs tested)
    nodes: int = 0             # expanded (frequent) nodes in the search tree
    comparisons: int = 0       # loop iterations inside intersect/difference
    es_checks: int = 0         # early-stopping bound evaluations (ES overhead)
    es_aborts: int = 0         # intersections cut short by the ES criterion
    runtime_s: float = 0.0

    @property
    def ratio(self) -> float:
        """#Cands / #Nodes — the paper's predictor of ES effectiveness."""
        return self.candidates / max(self.nodes, 1)

    def as_dict(self) -> Dict[str, float]:
        return {
            "candidates": self.candidates,
            "nodes": self.nodes,
            "ratio": round(self.ratio, 4),
            "comparisons": self.comparisons,
            "es_checks": self.es_checks,
            "es_aborts": self.es_aborts,
            "runtime_s": round(self.runtime_s, 6),
        }


# ---------------------------------------------------------------------------
# Shared preprocessing
# ---------------------------------------------------------------------------

def item_frequencies(db: Database) -> Dict[Item, int]:
    freq: Dict[Item, int] = defaultdict(int)
    for t in db:
        for it in set(t):
            freq[it] += 1
    return dict(freq)


def frequent_items_ascending(db: Database, minsup: int) -> List[Item]:
    """Frequent 1-itemsets sorted in increasing frequency (Eclat order)."""
    freq = item_frequencies(db)
    items = [it for it, f in freq.items() if f >= minsup]
    # Deterministic tie-break on repr so runs are reproducible across hash seeds.
    items.sort(key=lambda it: (freq[it], repr(it)))
    return items


def build_tidlists(db: Database, items: Sequence[Item]) -> Dict[Item, List[int]]:
    """TID-list per item; TIDs are 1-based like the paper's running example."""
    wanted = set(items)
    tids: Dict[Item, List[int]] = {it: [] for it in items}
    for tid, t in enumerate(db, start=1):
        for it in set(t):
            if it in wanted:
                tids[it].append(tid)
    return tids


# ---------------------------------------------------------------------------
# Brute force (ground truth of the ground truth; tiny DBs only)
# ---------------------------------------------------------------------------

def mine_bruteforce(db: Database, minsup: int) -> ItemsetSupports:
    """Enumerate all itemsets by support counting. Exponential; tests only."""
    from itertools import combinations

    freq = item_frequencies(db)
    items = sorted((it for it, f in freq.items() if f >= minsup), key=repr)
    tsets = [frozenset(t) for t in db]
    out: ItemsetSupports = {}
    for k in range(1, len(items) + 1):
        found_any = False
        for combo in combinations(items, k):
            s = frozenset(combo)
            support = sum(1 for t in tsets if s <= t)
            if support >= minsup:
                out[s] = support
                found_any = True
        if not found_any:
            break
    return out


# ---------------------------------------------------------------------------
# Eclat (Algorithm 1)
# ---------------------------------------------------------------------------

def _intersect(U: List[int], V: List[int], stats: MiningStats) -> List[int]:
    """INTERSECT (Alg. 1 lines 18-29). One comparison per loop iteration."""
    Z: List[int] = []
    i = j = 0
    nu, nv = len(U), len(V)
    while i < nu and j < nv:
        stats.comparisons += 1
        if U[i] == V[j]:
            Z.append(U[i])
            i += 1
            j += 1
        elif U[i] < V[j]:
            i += 1
        else:
            j += 1
    return Z


def _intersect_es(U: List[int], V: List[int], minsup: int,
                  stats: MiningStats) -> List[int]:
    """INTERSECT_ES (Alg. 1 lines 30-45): abort once |U|-s_U or |V|-s_V
    drops below minsup. Output is exact for frequent candidates and a
    (possibly truncated) certificate of infrequency otherwise."""
    Z: List[int] = []
    i = j = 0
    s_u = s_v = 0
    nu, nv = len(U), len(V)
    while i < nu and j < nv:
        stats.comparisons += 1
        if U[i] == V[j]:
            Z.append(U[i])
            i += 1
            j += 1
        elif U[i] < V[j]:
            i += 1
            s_u += 1
            stats.es_checks += 1
            if nu - s_u < minsup:
                stats.es_aborts += 1
                break
        else:
            j += 1
            s_v += 1
            stats.es_checks += 1
            if nv - s_v < minsup:
                stats.es_aborts += 1
                break
    return Z


def mine_eclat(db: Database, minsup: int, early_stop: bool = False,
               ) -> Tuple[ItemsetSupports, MiningStats]:
    """Depth-first Eclat over TID-lists (Algorithm 1)."""
    if minsup < 1:
        raise ValueError("minsup must be an absolute count >= 1")
    stats = MiningStats()
    t0 = time.perf_counter()

    items = frequent_items_ascending(db, minsup)
    tidlists = build_tidlists(db, items)

    out: ItemsetSupports = {}
    for it in items:
        out[frozenset((it,))] = len(tidlists[it])
        stats.nodes += 1

    def traverse(klass: List[Tuple[Tuple[Item, ...], List[int]]]) -> None:
        # klass: members of one equivalence class (shared prefix), in item order.
        for a in range(len(klass)):
            new_class: List[Tuple[Tuple[Item, ...], List[int]]] = []
            pxy_items, px_tids = klass[a]
            for b in range(a + 1, len(klass)):
                py_items, py_tids = klass[b]
                stats.candidates += 1
                if early_stop:
                    z = _intersect_es(px_tids, py_tids, minsup, stats)
                else:
                    z = _intersect(px_tids, py_tids, stats)
                if len(z) >= minsup:
                    child = pxy_items + (py_items[-1],)
                    out[frozenset(child)] = len(z)
                    stats.nodes += 1
                    new_class.append((child, z))
            if new_class:
                traverse(new_class)

    traverse([((it,), tidlists[it]) for it in items])
    stats.runtime_s = time.perf_counter() - t0
    return out, stats


# ---------------------------------------------------------------------------
# dEclat (Algorithm 2)
# ---------------------------------------------------------------------------

def _difference(U: List[int], V: List[int], stats: MiningStats) -> List[int]:
    """DIFFERENCE (Alg. 2 lines 18-31): Z = U - V over sorted TID lists."""
    Z: List[int] = []
    i = j = 0
    nu, nv = len(U), len(V)
    while i < nu and j < nv:
        stats.comparisons += 1
        if U[i] == V[j]:
            i += 1
            j += 1
        elif U[i] < V[j]:
            Z.append(U[i])
            i += 1
        else:
            j += 1
    if i < nu:
        Z.extend(U[i:])
    return Z


def _difference_es(U: List[int], V: List[int], rho_parent: int, minsup: int,
                   stats: MiningStats) -> List[int]:
    """DIFFERENCE_ES (Alg. 2 lines 32-47): abort when rho(Px) - |Z| < minsup.

    Every element appended to Z lowers the achievable support
    rho(Pxy) = rho(Px) - |D(Pxy)| by one; once it cannot reach minsup the
    remaining merge work is provably redundant."""
    Z: List[int] = []
    i = j = 0
    nu, nv = len(U), len(V)
    while i < nu and j < nv:
        stats.comparisons += 1
        if U[i] == V[j]:
            i += 1
            j += 1
        elif U[i] < V[j]:
            Z.append(U[i])
            i += 1
            stats.es_checks += 1
            if rho_parent - len(Z) < minsup:
                stats.es_aborts += 1
                return Z
        else:
            j += 1
    if i < nu:
        # The tail flush can also cross the bound; honour it exactly.
        for k in range(i, nu):
            Z.append(U[k])
            stats.es_checks += 1
            if rho_parent - len(Z) < minsup:
                stats.es_aborts += 1
                return Z
    return Z


def mine_declat(db: Database, minsup: int, early_stop: bool = False,
                ) -> Tuple[ItemsetSupports, MiningStats]:
    """Depth-first dEclat over diffsets (Algorithm 2).

    Level 1 stores TID-lists; level 2 uses D(xy) = T(x) - T(y); deeper
    levels use D(Pxy) = D(Py) - D(Px) with
    rho(Pxy) = rho(Px) - |D(Pxy)| (paper §III-B).
    """
    if minsup < 1:
        raise ValueError("minsup must be an absolute count >= 1")
    stats = MiningStats()
    t0 = time.perf_counter()

    items = frequent_items_ascending(db, minsup)
    tidlists = build_tidlists(db, items)

    out: ItemsetSupports = {}
    for it in items:
        out[frozenset((it,))] = len(tidlists[it])
        stats.nodes += 1

    # Class member: (itemset, listing, support, is_tidlist)
    def traverse(klass: List[Tuple[Tuple[Item, ...], List[int], int, bool]]) -> None:
        for a in range(len(klass)):
            new_class: List[Tuple[Tuple[Item, ...], List[int], int, bool]] = []
            px_items, px_list, px_sup, px_is_tid = klass[a]
            for b in range(a + 1, len(klass)):
                py_items, py_list, py_sup, py_is_tid = klass[b]
                stats.candidates += 1
                if px_is_tid:
                    # Level-2 transition: D(xy) = T(x) - T(y).
                    u, v = px_list, py_list
                else:
                    # D(Pxy) = D(Py) - D(Px).
                    u, v = py_list, px_list
                if early_stop:
                    z = _difference_es(u, v, px_sup, minsup, stats)
                else:
                    z = _difference(u, v, stats)
                sup = px_sup - len(z)
                if sup >= minsup:
                    child = px_items + (py_items[-1],)
                    out[frozenset(child)] = sup
                    stats.nodes += 1
                    new_class.append((child, z, sup, False))
            if new_class:
                traverse(new_class)

    traverse([((it,), tidlists[it], len(tidlists[it]), True) for it in items])
    stats.runtime_s = time.perf_counter() - t0
    return out, stats


# ---------------------------------------------------------------------------
# PrePost+ (Algorithm 3): PPC-tree, N-lists, NL_intersect(_ES)
# ---------------------------------------------------------------------------

@dataclass
class _PPCNode:
    name: Item
    frequency: int = 0
    children: Dict[Item, "_PPCNode"] = field(default_factory=dict)
    pre: int = -1
    post: int = -1


PPCode = Tuple[int, int, int]  # (pre, post, frequency)


class PPCTree:
    """PPC-tree (paper §IV-A): prefix tree over transactions reordered in
    decreasing item frequency, annotated with pre/post traversal ranks."""

    def __init__(self, db: Database, minsup: int):
        freq = item_frequencies(db)
        frequent = {it: f for it, f in freq.items() if f >= minsup}
        # Decreasing frequency (ties broken deterministically), paper §II-A.
        self.order_desc: List[Item] = sorted(
            frequent, key=lambda it: (-frequent[it], repr(it)))
        self.rank_desc = {it: r for r, it in enumerate(self.order_desc)}
        self.item_support = frequent

        self.root = _PPCNode(name=None)
        for t in db:
            kept = sorted({it for it in t if it in frequent},
                          key=lambda it: self.rank_desc[it])
            node = self.root
            for it in kept:
                nxt = node.children.get(it)
                if nxt is None:
                    nxt = _PPCNode(name=it)
                    node.children[it] = nxt
                nxt.frequency += 1
                node = nxt

        # Pre/post ranks. Children are visited in insertion order, which is
        # the order transactions introduced them (matches the paper's figures).
        self._rank()
        self.nlists: Dict[Item, List[PPCode]] = self._collect_nlists()

    def _rank(self) -> None:
        pre_counter = 0
        post_counter = 0
        # Iterative DFS to avoid recursion limits on deep trees.
        stack: List[Tuple[_PPCNode, bool]] = [(self.root, False)]
        while stack:
            node, expanded = stack.pop()
            if expanded:
                node.post = post_counter
                post_counter += 1
                continue
            node.pre = pre_counter
            pre_counter += 1
            stack.append((node, True))
            for child in reversed(list(node.children.values())):
                stack.append((child, False))
        # The paper ranks item nodes only (root excluded from its figures);
        # offsets are irrelevant to the ancestor test, so we keep raw ranks.

    def _collect_nlists(self) -> Dict[Item, List[PPCode]]:
        nl: Dict[Item, List[PPCode]] = defaultdict(list)
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            nl[node.name].append((node.pre, node.post, node.frequency))
            stack.extend(node.children.values())
        # Ascending pre-order rank, per §IV-A.
        return {it: sorted(codes) for it, codes in nl.items()}


def _nl_support(nl: List[PPCode]) -> int:
    return sum(c[2] for c in nl)


def _merge_same_code(Z: List[PPCode]) -> List[PPCode]:
    """Combine PP-codes sharing (pre, post) — Alg. 3 line 31."""
    if not Z:
        return Z
    merged: List[PPCode] = []
    for pre, post, f in Z:
        if merged and merged[-1][0] == pre and merged[-1][1] == post:
            merged[-1] = (pre, post, merged[-1][2] + f)
        else:
            merged.append((pre, post, f))
    return merged


def _nl_intersect(U: List[PPCode], V: List[PPCode],
                  stats: MiningStats) -> List[PPCode]:
    """NL_INTERSECT (Alg. 3 lines 19-33). U = NL(xS), V = NL(yS); a code of
    V contributes when it is an ancestor of the current code of U."""
    Z: List[PPCode] = []
    i = j = 0
    nu, nv = len(U), len(V)
    while i < nu and j < nv:
        stats.comparisons += 1
        xi, yj = U[i], V[j]
        if xi[0] > yj[0]:
            if xi[1] < yj[1]:
                Z.append((yj[0], yj[1], xi[2]))
                i += 1
            else:
                j += 1
        else:
            i += 1
    return _merge_same_code(Z)


def _nl_intersect_es(U: List[PPCode], V: List[PPCode], rho_v: int,
                     minsup: int, stats: MiningStats) -> List[PPCode]:
    """NL_INTERSECT_ES (Alg. 3 lines 34-52): every skipped V-code removes
    its frequency mass from the achievable support; abort when the bound
    drops below minsup (returns the empty N-list, support 0).

    PAPER ERRATUM (documented in DESIGN.md §Errata): as printed, the
    criterion is ``rho_V - skip < minSup`` with ``skip`` accumulated on
    *every* j-advance.  Because j only ever advances through the skip
    branch, a V-code that already contributed matches to Z also lands in
    ``skip``, so the printed bound ignores support mass that has already
    been earned and can abort a *frequent* candidate (it is only exact
    when Z is empty at check time, as in the paper's Example 4.2).  The
    sound version of the same idea — which we implement — is

        z_mass + (rho_V - skip) < minSup

    i.e. mass already earned plus everything still achievable from the
    unpassed V-codes.  This preserves the paper's guarantees (identical
    output, never more comparisons)."""
    Z: List[PPCode] = []
    z_mass = 0
    i = j = 0
    skip = 0
    nu, nv = len(U), len(V)
    while i < nu and j < nv:
        stats.comparisons += 1
        xi, yj = U[i], V[j]
        if xi[0] > yj[0]:
            if xi[1] < yj[1]:
                Z.append((yj[0], yj[1], xi[2]))
                z_mass += xi[2]
                i += 1
            else:
                skip += yj[2]
                stats.es_checks += 1
                if z_mass + (rho_v - skip) < minsup:
                    stats.es_aborts += 1
                    return []
                j += 1
        else:
            i += 1
    return _merge_same_code(Z)


def mine_prepost(db: Database, minsup: int, early_stop: bool = False,
                 ) -> Tuple[ItemsetSupports, MiningStats]:
    """PrePost+ (Algorithm 3): N-list intersection over the PPC-tree with
    suffix-sharing depth-first search in ascending frequency order."""
    if minsup < 1:
        raise ValueError("minsup must be an absolute count >= 1")
    stats = MiningStats()
    t0 = time.perf_counter()

    tree = PPCTree(db, minsup)
    order_asc = list(reversed(tree.order_desc))  # search order, §IV-A

    out: ItemsetSupports = {}
    for it in order_asc:
        out[frozenset((it,))] = tree.item_support[it]
        stats.nodes += 1

    # Class member: (itemset-as-tuple with newest item first, N-list, support)
    def traverse(klass: List[Tuple[Tuple[Item, ...], List[PPCode], int]]) -> None:
        for a in range(len(klass)):
            new_class: List[Tuple[Tuple[Item, ...], List[PPCode], int]] = []
            xs_items, xs_nl, _ = klass[a]
            for b in range(a + 1, len(klass)):
                ys_items, ys_nl, ys_sup = klass[b]
                stats.candidates += 1
                if early_stop:
                    z = _nl_intersect_es(xs_nl, ys_nl, ys_sup, minsup, stats)
                else:
                    z = _nl_intersect(xs_nl, ys_nl, stats)
                sup = _nl_support(z)
                if sup >= minsup:
                    child = xs_items + (ys_items[-1],)
                    out[frozenset(child)] = sup
                    stats.nodes += 1
                    new_class.append((child, z, sup))
            if new_class:
                traverse(new_class)

    traverse([((it,), tree.nlists[it], tree.item_support[it])
              for it in order_asc])
    stats.runtime_s = time.perf_counter() - t0
    return out, stats


# ---------------------------------------------------------------------------
# Convenience front-end
# ---------------------------------------------------------------------------

MINERS = {
    "eclat": mine_eclat,
    "declat": mine_declat,
    "prepost": mine_prepost,
}


def mine(db: Database, minsup: int, scheme: str = "eclat",
         early_stop: bool = False) -> Tuple[ItemsetSupports, MiningStats]:
    try:
        fn = MINERS[scheme]
    except KeyError:
        raise ValueError(
            f"unknown scheme {scheme!r}; pick from {sorted(MINERS)}"
        ) from None
    return fn(db, minsup, early_stop=early_stop)
