"""Runtime device-purity enforcement (ISSUE 10).

Static rule DL001 (``tools/devicelint``) proves the *source* contains
no unannotated host-sync sites; this module is the *runtime* half of
the same contract:

* :func:`device_purity_guard` wraps a region (``FrontierScheduler.run``
  and the equivalence harness use it) in JAX's device->host transfer
  guard set to ``"disallow"`` — any readback not routed through
  :func:`host_sync` raises instead of silently stalling the dispatch
  pipeline.
* :func:`host_sync` is the narrow escape, placed at exactly the
  ``# host-sync:``-annotated sites, so the static rule and the runtime
  guard certify each other: devicelint fails if an escape loses its
  annotation, and the guard fires if a sync appears outside one.

Backend caveat (measured, not assumed): on the **CPU** backend JAX
device buffers alias host memory, so device->host "transfers" are
zero-copy and the guard never fires — there DL001 is the only
enforcement with teeth.  On TPU/GPU the guard is real: an unannotated
``np.asarray(device_value)`` inside a guarded region raises
``XlaRuntimeError``.  We deliberately do NOT disallow host->device
transfers: streaming host operand columns into fused dispatches is the
designed data flow (h2d is async and never stalls the pipeline).

Only the device->host direction is guarded; ``jax.transfer_guard`` (all
directions) would flag benign implicit h2d of python scalar constants
in eager ops.
"""

from __future__ import annotations

import contextlib

import jax

__all__ = ["device_purity_guard", "host_sync", "purity_guard_active"]

# Nesting depth of active disallow regions — lets tests (and the
# equivalence harness) assert the guard is actually armed, which is the
# CPU-backend-visible part of the contract.
_DEPTH = 0


def purity_guard_active() -> bool:
    """True while inside a :func:`device_purity_guard` region and not
    inside a :func:`host_sync` escape."""
    return _DEPTH > 0


def _d2h_guard(level: str):
    # jax.transfer_guard_device_to_host is stable API since jax 0.3;
    # the getattr shim keeps ancient/forked builds importable.
    g = getattr(jax, "transfer_guard_device_to_host", None)
    if g is None:                         # pragma: no cover
        return contextlib.nullcontext()
    return g(level)


@contextlib.contextmanager
def device_purity_guard():
    """Disallow unannotated device->host transfers in this region."""
    global _DEPTH
    _DEPTH += 1
    try:
        with _d2h_guard("disallow"):
            yield
    finally:
        _DEPTH -= 1


@contextlib.contextmanager
def host_sync(why: str):
    """Sanctioned host-sync escape — pair with a ``# host-sync:``
    annotation carrying the same justification."""
    assert why, "host_sync requires a non-empty justification"
    global _DEPTH
    saved, _DEPTH = _DEPTH, 0
    try:
        with _d2h_guard("allow"):
            yield
    finally:
        _DEPTH = saved
