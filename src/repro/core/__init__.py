"""Core library: the paper's contribution.

Early-stopping list intersection for depth-first frequent itemset mining
(Eclat / dEclat / PrePost+), as published, plus the TPU-native bitmap
engine and the count-distribution distributed miner.
"""

from repro.core.oracle import (  # noqa: F401
    MiningStats, mine, mine_bruteforce, mine_eclat, mine_declat,
    mine_prepost, PPCTree, item_frequencies, frequent_items_ascending,
)
from repro.core.bitmap import (  # noqa: F401
    BitmapDB, pack_tidlists, unpack_row, popcount32, popcount32_np,
    suffix_popcounts, suffix_popcounts_np, DEFAULT_BLOCK_WORDS,
)
from repro.core.rowstore import DeviceRowStore, NListPool  # noqa: F401
from repro.core.frontier import (  # noqa: F401
    ClassNode, EngineAccounting, FrontierScheduler,
)
from repro.core.eclat import (  # noqa: F401
    BitmapMiner, DeviceMiningStats, mine_bitmap,
)
from repro.core.prepost import DevicePrePost, mine_prepost_device  # noqa: F401
from repro.core.distributed import (  # noqa: F401
    DistributedMiner, DistributedStats, make_mining_round,
    make_mining_round_v2,
)
