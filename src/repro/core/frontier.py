"""Engine-agnostic DFS frontier scheduler (ISSUE 4 tentpole).

The paper's early-stopping trick only pays off when support checks are
issued in large device batches: deep in the DFS individual equivalence
classes are tiny, so an engine that dispatches per class (or per class
member) is launch-latency-bound long before it is compute-bound.  The
cross-class *drain-group* batching that fixes this used to live inside
``core.eclat.BitmapMiner`` only; this module extracts the whole
traversal policy — work stack, drain grouping, pair-triangle assembly,
chunk slicing, operand free-listing and compaction scheduling — into
one scheduler that all three engines drive:

* ``core.eclat.BitmapMiner``            (bitmap rows, fused screen+ES)
* ``core.distributed.DistributedMiner`` (block-sharded rows, shard_map)
* ``core.prepost.DevicePrePost``        (N-list extents, fused merge)

The engine ("client") owns the *device* side: how a pair chunk becomes
operand index columns, what the one fused dispatch per chunk is, and
how surviving children are materialised.  The scheduler owns the *host*
side: which classes are drained together so batches stay full, when
spent operand rows go back to the allocator, and when the allocator is
compacted (a drain-group boundary is the only point where every live
row is reachable from the frontier, so handle remapping is sound).

Client protocol (duck-typed; the miners implement it directly):

``pair_columns(klass, ia, ib) -> Dict[str, np.ndarray]``
    Per-pair operand columns for one class's sibling-pair triangle.
    Clients that mix *representations* (tidset vs diffset classes,
    ISSUE 6) read ``klass.representation`` here to orient operands and
    emit a per-pair op column so mixed drain groups stay dispatchable.
``evaluate_pairs(cols) -> Iterable[(ki, row, support, extra)]``
    ONE fused device dispatch for a <= pair_chunk column slice (one per
    representation present in the slice, when a group mixes them);
    yields the surviving children by chunk-local pair index.
``make_class(parent, children) -> ClassNode``
    Wrap surviving children of one (class, member) group as a new class.
    This is also where a representation flip is decided: the returned
    node's ``representation``/``payload`` tags are the only state the
    adaptive tidset→diffset switch needs (see ``core.eclat``).
``emit(itemset, support)``          record one frequent itemset.
``release(klass)``                  free a class's operand rows.
``maybe_compact(reserve) -> Optional[np.ndarray]``
    Compact the allocator if occupancy warrants it; return an old->new
    row-id mapping when handles moved (``None`` when ids are stable).
    ``reserve`` covers the WHOLE drain group about to run.
``chunk_sort_key(cols) -> Optional[np.ndarray]`` (optional)
    Per-pair sort key (e.g. operand length bucket): drained pairs are
    stably reordered by it before chunk slicing so chunks stay
    dispatch-width homogeneous (see ``_assemble``).

Work accounting for every engine flows through one shared struct
(:class:`EngineAccounting`): ``device_calls``, ES deaths, allocator
grows/compactions and peak live mass mean the same thing in every
engine's stats dict and in ``benchmarks/bench_paper.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import (Any, Dict, Hashable, List, NamedTuple, Optional,
                    Tuple)

import numpy as np


@dataclass
class EngineAccounting:
    """Shared device-engine accounting (one struct for all three engines).

    ``peak_live`` is the allocator's peak live mass — bitmap rows for the
    row-store engines, PPC-code triples for the N-list engine.
    ``compaction_occupancy`` is ``live / capacity`` right after the most
    recent compaction epoch (0.0 when compaction never fired)."""

    candidates: int = 0
    nodes: int = 0
    device_calls: int = 0
    grows: int = 0               # allocator slab reallocations
    compactions: int = 0         # allocator compaction epochs
    peak_live: int = 0           # peak live allocator mass
    compaction_occupancy: float = 0.0
    runtime_s: float = 0.0
    # Survivor-only materialization telemetry (ISSUE 5): every fused
    # dispatch scatters ONLY the children whose support cleared minsup,
    # so ``child_scatters`` equals the number of frequent children (not
    # candidates) and ``scatter_words`` is the device words they cost —
    # bitmap rows (n_blocks * block_words each) or PPC-code words
    # (3 * child_len each).
    child_scatters: int = 0
    scatter_words: int = 0

    @property
    def deaths(self) -> int:
        """Candidates certified infrequent by ES (engine-specific split
        lives in the subclasses)."""
        return 0

    def note_allocator(self, alloc) -> None:
        """Pull the shared allocator counters (rowstore / nlist pool)."""
        self.grows = alloc.grows
        self.compactions = alloc.compactions
        self.peak_live = alloc.peak_live
        self.compaction_occupancy = alloc.last_compaction_occupancy

    def accounting_dict(self) -> Dict[str, float]:
        return {
            "device_calls": self.device_calls,
            "deaths": self.deaths,
            "compactions": self.compactions,
            "compaction_occupancy": round(self.compaction_occupancy, 4),
            "child_scatters": self.child_scatters,
            "scatter_words": self.scatter_words,
        }


@dataclass
class ClassNode:
    """One equivalence class on the frontier.

    ``rows`` are allocator handles (row-store slots or N-list pool row
    ids) — contents never leave the device.  ``representation`` tags
    what those handles *hold* (ISSUE 6): ``"tidset"`` (TID bitmap
    rows), ``"diffset"`` (dEclat difference rows) or ``"nlist"``
    (PPC-code extents).  The tag rides the class, not the allocator —
    both bitmap representations share one ``DeviceRowStore`` slab, and
    compaction remaps ``rows`` only, so the tag survives remapping by
    construction.  ``payload`` carries the engine-specific extras
    (bitmap miners: the representation the class's *children* will be
    materialised in, decided once at ``make_class`` time; N-list: the
    per-member exact lengths)."""

    itemsets: List[Tuple[Hashable, ...]]
    rows: np.ndarray          # int32 (m,)
    supports: np.ndarray      # int32 (m,)
    payload: Any = None
    representation: str = "tidset"


class Child(NamedTuple):
    """One surviving candidate, as returned through ``evaluate_pairs``."""

    itemset: Tuple[Hashable, ...]
    row: int
    support: int
    extra: Any


class FrontierScheduler:
    """Shared DFS work-stack with cross-class drain-group batching.

    Classes are drained from the stack until one ``pair_chunk`` worth of
    sibling pairs is collected, their pair triangles are concatenated
    into global operand columns, and each ``pair_chunk`` slice goes to
    the client as exactly one fused device dispatch.  Result sets are
    order-independent, so draining order never affects correctness.

    Row lifetime: a class's member rows are operands only for its own
    pair triangle, so they are released as soon as the drain group that
    consumed them completes; child rows live until the child class is
    drained in turn.  Compaction runs at drain-group boundaries, where
    the stack plus the drained group is exactly the live row set — the
    scheduler remaps every frontier handle through the mapping the
    allocator returns.
    """

    def __init__(self, client, pair_chunk: int):
        self.client = client
        self.pair_chunk = int(pair_chunk)
        self._stack: List[ClassNode] = []

    # -- frontier bookkeeping ------------------------------------------------

    def push(self, klass: ClassNode) -> None:
        self._stack.append(klass)

    def drain_group(self) -> Tuple[List[ClassNode], int]:
        """Pop classes until one pair_chunk of pairs is filled.  Leaf
        classes (< 2 members) release their rows and contribute none."""
        drained: List[ClassNode] = []
        total = 0
        while self._stack and total < self.pair_chunk:
            klass = self._stack.pop()
            m = len(klass.itemsets)
            if m < 2:
                self.client.release(klass)
                continue
            drained.append(klass)
            total += m * (m - 1) // 2
        return drained, total

    def remap(self, mapping: np.ndarray,
              drained: Optional[List[ClassNode]] = None) -> None:
        """Apply an allocator old->new row-id mapping to every live
        frontier handle (stack + the in-flight drain group)."""
        for klass in self._stack:
            klass.rows = mapping[klass.rows]
        for klass in drained or ():
            klass.rows = mapping[klass.rows]

    # -- main loop -----------------------------------------------------------

    def run(self, root: ClassNode) -> None:
        self.push(root)
        while self._stack:
            drained, total = self.drain_group()
            if not drained:
                continue
            # Compaction reserve must cover the WHOLE drain group, not
            # one pair_chunk: a group's chunks allocate children
            # cumulatively (earlier chunks' survivors stay live while
            # later chunks allocate), so reserving ``min(total,
            # pair_chunk)`` let a compaction shrink to a size the same
            # group immediately regrew (compact -> grow thrash).
            mapping = self.client.maybe_compact(total)
            if mapping is not None:
                self.remap(mapping, drained)

            cols, meta = self._assemble(drained)
            groups: Dict[Tuple[int, int], List[Tuple[int, Child]]] = {}
            for lo in range(0, total, self.pair_chunk):
                sl = slice(lo, lo + self.pair_chunk)
                chunk = {k: v[sl] for k, v in cols.items()}
                for ki, row, support, extra in self.client.evaluate_pairs(
                        chunk):
                    ci, a, b = meta[lo + ki]
                    klass = drained[ci]
                    itemset = klass.itemsets[a] + (klass.itemsets[b][-1],)
                    self.client.emit(itemset, support)
                    groups.setdefault((ci, a), []).append(
                        (b, Child(itemset, row, support, extra)))
            # Child classes are rebuilt in canonical sibling order (b
            # ascending), NOT evaluation order: chunk_sort_key may have
            # permuted the pairs, and class member order is load-bearing
            # (pair orientation / search order within the class).
            for ci, _a in sorted(groups):
                kids = [c for _b, c in sorted(groups[(ci, _a)])]
                self.push(self.client.make_class(drained[ci], kids))
            for klass in drained:
                self.client.release(klass)

    def _assemble(self, drained: List[ClassNode],
                  ) -> Tuple[Dict[str, np.ndarray],
                             List[Tuple[int, int, int]]]:
        """Concatenate every drained class's sibling-pair triangle into
        global operand columns plus (class, a, b) metadata.

        Length-aware composition (ISSUE 5): a client whose per-pair
        dispatch width depends on operand size (the N-list engine — its
        gather widths are the buckets of the chunk *maxima*) exposes
        ``chunk_sort_key(cols) -> int array``; the assembled pairs are
        then stably sorted by that key before chunk slicing, so one
        huge operand no longer widens the dispatch for every pair in
        its chunk.  The permutation is applied to the metadata too, and
        result sets are order-independent, so this only moves padding.
        """
        cols_l: Dict[str, List[np.ndarray]] = {}
        meta: List[Tuple[int, int, int]] = []
        for ci, klass in enumerate(drained):
            m = len(klass.itemsets)
            ia, ib = np.triu_indices(m, 1)
            for key, col in self.client.pair_columns(klass, ia, ib).items():
                cols_l.setdefault(key, []).append(np.asarray(col))
            meta.extend((ci, int(a), int(b)) for a, b in zip(ia, ib, strict=True))
        cols = {k: np.concatenate(v) for k, v in cols_l.items()}
        key_fn = getattr(self.client, "chunk_sort_key", None)
        if key_fn is not None and len(meta) > 1:
            key = key_fn(cols)
            if key is not None:
                order = np.argsort(np.asarray(key), kind="stable")
                cols = {k: c[order] for k, c in cols.items()}
                meta = [meta[int(i)] for i in order]
        return cols, meta
