"""Engine-agnostic DFS frontier scheduler (ISSUE 4 tentpole, pipelined
in ISSUE 7).

The paper's early-stopping trick only pays off when support checks are
issued in large device batches: deep in the DFS individual equivalence
classes are tiny, so an engine that dispatches per class (or per class
member) is launch-latency-bound long before it is compute-bound.  The
cross-class *drain-group* batching that fixes this used to live inside
``core.eclat.BitmapMiner`` only; this module extracts the whole
traversal policy — work stack, drain grouping, pair-triangle assembly,
chunk slicing, operand free-listing and compaction scheduling — into
one scheduler that all three engines drive:

* ``core.eclat.BitmapMiner``            (bitmap rows, fused screen+ES)
* ``core.distributed.DistributedMiner`` (block-sharded rows, shard_map)
* ``core.prepost.DevicePrePost``        (N-list extents, fused merge)

The engine ("client") owns the *device* side: how a pair chunk becomes
operand index columns, what the one fused dispatch per chunk is, and
how surviving children are materialised.  The scheduler owns the *host*
side: which classes are drained together so batches stay full, when
spent operand rows go back to the allocator, and when the allocator is
compacted (a drain-group boundary is the only point where every live
row is reachable from the frontier, so handle remapping is sound).

Dispatch pipeline (ISSUE 7): ``run()`` keeps an in-flight ring of up to
``inflight`` dispatched-but-unretired drain groups.  While group N's
fused dispatches execute on the device (JAX async dispatch returns
immediately), the host drains, assembles and dispatches group N+1 —
the blocking accounting readbacks are *deferred* into the lazy handle
``evaluate_pairs`` returns and only materialise when the group retires
from the ring.  Children are therefore pushed, classes released and
itemsets emitted at *retire* time, preserving the serial DFS child
order exactly; with ``inflight=1`` every handle is resolved immediately
after its dispatch and the scheduler reproduces the serial engine's
accounting bit-for-bit (chunk-level free-before-alloc slot reuse
included).

Client protocol (duck-typed; the miners implement it directly):

``pair_columns(klass, ia, ib) -> Dict[str, np.ndarray]``
    Per-pair operand columns for one class's sibling-pair triangle.
    Clients that mix *representations* (tidset vs diffset classes,
    ISSUE 6) read ``klass.representation`` here to orient operands and
    emit a per-pair op column so mixed drain groups stay dispatchable.
``evaluate_pairs(cols) -> handle``
    ONE fused device dispatch for a <= pair_chunk column slice (one per
    representation present in the slice, when a group mixes them).
    Returns a *lazy result handle*: an object with ``.resolve() ->
    Iterable[(ki, row, support, extra)]`` (blocking readbacks + stats
    attribution, called once at group retirement) and ``.remap(mapping)``
    (rewrite any allocator handles the pending result still holds when
    a compaction lands while the group is in flight).  A plain iterable
    of ``(ki, row, support, extra)`` tuples is also accepted — the
    scheduler treats it as an already-resolved handle.
``make_class(parent, children) -> ClassNode``
    Wrap surviving children of one (class, member) group as a new class.
    This is also where a representation flip is decided: the returned
    node's ``representation``/``payload`` tags are the only state the
    adaptive tidset→diffset switch needs (see ``core.eclat``).
``emit(itemset, support)``          record one frequent itemset.
``release(klass)``                  free a class's operand rows.
``maybe_compact(reserve) -> Optional[np.ndarray]``
    Compact the allocator if occupancy warrants it; return an old->new
    row-id mapping when handles moved (``None`` when ids are stable).
    ``reserve`` covers the WHOLE drain group about to run PLUS every
    group still in flight (their children allocate at retirement).
``chunk_sort_key(cols) -> Optional[np.ndarray]`` (optional)
    Per-pair sort key (e.g. operand length bucket): drained pairs are
    stably reordered by it before chunk slicing so chunks stay
    dispatch-width homogeneous (see ``_assemble``).
``chunk_widths(cols) -> Optional[np.ndarray]`` (optional)
    Per-pair chunk-width cap, evaluated on the *sorted* columns: pair i
    may share a chunk with at most ``widths[i] - 1`` predecessors.
    Engines derive it per length bucket (``core.bitmap.chunk_width_for``)
    so small-operand chunks go wider at equal VMEM footprint while the
    compile cache stays keyed on bucketed (width, op) pairs.  ``None``
    (or an absent hook) falls back to the global ``pair_chunk`` knob.

Work accounting for every engine flows through one shared struct
(:class:`EngineAccounting`): ``device_calls``, ES deaths, allocator
grows/compactions, peak live mass and the pipeline occupancy metrics
mean the same thing in every engine's stats dict and in
``benchmarks/bench_paper.py``.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from time import perf_counter
from typing import (Any, Deque, Dict, Hashable, List, NamedTuple,
                    Optional, Tuple)

import numpy as np

from repro.core.guards import device_purity_guard


@dataclass
class EngineAccounting:
    """Shared device-engine accounting (one struct for all three engines).

    ``peak_live`` is the allocator's peak live mass — bitmap rows for the
    row-store engines, PPC-code triples for the N-list engine.
    ``compaction_occupancy`` is ``live / capacity`` right after the most
    recent compaction epoch (0.0 when compaction never fired).

    Pipeline telemetry (ISSUE 7): ``inflight_groups`` is the ring depth
    the run was configured with; ``device_occupancy`` is the fraction of
    drain groups dispatched while an earlier group was still in flight
    (deterministic — derived from ring state at dispatch, not from
    timing — so it is exactly 0.0 for a serial ``inflight=1`` run);
    ``assemble_s`` / ``resolve_s`` split host time between group
    assembly+dispatch and blocking retire-time readbacks."""

    candidates: int = 0
    nodes: int = 0
    device_calls: int = 0
    grows: int = 0               # allocator slab reallocations
    compactions: int = 0         # allocator compaction epochs
    peak_live: int = 0           # peak live allocator mass
    peak_device_words: int = 0   # high-water slab words, all shards
    compaction_occupancy: float = 0.0
    runtime_s: float = 0.0
    # Survivor-only materialization telemetry (ISSUE 5): every fused
    # dispatch scatters ONLY the children whose support cleared minsup,
    # so ``child_scatters`` equals the number of frequent children (not
    # candidates) and ``scatter_words`` is the device words they cost —
    # bitmap rows (n_blocks * block_words each) or PPC-code words
    # (3 * child_len each).
    child_scatters: int = 0
    scatter_words: int = 0
    # Dispatch-pipeline telemetry (ISSUE 7).
    inflight_groups: int = 1
    device_occupancy: float = 0.0
    assemble_s: float = 0.0
    resolve_s: float = 0.0

    @property
    def deaths(self) -> int:
        """Candidates certified infrequent by ES (engine-specific split
        lives in the subclasses)."""
        return 0

    def note_allocator(self, alloc) -> None:
        """Pull the shared allocator counters (rowstore / nlist pool)."""
        self.grows = alloc.grows
        self.compactions = alloc.compactions
        self.peak_live = alloc.peak_live
        # Row-store slabs report their high-water device footprint; the
        # N-list pool has no single-slab equivalent (0 there).
        self.peak_device_words = int(
            getattr(alloc, "peak_device_words", 0))
        self.compaction_occupancy = alloc.last_compaction_occupancy

    def note_scheduler(self, sched: "FrontierScheduler") -> None:
        """Pull the pipeline counters from the scheduler that ran."""
        self.inflight_groups = sched.inflight
        self.device_occupancy = sched.device_occupancy
        self.assemble_s = sched.assemble_s
        self.resolve_s = sched.resolve_s

    def accounting_dict(self) -> Dict[str, float]:
        return {
            "device_calls": self.device_calls,
            "deaths": self.deaths,
            "compactions": self.compactions,
            "compaction_occupancy": round(self.compaction_occupancy, 4),
            "child_scatters": self.child_scatters,
            "scatter_words": self.scatter_words,
            "inflight_groups": self.inflight_groups,
            "device_occupancy": round(self.device_occupancy, 4),
            "assemble_s": round(self.assemble_s, 6),
            "resolve_s": round(self.resolve_s, 6),
        }


@dataclass
class ClassNode:
    """One equivalence class on the frontier.

    ``rows`` are allocator handles (row-store slots or N-list pool row
    ids) — contents never leave the device.  ``representation`` tags
    what those handles *hold* (ISSUE 6): ``"tidset"`` (TID bitmap
    rows), ``"diffset"`` (dEclat difference rows) or ``"nlist"``
    (PPC-code extents).  The tag rides the class, not the allocator —
    both bitmap representations share one ``DeviceRowStore`` slab, and
    compaction remaps ``rows`` only, so the tag survives remapping by
    construction.  ``payload`` carries the engine-specific extras
    (bitmap miners: the representation the class's *children* will be
    materialised in, decided once at ``make_class`` time; N-list: the
    per-member exact lengths)."""

    itemsets: List[Tuple[Hashable, ...]]
    rows: np.ndarray          # int32 (m,)
    supports: np.ndarray      # int32 (m,)
    payload: Any = None
    representation: str = "tidset"


class Child(NamedTuple):
    """One surviving candidate, as returned through ``evaluate_pairs``."""

    itemset: Tuple[Hashable, ...]
    row: int
    support: int
    extra: Any


class _InflightGroup:
    """One dispatched-but-unretired drain group in the pipeline ring.

    ``parts`` holds ``(chunk_lo, handle_or_results)`` per chunk slice:
    a lazy handle while readbacks are deferred, or an already-resolved
    result list (``inflight=1``, or clients returning plain iterables).
    """

    __slots__ = ("drained", "meta", "parts", "total")

    def __init__(self, drained: List[ClassNode],
                 meta: List[Tuple[int, int, int]],
                 parts: List[Tuple[int, Any]], total: int):
        self.drained = drained
        self.meta = meta
        self.parts = parts
        self.total = total


class FrontierScheduler:
    """Shared DFS work-stack with cross-class drain-group batching and a
    double-buffered dispatch pipeline.

    Classes are drained from the stack until one ``drain_target`` worth
    of sibling pairs is collected, their pair triangles are concatenated
    into global operand columns, and each chunk slice goes to the client
    as exactly one fused device dispatch.  Result sets are
    order-independent, so draining order never affects correctness.

    Pipelining: up to ``inflight`` groups sit in a FIFO ring between
    dispatch and retirement.  Assembly of the next group overlaps device
    execution of the previous ones; a group's blocking readbacks, child
    pushes and operand releases all happen when it is popped from the
    ring.  Group composition is taken from the stack *as of dispatch
    time* — a pipelined run may therefore batch classes differently
    than a serial one (``device_calls``/``grows`` may differ) while the
    emitted itemsets, child order and all order-invariant work counters
    (candidates, word_ops, comparisons, es_checks, ...) are identical.

    Row lifetime: a class's member rows are operands only for its own
    pair triangle, so they are released as soon as the drain group that
    consumed them retires; child rows live until the child class is
    drained in turn.  Compaction runs at drain-group boundaries, where
    the stack plus the drained group plus the in-flight ring is exactly
    the live row set — the scheduler remaps every frontier handle,
    including the pending handles of in-flight groups, through the
    mapping the allocator returns (safe under JAX async dispatch: the
    in-flight dispatches hold their operand *values* via the donation
    data-dependency chain, only host-side slot ids move).
    """

    def __init__(self, client, pair_chunk: int, *, inflight: int = 1,
                 drain_target: Optional[int] = None):
        self.client = client
        self.pair_chunk = int(pair_chunk)
        self.inflight = max(1, int(inflight))
        # Autotuned widths can exceed pair_chunk; drain enough pairs to
        # fill the widest chunk the client may request.
        self.drain_target = (int(drain_target) if drain_target
                             else self.pair_chunk)
        # 2-D dispatch alignment (ISSUE 9): a client whose dispatch
        # splits each chunk over a cls mesh axis advertises the shard
        # count; chunk boundaries are rounded down to a multiple of it
        # so every cls-shard's slice is an equal contiguous run of the
        # sorted pair columns (bucket-sorted by construction — a
        # contiguous slice of a sorted chunk is sorted).
        self.chunk_quantum = max(1, int(getattr(client, "chunk_quantum", 1)))
        self._stack: List[ClassNode] = []
        self._ring: Deque[_InflightGroup] = deque()
        # Pipeline telemetry: a group counts as "overlapped" iff an
        # earlier group was still in flight at its dispatch.  Pure ring
        # bookkeeping (no timing), so the metric is deterministic.
        self.groups_dispatched = 0
        self.groups_overlapped = 0
        self.assemble_s = 0.0
        self.resolve_s = 0.0

    @property
    def device_occupancy(self) -> float:
        """Fraction of drain groups dispatched while the ring was
        non-empty (exactly 0.0 for a serial ``inflight=1`` run)."""
        return self.groups_overlapped / max(self.groups_dispatched, 1)

    # -- frontier bookkeeping ------------------------------------------------

    def push(self, klass: ClassNode) -> None:
        self._stack.append(klass)

    def drain_group(self) -> Tuple[List[ClassNode], int]:
        """Pop classes until one drain_target of pairs is filled.  Leaf
        classes (< 2 members) release their rows and contribute none."""
        drained: List[ClassNode] = []
        total = 0
        while self._stack and total < self.drain_target:
            klass = self._stack.pop()
            m = len(klass.itemsets)
            if m < 2:
                self.client.release(klass)
                continue
            drained.append(klass)
            total += m * (m - 1) // 2
        return drained, total

    def remap(self, mapping: np.ndarray,
              drained: Optional[List[ClassNode]] = None) -> None:
        """Apply an allocator old->new row-id mapping to every live
        frontier handle: stack, the drain group being assembled, and
        every in-flight group (class handles AND pending result
        handles — a retired handle is never remapped because retirement
        pops the group from the ring before the next compaction point).
        """
        for klass in self._stack:
            klass.rows = mapping[klass.rows]
        for klass in drained or ():
            klass.rows = mapping[klass.rows]
        for group in self._ring:
            for klass in group.drained:
                klass.rows = mapping[klass.rows]
            for _lo, part in group.parts:
                remap_fn = getattr(part, "remap", None)
                if remap_fn is not None:
                    remap_fn(mapping)

    # -- main loop -----------------------------------------------------------

    def run(self, root: ClassNode) -> None:
        # Runtime half of the DL001 contract (ISSUE 10): the whole
        # mining loop runs under the device->host transfer guard, so
        # any readback not routed through an annotated host_sync()
        # escape raises on accelerator backends (inert on CPU, where
        # d2h is zero-copy — there the static rule enforces).
        with device_purity_guard():
            self._run(root)

    def _run(self, root: ClassNode) -> None:
        self.push(root)
        ring = self._ring
        while self._stack or ring:
            # Fill the pipeline: dispatch groups until the ring is full
            # or the stack is dry.  Children only appear at retirement,
            # so every group in one fill round batches pre-existing
            # frontier classes.
            while self._stack and len(ring) < self.inflight:
                drained, total = self.drain_group()
                if not drained:
                    continue
                # Compaction reserve must cover the WHOLE drain group
                # plus every in-flight group, not one pair_chunk: a
                # group's chunks allocate children cumulatively (earlier
                # chunks' survivors stay live while later chunks
                # allocate), and in-flight groups allocate at
                # retirement, so a smaller reserve let a compaction
                # shrink to a size the pipeline immediately regrew
                # (compact -> grow thrash).  Under a 2-D (block, cls)
                # dispatch this reserve is already the UNION of all
                # cls-shards' pending handles (satellite 6 audit):
                # ``g.total`` counts the group's GLOBAL pairs — slots
                # are allocated host-side per pair before the chunk is
                # cls-split on device — so no per-shard accounting can
                # undercount it.
                pending = sum(g.total for g in ring)
                mapping = self.client.maybe_compact(total + pending)
                if mapping is not None:
                    self.remap(mapping, drained)

                t0 = perf_counter()
                r0 = self.resolve_s
                cols, meta = self._assemble(drained)
                widths = None
                widths_fn = getattr(self.client, "chunk_widths", None)
                if widths_fn is not None:
                    widths = widths_fn(cols)
                parts: List[Tuple[int, Any]] = []
                for lo, sl in self._chunk_slices(total, widths):
                    chunk = {k: v[sl] for k, v in cols.items()}
                    handle = self.client.evaluate_pairs(chunk)
                    if self.inflight == 1:
                        # Serial mode resolves chunk-by-chunk so dead
                        # slots are freed before the next chunk
                        # allocates — bit-for-bit the pre-pipeline
                        # accounting (slot reuse order included).
                        handle = self._resolve(handle)
                    parts.append((lo, handle))
                # Assembly time excludes any resolve time accrued inside
                # the loop (inflight=1 resolves inline).
                self.assemble_s += ((perf_counter() - t0)
                                    - (self.resolve_s - r0))
                if ring:
                    self.groups_overlapped += 1
                self.groups_dispatched += 1
                ring.append(_InflightGroup(drained, meta, parts, total))
            if ring:
                self._retire(ring.popleft())

    def _resolve(self, handle) -> List[Tuple[int, int, int, Any]]:
        """Materialise one chunk's deferred result (blocking readbacks
        + stats attribution happen inside the client handle)."""
        t0 = perf_counter()
        if hasattr(handle, "resolve"):
            out = list(handle.resolve())
        else:
            out = list(handle)
        self.resolve_s += perf_counter() - t0
        return out

    def _retire(self, group: _InflightGroup) -> None:
        """Pop one group from the ring: resolve its deferred handles,
        emit survivors, push child classes in canonical order, release
        the consumed operand rows."""
        drained, meta = group.drained, group.meta
        groups: Dict[Tuple[int, int], List[Tuple[int, Child]]] = {}
        for lo, part in group.parts:
            results = part if isinstance(part, list) else self._resolve(part)
            for ki, row, support, extra in results:
                ci, a, b = meta[lo + ki]
                klass = drained[ci]
                itemset = klass.itemsets[a] + (klass.itemsets[b][-1],)
                self.client.emit(itemset, support)
                groups.setdefault((ci, a), []).append(
                    (b, Child(itemset, row, support, extra)))
        # Child classes are rebuilt in canonical sibling order (b
        # ascending), NOT evaluation order: chunk_sort_key may have
        # permuted the pairs, and class member order is load-bearing
        # (pair orientation / search order within the class).
        for ci, _a in sorted(groups):
            kids = [c for _b, c in sorted(groups[(ci, _a)])]
            self.push(self.client.make_class(drained[ci], kids))
        for klass in drained:
            self.client.release(klass)

    def _chunk_slices(self, total: int,
                      widths: Optional[np.ndarray],
                      ) -> List[Tuple[int, slice]]:
        """Cut [0, total) into dispatch chunks.  Without widths: fixed
        ``pair_chunk`` strides.  With per-pair width caps (already in
        sorted-column order, non-increasing after the length sort): grow
        each chunk greedily while it stays within the width cap of every
        member — chunk size <= min(widths in chunk) by construction."""
        slices: List[Tuple[int, slice]] = []
        q = self.chunk_quantum
        lo = 0
        while lo < total:
            if widths is None:
                end = min(lo + self.pair_chunk, total)
            else:
                end = lo + 1
                while end < total and (end - lo) < int(widths[end]):
                    end += 1
            if q > 1 and end < total and (end - lo) > q:
                # Align non-final chunks to the cls-shard count so each
                # shard's slice covers real pairs evenly (the dispatch
                # pads any remainder with dropped writes — correct but
                # wasted lanes).  Rounding DOWN keeps every width cap
                # satisfied.
                end = lo + ((end - lo) // q) * q
            slices.append((lo, slice(lo, end)))
            lo = end
        return slices

    def _assemble(self, drained: List[ClassNode],
                  ) -> Tuple[Dict[str, np.ndarray],
                             List[Tuple[int, int, int]]]:
        """Concatenate every drained class's sibling-pair triangle into
        global operand columns plus (class, a, b) metadata.

        Length-aware composition (ISSUE 5): a client whose per-pair
        dispatch width depends on operand size (the N-list engine — its
        gather widths are the buckets of the chunk *maxima*) exposes
        ``chunk_sort_key(cols) -> int array``; the assembled pairs are
        then stably sorted by that key before chunk slicing, so one
        huge operand no longer widens the dispatch for every pair in
        its chunk.  The permutation is applied to the metadata too, and
        result sets are order-independent, so this only moves padding.
        """
        cols_l: Dict[str, List[np.ndarray]] = {}
        meta: List[Tuple[int, int, int]] = []
        for ci, klass in enumerate(drained):
            m = len(klass.itemsets)
            ia, ib = np.triu_indices(m, 1)
            for key, col in self.client.pair_columns(klass, ia, ib).items():
                # host-sync: protocol guarantees host np operand columns
                cols_l.setdefault(key, []).append(np.asarray(col))
            meta.extend((ci, int(a), int(b)) for a, b in zip(ia, ib, strict=True))
        cols = {k: np.concatenate(v) for k, v in cols_l.items()}
        key_fn = getattr(self.client, "chunk_sort_key", None)
        if key_fn is not None and len(meta) > 1:
            key = key_fn(cols)
            if key is not None:
                # host-sync: sort key is a host np vector by protocol
                order = np.argsort(np.asarray(key), kind="stable")
                cols = {k: c[order] for k, c in cols.items()}
                meta = [meta[int(i)] for i in order]
        return cols, meta
