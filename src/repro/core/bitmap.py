"""Bitmap vertical format for TID-lists — the TPU-native data layout.

The paper's sorted-int TID-lists are pointer-chasing merges; on TPU we
re-represent every TID-list as a packed bitmap row so that intersection is
``AND`` + popcount (pure 8x128-lane VPU work) and dEclat's difference is
``ANDNOT``.  The early-stopping criterion survives the translation at block
granularity via per-row *suffix popcount* tables (see DESIGN.md §2).

Layout
------
``bitmaps: uint32[n_items, n_blocks, block_words]``
    bit ``b`` of word ``w`` of block ``k`` of row ``i``  ⇔  transaction
    ``(k*block_words + w) * 32 + b`` contains item ``i``.  TIDs here are
    0-based (the oracle is 1-based to match the paper's prose).
``suffix: int32[n_items, n_blocks + 1]``
    ``suffix[i, k] = popcount(bitmaps[i, k:, :])`` — the mass still
    achievable from block ``k`` onward.  ``suffix[i, 0]`` is the support.

``block_words`` defaults to 128 words = 4096 transactions per block so a
block is exactly one 8x128 VPU-aligned uint32 tile row-group.

Residency: on the mining hot path both slabs are *device-resident* — rows
and suffix tables live in ``core.rowstore.DeviceRowStore`` and are
gathered/scattered by row index inside the fused dispatch
(``kernels.ops.screen_and_intersect``).  :func:`suffix_popcounts` is the
device producer of the suffix slab; :func:`suffix_popcounts_np` is its
host mirror, kept for packing-time code and tests.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Hashable, List, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

WORD_BITS = 32
DEFAULT_BLOCK_WORDS = 128  # 4096 TIDs per block; one lane-aligned tile.

# Padding sentinel for N-list arrays (shared by core.prepost and
# kernels.ref; lives here to keep the import graph acyclic).
NL_SENTINEL = np.iinfo(np.int32).max

# Bucketed N-list lengths: gather widths and pool extents are padded to
# these so the jit cache sees few distinct shapes.  Lengths past the
# largest tuned bucket fall back to next-power-of-two sizing (huge
# N-lists are rare but must not be a hard error).
NL_LEN_BUCKETS = (8, 32, 128, 512, 2048, 8192, 32768)

# Pair-chunk batch buckets, one table per dispatch family.  They live
# HERE, next to :func:`bucket_pad`, so the engines' pair-chunk clamp
# (``min(pair_chunk, BUCKETS[-1])``) and their pad calls can never
# drift apart again (pre-ISSUE-5 each engine kept a private, diverging
# ``_PAIR_BUCKETS`` copy).  The bitmap table tops out higher because a
# bitmap pair costs O(row) operand traffic regardless of batch width,
# while an N-list chunk's gather width is the bucket of its LONGEST
# operand — huge merge batches amplify padding instead of throughput.
PAIR_CHUNK_BUCKETS = (64, 256, 1024, 4096, 16384, 65536, 262144)
NL_PAIR_CHUNK_BUCKETS = (64, 256, 1024, 4096, 8192, 32768)

# Chunk-width autotuning (ISSUE 7): ``pair_chunk`` is calibrated at a
# *reference* per-pair operand size; smaller operands can dispatch in
# proportionally wider chunks at the same VMEM footprint.  These are
# the reference sizes the knob is understood to be tuned at — a bitmap
# pair moving ~1024 words (8 blocks x 128 words, the smoke shape), an
# N-list pair whose longest operand sits in the 128-length bucket
# (3 code words per PPC node).
BITMAP_REF_ROW_WORDS = 1024
NL_REF_LEN = 128


def chunk_width_for(words_per_pair: int, base_chunk: int,
                    bucket_table: Sequence[int], ref_words: int) -> int:
    """Per-bucket pair-chunk width at equal VMEM footprint.

    Returns the largest bucket ``w`` in ``bucket_table`` with
    ``w * words_per_pair <= base_chunk * ref_words`` — i.e. the widest
    bucketed chunk whose operand traffic stays within the budget the
    caller's ``base_chunk`` knob implies at the reference operand size.
    The result is floored at ``base_chunk`` (snapped into the table):
    autotuning only *widens* small-operand chunks, so ``device_calls``
    can never increase relative to the un-autotuned engine, and only
    bucketed widths reach the jit cache (one (width, op) variant per
    table entry, bounded)."""
    budget = max(1, int(base_chunk)) * max(1, int(ref_words))
    width = 0
    for b in bucket_table:
        if b * max(1, int(words_per_pair)) <= budget:
            width = b
    floor = min(int(base_chunk), bucket_table[-1])
    return max(width, floor)


def nl_pad_len(n: int) -> int:
    """Smallest N-list bucket >= ``n`` (power-of-two fallback past the
    largest tuned bucket)."""
    for b in NL_LEN_BUCKETS:
        if n <= b:
            return b
    b = NL_LEN_BUCKETS[-1]
    while b < n:
        b *= 2
    return b


def nl_pad_len_np(lengths: np.ndarray) -> np.ndarray:
    """Vectorized :func:`nl_pad_len` (host): the per-pair length-bucket
    key the frontier scheduler sorts drained pairs by so one huge N-list
    cannot widen the gather for a whole chunk of small ones."""
    # host-sync: host length vectors (scheduler sort key); no device value
    lengths = np.asarray(lengths, np.int64)
    # host-sync: host bucket-table constant; no device value touched
    buckets = np.asarray(NL_LEN_BUCKETS, np.int64)
    idx = np.searchsorted(buckets, np.maximum(lengths, 0))
    out = buckets[np.minimum(idx, len(buckets) - 1)]
    big = lengths > buckets[-1]
    if big.any():
        out = out.copy()
        out[big] = [nl_pad_len(int(v)) for v in lengths[big]]
    return out


def bucket_pad(arr: np.ndarray, n: int, bucket_sizes: Sequence[int],
               fill=0) -> np.ndarray:
    """Pad ``arr`` (first ``n`` entries valid) to the smallest bucket >= n.

    Shared by every engine's pair-chunk dispatch so jit caches stay
    small; callers drop results past ``n``."""
    for b in bucket_sizes:
        if n <= b:
            if n == b:
                return arr
            pad_shape = (b - n,) + arr.shape[1:]
            return np.concatenate([arr, np.full(pad_shape, fill, arr.dtype)])
    raise ValueError(f"batch of {n} exceeds largest bucket "
                     f"{max(bucket_sizes)}")


def popcount32(x: jnp.ndarray) -> jnp.ndarray:
    """SWAR population count for uint32 arrays (returns int32)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def popcount32_np(x: np.ndarray) -> np.ndarray:
    """Host-side popcount (numpy mirror of :func:`popcount32`)."""
    x = x.astype(np.uint32)
    x = x - ((x >> 1) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> 2) & np.uint32(0x33333333))
    x = (x + (x >> 4)) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> 24).astype(np.int32)


def pack_tidlists(tidlists: Sequence[Sequence[int]], n_trans: int,
                  block_words: int = DEFAULT_BLOCK_WORDS,
                  ) -> np.ndarray:
    """Pack 0-based TID lists into ``uint32[n_rows, n_blocks, block_words]``."""
    n_rows = len(tidlists)
    n_words = -(-n_trans // WORD_BITS)
    n_blocks = max(1, -(-n_words // block_words))
    flat = np.zeros((n_rows, n_blocks * block_words), dtype=np.uint32)
    for r, tids in enumerate(tidlists):
        if len(tids) == 0:
            continue
        # host-sync: pack-time host TID lists; no device value touched
        t = np.asarray(tids, dtype=np.int64)
        if t.min() < 0 or t.max() >= n_trans:
            raise ValueError("TID out of range")
        np.bitwise_or.at(flat[r], t // WORD_BITS,
                         np.uint32(1) << (t % WORD_BITS).astype(np.uint32))
    return flat.reshape(n_rows, n_blocks, block_words)


def unpack_row(row: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack_tidlists` for one row -> sorted 0-based TIDs."""
    # host-sync: tests/debug unpack helper (readback is the caller's choice)
    flat = np.asarray(row, dtype=np.uint32).reshape(-1)
    bits = np.unpackbits(flat.view(np.uint8), bitorder="little")
    return np.nonzero(bits)[0].astype(np.int64)


def suffix_popcounts_np(bitmaps: np.ndarray) -> np.ndarray:
    """``int32[n_rows, n_blocks+1]`` suffix popcount table (host)."""
    per_block = popcount32_np(bitmaps).sum(axis=-1)          # (rows, blocks)
    n_rows, n_blocks = per_block.shape
    out = np.zeros((n_rows, n_blocks + 1), dtype=np.int32)
    out[:, :-1] = per_block[:, ::-1].cumsum(axis=1)[:, ::-1]
    return out


def suffix_popcounts(bitmaps: jnp.ndarray) -> jnp.ndarray:
    """Device version of :func:`suffix_popcounts_np`."""
    per_block = popcount32(bitmaps).sum(axis=-1).astype(jnp.int32)
    rev = jnp.cumsum(per_block[:, ::-1], axis=1)[:, ::-1]
    zeros = jnp.zeros((bitmaps.shape[0], 1), dtype=jnp.int32)
    return jnp.concatenate([rev, zeros], axis=1)


@dataclass
class BitmapDB:
    """A transaction database packed for device mining.

    Rows are the frequent 1-itemsets in *increasing* frequency (the
    Eclat/dEclat search order from the paper §II-A).
    """

    items: List[Hashable]                 # row -> original item
    bitmaps: np.ndarray                   # uint32 (n_items, n_blocks, bw)
    supports: np.ndarray                  # int32 (n_items,)
    n_trans: int
    minsup: int
    block_words: int

    @property
    def n_items(self) -> int:
        return len(self.items)

    @property
    def n_blocks(self) -> int:
        return self.bitmaps.shape[1]

    @classmethod
    def from_db(cls, db: Sequence[Sequence[Hashable]], minsup: int,
                block_words: int = DEFAULT_BLOCK_WORDS) -> "BitmapDB":
        from .oracle import frequent_items_ascending

        items = frequent_items_ascending(db, minsup)
        index: Dict[Hashable, int] = {it: r for r, it in enumerate(items)}
        tidlists: List[List[int]] = [[] for _ in items]
        for tid, t in enumerate(db):
            for it in set(t):
                r = index.get(it)
                if r is not None:
                    tidlists[r].append(tid)
        bitmaps = pack_tidlists(tidlists, max(len(db), 1), block_words)
        # host-sync: pack-time host supports; no device value touched
        supports = np.array([len(t) for t in tidlists], dtype=np.int32)
        return cls(items=items, bitmaps=bitmaps, supports=supports,
                   n_trans=len(db), minsup=minsup, block_words=block_words)


def pad_pairs(ia: np.ndarray, ib: np.ndarray, bucket_sizes: Sequence[int],
              ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pad pair index vectors to the smallest bucket >= n (stable jit shapes).

    Padding replicates pair 0 (harmless: results beyond ``n`` are dropped by
    the caller).  Returns (ia_padded, ib_padded, n_valid)."""
    n = int(ia.shape[0])
    for b in bucket_sizes:
        if n <= b:
            pad = b - n
            if pad:
                ia = np.concatenate([ia, np.zeros(pad, ia.dtype)])
                ib = np.concatenate([ib, np.zeros(pad, ib.dtype)])
            return ia, ib, n
    raise ValueError(f"pair batch of {n} exceeds largest bucket "
                     f"{max(bucket_sizes)}; raise pair_chunk")
