"""Device bitmap miners: Eclat and dEclat with block-level early stopping.

Host/DFS split (DESIGN.md §2): the equivalence-class depth-first search
stays on the host (Python), but the host only ever handles row *indices*
and small int vectors — every bitmap row lives in a device-resident
``DeviceRowStore`` slab (core/rowstore.py) from the moment the level-1
TID bitmaps are uploaded until the slot is free-listed.  Candidate
evaluation is batched at the *class* level: every sibling pair (a, b),
a<b, of one equivalence class goes to the device in chunked calls, and
each chunk is exactly **one** device dispatch
(``kernels.ops.screen_and_intersect``):

  * gather: operand rows + suffix tables are picked out of the slab by
    index (no host U/V materialisation, no re-upload);
  * screen: the kernel evaluates the one-block bound first — a pair whose
    block-0 bound misses minsup dies with ``blocks_done == 1`` and costs
    no further blocks;
  * blocked ES: surviving pairs walk TID blocks and abort the moment the
    suffix bound drops below minsup (the paper's INTERSECT_ES /
    DIFFERENCE_ES quantised to blocks);
  * scatter: child rows *and* their suffix-popcount tables are computed
    on device and written into preallocated slots of the same slab —
    **survivor-only** (ISSUE 5): the count phase completes before the
    scatter phase and gates it, so dead candidates cost zero scatter
    words (``stats.child_scatters`` counts frequent children exactly).

Slots are still *reserved* pessimistically (one per candidate pair —
the scatter destinations must exist before the dispatch) and the dead
ones are returned to the free list right after, but nothing was ever
written to them: free-list traffic is pure host bookkeeping, so
infrequent candidates cost zero extra device work.  When occupancy
drops far enough the scheduler compacts the slab at a drain-group
boundary (``DeviceRowStore.compact_if_sparse``) and remaps the
frontier's slot handles through the returned mapping.

Representations (ISSUE 6): the same slab holds BOTH bitmap
representations.  A class is tagged ``tidset`` (rows are TID bitmaps,
pairs dispatch through ``ops.screen_and_intersect``) or ``diffset``
(rows are dEclat difference bitmaps ``d(Pxy)``, pairs dispatch through
``ops.screen_and_diff`` on the difference bound ``sup(parent) -
|diff|``).  ``scheme="eclat"`` stays tidset everywhere,
``scheme="declat"`` flips at level 2, and ``scheme="adaptive"`` flips a
subtree tidset→diffset when its density (mean member support /
n_trans) clears ``diff_density + diff_hysteresis`` at ``make_class``
time — dense classes are where diffsets shrink operands the most
(|d| = sup(parent) - sup(child)).  The flip is one-way (the parent
tidset rows are freed when the class drains) and costs no extra round
trip: the very same diff dispatch that extends diffset classes converts
a tidset pair ``T(a), T(b)`` into the level-2 diffset ``d(ab) = T(a) &
~T(b)`` inside its child scatter.  Mixed drain groups carry a per-pair
``op`` column; ``chunk_sort_key`` orders pairs by it so chunks stay
mode-homogeneous and pure schemes keep exactly one dispatch per chunk.

Work metric: ``word_ops`` — uint32 word operations actually performed
(blocks_done x block_words per pair; the fused screen is block 0 of the
same scan).  This is the device analogue of the paper's #comparisons and
is what benchmarks/bench_paper.py reports next to the oracle's exact
counter.  Diff dispatches charge only nonzero-mass U blocks (a zero
block of a sparse diffset operand cannot contribute to ``U & ~V`` and
is skipped), while ``word_ops_full`` stays the dense tidset full-scan
cost ``n_pairs * n_blocks * block_words`` — the paper's non-ES
baseline — so ``word_ops_saved_frac`` folds in both the ES savings and
the representation savings.

The traversal policy (work stack, cross-class drain-group batching,
chunk slicing, operand free-listing, compaction scheduling) lives in
``core.frontier.FrontierScheduler`` — this module only implements the
scheduler's client protocol on top of the fused bitmap dispatches.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, FrozenSet, Hashable, List, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.bitmap import (BITMAP_REF_ROW_WORDS, BitmapDB,
                               DEFAULT_BLOCK_WORDS, PAIR_CHUNK_BUCKETS,
                               bucket_pad, chunk_width_for)
from repro.core.frontier import (Child, ClassNode, EngineAccounting,
                                 FrontierScheduler)
from repro.core.guards import host_sync
from repro.core.rowstore import DeviceRowStore
from repro.kernels import ops

ItemsetSupports = Dict[FrozenSet[Hashable], int]

# Canonical table lives in core.bitmap next to bucket_pad (ISSUE 5
# consolidation) so the pair-chunk clamp and the pad logic cannot drift.
_PAIR_BUCKETS = PAIR_CHUNK_BUCKETS

# Per-pair dispatch-mode codes carried in the ``op`` column (int8):
# chunk_sort_key orders mixed drain groups by this so chunks stay
# mode-homogeneous.
_OP_AND = 0                    # tidset intersect (ops.screen_and_intersect)
_OP_DIFF = 1                   # diffset difference (ops.screen_and_diff)

# Default density threshold for scheme="adaptive": a class whose mean
# member support exceeds this fraction of n_trans (plus the hysteresis
# band) materialises its children as diffsets.
DEFAULT_DIFF_DENSITY = 0.5


@dataclass
class DeviceMiningStats(EngineAccounting):
    """Work accounting for the bitmap engine (device analogue of
    ``oracle.MiningStats``; the shared device/allocator counters come
    from ``frontier.EngineAccounting``)."""

    screened_out: int = 0        # pairs killed by the one-block screen
    kernel_aborts: int = 0       # pairs killed past block 0
    word_ops: int = 0            # uint32 ops actually performed
    word_ops_full: int = 0       # what a non-ES engine would have performed

    # Legacy names kept as read-only views of the shared accounting.
    @property
    def store_grows(self) -> int:
        return self.grows

    @property
    def peak_rows(self) -> int:
        return self.peak_live

    @property
    def deaths(self) -> int:
        return self.screened_out + self.kernel_aborts

    @property
    def ratio(self) -> float:
        return self.candidates / max(self.nodes, 1)

    @property
    def word_ops_saved_frac(self) -> float:
        if self.word_ops_full == 0:
            return 0.0
        return 1.0 - self.word_ops / self.word_ops_full

    def as_dict(self) -> Dict[str, float]:
        return {
            "candidates": self.candidates,
            "nodes": self.nodes,
            "ratio": round(self.ratio, 4),
            "screened_out": self.screened_out,
            "kernel_aborts": self.kernel_aborts,
            "word_ops": self.word_ops,
            "word_ops_full": self.word_ops_full,
            "word_ops_saved_frac": round(self.word_ops_saved_frac, 4),
            "store_grows": self.store_grows,
            "peak_rows": self.peak_rows,
            "runtime_s": round(self.runtime_s, 6),
            **self.accounting_dict(),
        }


def _bucket_pad(arr: np.ndarray, n: int, fill=0) -> np.ndarray:
    return bucket_pad(arr, n, _PAIR_BUCKETS, fill)


class PendingPairResult:
    """Lazy result handle for one bitmap ``evaluate_pairs`` dispatch
    (ISSUE 7 pipeline).

    The fused dispatches were already launched (JAX async dispatch —
    the device is busy); what is deferred here is every *blocking*
    ``np.asarray`` readback of count/blocks/alive plus the stats
    attribution and dead-slot frees that depend on them.  The scheduler
    calls ``resolve()`` exactly once when the owning drain group
    retires; if a slab compaction lands while the group is in flight it
    calls ``remap(mapping)`` so the child slot ids this handle will
    report stay valid (the dispatches themselves are unaffected — their
    operands travel by value through the donation chain)."""

    __slots__ = ("_miner", "_n", "_slots", "_segments")

    def __init__(self, miner: "BitmapMiner", n: int, slots: np.ndarray,
                 segments: List[Tuple[np.ndarray, str, np.ndarray, Any]]):
        self._miner = miner
        self._n = n
        self._slots = slots
        self._segments = segments

    def remap(self, mapping: np.ndarray) -> None:
        self._slots = mapping[self._slots]

    def resolve(self) -> List[Tuple[int, int, int, Any]]:
        miner = self._miner
        stats, store = miner._stats, miner._store
        n, slots = self._n, self._slots
        support = np.zeros(n, np.int64)
        freq = np.zeros(n, bool)
        for sel, mode, rho_sel, raw in self._segments:
            cnt, alive = miner._dispatch_resolve(raw, int(sel.size))
            sup = cnt if mode == "and" else rho_sel - cnt
            support[sel] = sup
            # Dead pairs carry frozen (partial) counts; in diff mode a
            # frozen count *overestimates* the support (rho - cnt), so
            # aliveness is load-bearing.  This mask is exactly the
            # dispatch's in-kernel scatter gate (ref._survivor_mask):
            # only these children were materialised.
            freq[sel] = np.logical_and(sup >= miner._minsup, alive)

        kept_idx = np.nonzero(freq)[0]
        stats.child_scatters += int(kept_idx.size)
        # Real (unpadded) blocks, like word_ops/word_ops_full: the
        # telemetry stays shard-count invariant even though a sharded
        # store physically pads each child row's block axis with zeros.
        stats.scatter_words += (int(kept_idx.size) * miner._n_blocks
                                * miner.block_words)
        store.free(slots[~freq])                  # dead children: recycle
        self._segments = []                       # drop device refs
        return [(int(ki), int(slots[ki]), int(support[ki]), None)
                for ki in kept_idx]


class BitmapMiner:
    """Eclat / dEclat / density-adaptive mining over a device-resident
    row store with fused screen+intersect(+difference) early stopping.

    The DFS itself is ``core.frontier.FrontierScheduler`` — this class is
    its client: it turns one class's sibling-pair triangle into store
    slot columns, evaluates a pair-chunk slice as ONE fused device
    dispatch, and recycles spent slots.  ``compact_occupancy`` is the
    allocator memory-tuning knob: when live rows fall below that
    fraction of the slab (and the slab would at least halve), the
    scheduler compacts it between drain groups; 0 disables compaction.

    ``scheme="adaptive"`` (ISSUE 6) mines tidsets but flips a subtree
    to diffsets when its class density (mean member support / n_trans)
    clears ``diff_density + diff_hysteresis`` — the flip is one-way and
    rides the normal child scatter (see the module docstring), so it
    costs no extra device round trip.
    """

    def __init__(self, scheme: str = "eclat", early_stop: bool = True,
                 block_words: int = DEFAULT_BLOCK_WORDS,
                 pair_chunk: int = 65536, backend: str = "auto",
                 metrics: bool = True, compact_occupancy: float = 0.25,
                 diff_density: "float | None" = None,
                 diff_hysteresis: float = 0.05, inflight: int = 2,
                 autotune_chunk: bool = False):
        if scheme not in ("eclat", "declat", "adaptive"):
            raise ValueError(f"bad scheme {scheme!r}")
        if scheme == "adaptive":
            if diff_density is None:
                diff_density = DEFAULT_DIFF_DENSITY
        elif diff_density is not None:
            raise ValueError(
                "diff_density only applies to scheme='adaptive' "
                "(eclat is tidset-only, declat flips unconditionally)")
        self.scheme = scheme
        self.early_stop = early_stop
        self.block_words = block_words
        self.pair_chunk = min(pair_chunk, _PAIR_BUCKETS[-1])
        self.backend = backend
        self.compact_occupancy = compact_occupancy
        # Density-adaptive representation knobs (ISSUE 6): a class flips
        # its children tidset->diffset when density clears
        # ``diff_density + diff_hysteresis``; classes straddling the bare
        # threshold stay tidset (the band plus the one-way flip rule is
        # what makes the choice stable across consecutive drain groups).
        self.diff_density = diff_density
        self.diff_hysteresis = diff_hysteresis
        # Dispatch-pipeline knobs (ISSUE 7): ``inflight`` is the ring
        # depth (2 = double-buffered; 1 reproduces the serial engine's
        # accounting bit-for-bit); ``autotune_chunk`` derives the chunk
        # width from the row size (small-operand runs dispatch wider at
        # equal VMEM footprint — see core.bitmap.chunk_width_for).
        self.inflight = max(1, int(inflight))
        self.autotune_chunk = bool(autotune_chunk)
        # The fused dispatch returns exact blocks_done/word_ops for free;
        # ``metrics`` is kept for API compatibility and no longer selects
        # a separate (two-dispatch) fast path.
        self.metrics = metrics

    # Dispatch chunks are sliced in units of this many pairs so each
    # cls-shard's slice stays aligned; the 2-D DistributedMiner sets it
    # to its cls-axis size (see core.frontier._chunk_slices).
    chunk_quantum = 1

    def mine(self, db: Sequence[Sequence[Hashable]], minsup: int,
             ) -> Tuple[ItemsetSupports, DeviceMiningStats]:
        if minsup < 1:
            raise ValueError("minsup must be an absolute count >= 1")
        return self.mine_packed(
            BitmapDB.from_db(db, minsup, self.block_words), minsup)

    def mine_packed(self, bdb: BitmapDB, minsup: int,
                    ) -> Tuple[ItemsetSupports, DeviceMiningStats]:
        """Mine a pre-packed :class:`BitmapDB` (the paper-scale bench
        streams transactions straight into one, skipping the host-side
        list-of-lists detour that ``mine`` takes)."""
        if minsup < 1:
            raise ValueError("minsup must be an absolute count >= 1")
        stats = DeviceMiningStats()
        t0 = time.perf_counter()

        out: ItemsetSupports = {}
        for r, item in enumerate(bdb.items):
            out[frozenset((item,))] = int(bdb.supports[r])
            stats.nodes += 1

        store = self._make_store(bdb)
        self._minsup = minsup
        self._n_trans = bdb.n_trans
        supports = bdb.supports.astype(np.int32)
        root = ClassNode(
            itemsets=[(it,) for it in bdb.items],
            rows=np.arange(bdb.n_items, dtype=np.int32),
            supports=supports,
            representation="tidset",       # level-1 rows are TID bitmaps
            # payload: the representation this class's CHILDREN will be
            # materialised in (declat flips at level 2; adaptive flips
            # when the density threshold clears).
            payload=self._child_representation("tidset", supports))
        # Work metrics use the REAL block count: a sharded store pads
        # its block axis up to the shard count, and charging those
        # all-zero pad blocks to ``word_ops_full`` inflated every
        # DistributedMiner run's saved-fraction (ISSUE 5 bugfix).
        self._n_blocks = bdb.n_blocks
        self._store = store
        self._out = out
        self._stats = stats
        # Autotuned chunk width: every bitmap pair in a run moves the
        # same per-pair word mass, so the width is one run-wide value
        # (the N-list engine's is per length bucket).
        self._chunk_width = (chunk_width_for(
            self._autotune_words_per_pair(bdb), self.pair_chunk,
            _PAIR_BUCKETS, BITMAP_REF_ROW_WORDS)
            if self.autotune_chunk else None)
        sched = FrontierScheduler(self, self.pair_chunk,
                                  inflight=self.inflight,
                                  drain_target=self._chunk_width)
        sched.run(root)
        stats.note_allocator(store)
        stats.note_scheduler(sched)
        stats.runtime_s = time.perf_counter() - t0
        return out, stats

    def _autotune_words_per_pair(self, bdb: BitmapDB) -> int:
        """Per-DEVICE word mass one pair moves — the autotune budget's
        numerator.  The 2-D distributed miner overrides this to divide
        by its cls-axis size: each cls-shard only evaluates 1/n_cls of
        the chunk, so at equal per-device VMEM the chunk can be n_cls
        times wider (ISSUE 9 satellite 6)."""
        return bdb.n_blocks * self.block_words

    def _make_store(self, bdb: BitmapDB) -> DeviceRowStore:
        """Allocate the device slab.  Subclasses (the distributed miner)
        override this to place it under a sharded layout."""
        return DeviceRowStore(
            bdb.bitmaps,
            capacity=bdb.n_items + min(self.pair_chunk, 4096))

    # -- representation policy (ISSUE 6) ------------------------------------

    def _child_representation(self, member_rep: str,
                              supports: np.ndarray) -> str:
        """Decide, once per class, the representation its children are
        materialised in.  Flips are ONE-WAY (a diffset subtree never
        reverts — its parent tidset rows are freed when the class
        drains), and the adaptive rule only fires when the class
        density clears ``diff_density + diff_hysteresis``: a class
        straddling the bare threshold keeps its tidsets, so the choice
        cannot oscillate across consecutive drain groups."""
        if member_rep == "diffset":
            return "diffset"               # one-way: stay diffset
        if self.scheme == "declat":
            return "diffset"               # unconditional level-2 flip
        if self.diff_density is None:
            return "tidset"                # eclat: tidset everywhere
        if supports.size == 0:
            return "tidset"
        density = float(np.mean(supports)) / max(self._n_trans, 1)
        if density >= self.diff_density + self.diff_hysteresis:
            return "diffset"
        return "tidset"

    # -- FrontierScheduler client protocol ----------------------------------

    def pair_columns(self, klass: ClassNode, ia: np.ndarray,
                     ib: np.ndarray) -> Dict[str, np.ndarray]:
        # Operand orientation (paper Alg. 1/2), keyed off what the
        # member rows HOLD (klass.representation) and what the children
        # should BECOME (klass.payload — fixed at make_class time):
        #   tidset -> tidset:   Z = T(Px) & T(Py)          (op AND)
        #   tidset -> diffset:  D(xy)  = T(x)  & ~T(y)     (op DIFF,
        #       the in-scatter representation conversion: U=x, V=y)
        #   diffset members:    D(Pxy) = D(Py) & ~D(Px)    (op DIFF,
        #       U=Py, V=Px)
        if klass.representation == "diffset":
            ua, vb, op = ib, ia, _OP_DIFF
        elif klass.payload == "diffset":
            ua, vb, op = ia, ib, _OP_DIFF
        else:
            ua, vb, op = ia, ib, _OP_AND
        return {"ua": klass.rows[ua].astype(np.int32),
                "vb": klass.rows[vb].astype(np.int32),
                "rho": klass.supports[ia].astype(np.int32),
                "op": np.full(ia.size, op, np.int8)}

    def chunk_sort_key(self, cols: Dict[str, np.ndarray],
                       ) -> "np.ndarray | None":
        """Stable-sort mixed drain groups by dispatch mode so chunk
        slices stay mode-homogeneous: pure schemes (and most adaptive
        groups) keep exactly ONE fused dispatch per chunk; only a chunk
        that genuinely straddles the AND/DIFF boundary splits in two."""
        op = cols["op"]
        if op.size and int(op.min()) != int(op.max()):
            return op
        return None                        # homogeneous: keep order

    def chunk_widths(self, cols: Dict[str, np.ndarray],
                     ) -> "np.ndarray | None":
        """Per-pair chunk-width cap (ISSUE 7): uniform for the bitmap
        engine — every pair moves ``n_blocks * block_words`` operand
        words, so the equal-VMEM width is one run-wide bucket."""
        if self._chunk_width is None:
            return None
        return np.full(cols["ua"].size, self._chunk_width, np.int64)

    def evaluate_pairs(self, cols: Dict[str, np.ndarray],
                       ) -> PendingPairResult:
        """One pair-chunk slice -> ONE fused device dispatch per
        representation present (exactly one for mode-homogeneous
        chunks — the common case, see ``chunk_sort_key``).

        Returns a :class:`PendingPairResult` whose ``resolve()`` yields
        the frequent children as ``(ki, slot, support, None)`` tuples
        (``ki`` = chunk-local pair index).  The dispatches launch here
        (async); the readbacks happen at resolve."""
        store, stats = self._store, self._stats
        ua, vb, rho, op = cols["ua"], cols["vb"], cols["rho"], cols["op"]
        n = int(ua.size)
        stats.candidates += n
        # word_ops_full is the dense tidset full-scan cost for EVERY
        # pair (the paper's non-ES baseline): diff dispatches that skip
        # zero-mass blocks show up as saved fraction, not a moving
        # baseline.
        stats.word_ops_full += n * self._n_blocks * self.block_words

        slots = store.alloc(n)
        segments = []
        for op_code, mode in ((_OP_AND, "and"), (_OP_DIFF, "diff")):
            sel = np.nonzero(op == op_code)[0]
            if sel.size == 0:
                continue
            raw = self._dispatch_launch(store, ua[sel], vb[sel],
                                        slots[sel], rho[sel], mode)
            segments.append((sel, mode, rho[sel].astype(np.int64), raw))
        return PendingPairResult(self, n, slots, segments)

    def make_class(self, parent: ClassNode,
                   children: List[Child]) -> ClassNode:
        # host-sync: host child metadata (python ints); no device value
        supports = np.asarray([c.support for c in children], np.int32)
        # The children were materialised in the representation the
        # parent committed to at ITS make_class time; decide the
        # grandchildren's representation here, once, so every sibling
        # pair of the new class agrees on a dispatch mode.
        rep = parent.payload
        return ClassNode(
            itemsets=[c.itemset for c in children],
            # host-sync: host child metadata; no device value touched
            rows=np.asarray([c.row for c in children], np.int32),
            supports=supports,
            representation=rep,
            payload=self._child_representation(rep, supports))

    def emit(self, itemset: Tuple[Hashable, ...], support: int) -> None:
        self._out[frozenset(itemset)] = support
        self._stats.nodes += 1

    def release(self, klass: ClassNode) -> None:
        self._store.free(klass.rows)

    def maybe_compact(self, reserve: int) -> "np.ndarray | None":
        """Drain-group boundary hook: compact the slab when occupancy
        warrants it.  Returns the slot mapping for the scheduler to
        remap every live frontier handle (or None)."""
        return self._store.compact_if_sparse(
            self.compact_occupancy, reserve=reserve, backend=self.backend)

    def _dispatch_launch(self, store: DeviceRowStore, ua: np.ndarray,
                         vb: np.ndarray, slots: np.ndarray,
                         rho: np.ndarray, mode: str) -> Tuple:
        """Launch one fused device dispatch and return its un-read
        device outputs ``(cnt, blocks, alive)`` — NO host sync here;
        JAX async dispatch returns immediately and the blocking
        readbacks live in ``_dispatch_resolve`` (the retire path).

        ``mode`` is "and" (tidset intersect) or "diff" (dEclat
        difference — ``ops.screen_and_diff``).  The distributed miner
        overrides the launch/resolve pair with the shard_map
        dispatches."""
        n = int(ua.size)
        cap = store.capacity
        # minsup is always the real threshold: the dispatch's
        # survivor-only scatter gate needs it even with ES disabled
        # (the ``early_stop`` flag alone controls the in-scan abort).
        if mode == "diff":
            store.rows, store.suffix, cnt, blocks, alive = \
                ops.screen_and_diff(
                    store.rows, store.suffix,
                    _bucket_pad(ua, n), _bucket_pad(vb, n),
                    _bucket_pad(slots, n, fill=cap),  # OOB pad -> dropped
                    _bucket_pad(rho, n), jnp.int32(self._minsup),
                    early_stop=self.early_stop, backend=self.backend)
        else:
            store.rows, store.suffix, cnt, blocks, alive = \
                ops.screen_and_intersect(
                    store.rows, store.suffix,
                    _bucket_pad(ua, n), _bucket_pad(vb, n),
                    _bucket_pad(slots, n, fill=cap),  # OOB pad -> dropped
                    _bucket_pad(rho, n), jnp.int32(self._minsup),
                    mode=mode, early_stop=self.early_stop,
                    backend=self.backend)
        self._stats.device_calls += 1
        return cnt, blocks, alive

    def _dispatch_resolve(self, raw: Tuple, n: int,
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Blocking readback of one launched dispatch + work/attribution
        stats (the retire path's deliberate host sync).  Returns
        ``(cnt, alive)`` trimmed to the chunk length, where ``cnt`` is
        the raw kernel count (support for "and", diffset size for
        "diff") and ``alive`` marks pairs that survived ES."""
        stats = self._stats
        cnt, blocks, alive = raw
        # host-sync: the audited group-retirement readback (PR 7) — one
        # deliberate d2h per retired dispatch, deferred via the handle
        with host_sync("group-retirement accounting readback"):
            cnt = np.asarray(cnt[:n])
            blocks = np.asarray(blocks[:n])
            alive = np.asarray(alive[:n])
        stats.word_ops += int(blocks.sum()) * self.block_words
        if self.early_stop:
            # Attribution: a dead pair that did at most one (charged)
            # block was killed by the fused one-block screen — including
            # on single-block datasets (nb == 1) and pairs that died on
            # the final block (blocks == nb), which the pre-ISSUE-2 code
            # dropped from both buckets.  The ``<= 1`` covers diff
            # dispatches, whose skip-aware counter may not charge the
            # screen block itself (zero-mass prefix).
            dead = ~alive
            stats.screened_out += int((dead & (blocks <= 1)).sum())
            stats.kernel_aborts += int((dead & (blocks > 1)).sum())
        return cnt, alive


def mine_bitmap(db: Sequence[Sequence[Hashable]], minsup: int,
                scheme: str = "eclat", early_stop: bool = True,
                **kw) -> Tuple[ItemsetSupports, DeviceMiningStats]:
    """Convenience front-end mirroring ``oracle.mine``."""
    return BitmapMiner(scheme=scheme, early_stop=early_stop, **kw).mine(
        db, minsup)
