"""Device bitmap miners: Eclat and dEclat with block-level early stopping.

Host/DFS split (DESIGN.md §2): the equivalence-class depth-first search
stays on the host (Python), but the host only ever handles row *indices*
and small int vectors — every bitmap row lives in a device-resident
``DeviceRowStore`` slab (core/rowstore.py) from the moment the level-1
TID bitmaps are uploaded until the slot is free-listed.  Candidate
evaluation is batched at the *class* level: every sibling pair (a, b),
a<b, of one equivalence class goes to the device in chunked calls, and
each chunk is exactly **one** device dispatch
(``kernels.ops.screen_and_intersect``):

  * gather: operand rows + suffix tables are picked out of the slab by
    index (no host U/V materialisation, no re-upload);
  * screen: the kernel evaluates the one-block bound first — a pair whose
    block-0 bound misses minsup dies with ``blocks_done == 1`` and costs
    no further blocks;
  * blocked ES: surviving pairs walk TID blocks and abort the moment the
    suffix bound drops below minsup (the paper's INTERSECT_ES /
    DIFFERENCE_ES quantised to blocks);
  * scatter: child rows *and* their suffix-popcount tables are computed
    on device and written into preallocated slots of the same slab.

Slots are allocated pessimistically (one per candidate pair) before the
dispatch and the dead ones are returned to the free list right after —
free-list traffic is pure host bookkeeping, so infrequent candidates
still cost zero extra device work.

Work metric: ``word_ops`` — uint32 word operations actually performed
(blocks_done x block_words per pair; the fused screen is block 0 of the
same scan).  This is the device analogue of the paper's #comparisons and
is what benchmarks/bench_paper.py reports next to the oracle's exact
counter.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, FrozenSet, Hashable, List, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.bitmap import BitmapDB, DEFAULT_BLOCK_WORDS, bucket_pad
from repro.core.rowstore import DeviceRowStore
from repro.kernels import ops

ItemsetSupports = Dict[FrozenSet[Hashable], int]

_PAIR_BUCKETS = (64, 256, 1024, 4096, 16384, 65536, 262144)


@dataclass
class DeviceMiningStats:
    """Work accounting for the bitmap engine (device analogue of
    ``oracle.MiningStats``)."""

    candidates: int = 0
    nodes: int = 0
    screened_out: int = 0        # pairs killed by the one-block screen
    kernel_aborts: int = 0       # pairs killed past block 0
    word_ops: int = 0            # uint32 ops actually performed
    word_ops_full: int = 0       # what a non-ES engine would have performed
    device_calls: int = 0
    store_grows: int = 0         # row-store slab reallocations
    peak_rows: int = 0           # peak live rows in the store
    runtime_s: float = 0.0

    @property
    def ratio(self) -> float:
        return self.candidates / max(self.nodes, 1)

    @property
    def word_ops_saved_frac(self) -> float:
        if self.word_ops_full == 0:
            return 0.0
        return 1.0 - self.word_ops / self.word_ops_full

    def as_dict(self) -> Dict[str, float]:
        return {
            "candidates": self.candidates,
            "nodes": self.nodes,
            "ratio": round(self.ratio, 4),
            "screened_out": self.screened_out,
            "kernel_aborts": self.kernel_aborts,
            "word_ops": self.word_ops,
            "word_ops_full": self.word_ops_full,
            "word_ops_saved_frac": round(self.word_ops_saved_frac, 4),
            "device_calls": self.device_calls,
            "store_grows": self.store_grows,
            "peak_rows": self.peak_rows,
            "runtime_s": round(self.runtime_s, 6),
        }


def _bucket_pad(arr: np.ndarray, n: int, fill=0) -> np.ndarray:
    return bucket_pad(arr, n, _PAIR_BUCKETS, fill)


@dataclass
class _Class:
    """One equivalence class: members share a prefix (Eclat) and are kept
    in search order.  ``row_ids`` are slots in the device row store
    holding TID bitmaps (Eclat, dEclat level 1) or diffsets (dEclat
    level >= 2) — contents never leave the device."""

    itemsets: List[Tuple[Hashable, ...]]
    row_ids: np.ndarray       # int32 (m,) store slots
    supports: np.ndarray      # int32 (m,)
    is_tidlist: bool


class BitmapMiner:
    """Eclat / dEclat over a device-resident row store with fused
    screen+intersect early stopping."""

    def __init__(self, scheme: str = "eclat", early_stop: bool = True,
                 block_words: int = DEFAULT_BLOCK_WORDS,
                 pair_chunk: int = 65536, backend: str = "auto",
                 metrics: bool = True):
        if scheme not in ("eclat", "declat"):
            raise ValueError(f"bad scheme {scheme!r}")
        self.scheme = scheme
        self.early_stop = early_stop
        self.block_words = block_words
        self.pair_chunk = min(pair_chunk, _PAIR_BUCKETS[-1])
        self.backend = backend
        # The fused dispatch returns exact blocks_done/word_ops for free;
        # ``metrics`` is kept for API compatibility and no longer selects
        # a separate (two-dispatch) fast path.
        self.metrics = metrics

    def mine(self, db: Sequence[Sequence[Hashable]], minsup: int,
             ) -> Tuple[ItemsetSupports, DeviceMiningStats]:
        if minsup < 1:
            raise ValueError("minsup must be an absolute count >= 1")
        stats = DeviceMiningStats()
        t0 = time.perf_counter()

        bdb = BitmapDB.from_db(db, minsup, self.block_words)
        out: ItemsetSupports = {}
        for r, item in enumerate(bdb.items):
            out[frozenset((item,))] = int(bdb.supports[r])
            stats.nodes += 1

        store = self._make_store(bdb)
        root = _Class(
            itemsets=[(it,) for it in bdb.items],
            row_ids=np.arange(bdb.n_items, dtype=np.int32),
            supports=bdb.supports.astype(np.int32),
            is_tidlist=True)
        self._minsup = minsup
        self._n_blocks = store.n_blocks   # padded under a sharded store
        self._traverse(store, root, out, stats)
        stats.store_grows = store.grows
        stats.peak_rows = store.peak_live
        stats.runtime_s = time.perf_counter() - t0
        return out, stats

    def _make_store(self, bdb: BitmapDB) -> DeviceRowStore:
        """Allocate the device slab.  Subclasses (the distributed miner)
        override this to place it under a sharded layout."""
        return DeviceRowStore(
            bdb.bitmaps,
            capacity=bdb.n_items + min(self.pair_chunk, 4096))

    # -- frontier-batched expansion -----------------------------------------
    #
    # A work stack of pending classes is drained in groups: pairs from as
    # many classes as fit in one ``pair_chunk`` are concatenated into a
    # single device call.  This keeps batches large even deep in the DFS
    # where individual classes are tiny — on a real TPU this is what
    # amortises launch latency; on CPU it is the difference between
    # dispatch-bound and compute-bound mining.  Result sets are order-
    # independent, so draining order does not affect correctness.
    #
    # Row lifetime: a class's member rows are operands only for that
    # class's own pair batch, so they are free-listed as soon as the drain
    # group that consumed them completes; child slots live until the child
    # class is drained in turn.

    def _traverse(self, store: DeviceRowStore, root: _Class,
                  out: ItemsetSupports, stats: DeviceMiningStats) -> None:
        stack: List[_Class] = [root]
        while stack:
            # -- drain classes until one pair_chunk is filled --------------
            drained: List[_Class] = []
            total = 0
            while stack and total < self.pair_chunk:
                klass = stack.pop()
                m = len(klass.itemsets)
                if m < 2:
                    store.free(klass.row_ids)      # leaf: rows are done
                    continue
                drained.append(klass)
                total += m * (m - 1) // 2
            if not drained:
                continue

            # -- merge all pairs into global slot-index arrays --------------
            ua_l, vb_l, rho_l, meta = [], [], [], []
            for ci, klass in enumerate(drained):
                m = len(klass.itemsets)
                ia, ib = np.triu_indices(m, 1)
                # Operand orientation (paper Alg. 1/2):
                #   eclat:             Z = T(Px) & T(Py)
                #   declat level 2:    D(xy)  = T(x)  & ~T(y)  (U=x,  V=y)
                #   declat level >=3:  D(Pxy) = D(Py) & ~D(Px) (U=Py, V=Px)
                if self.scheme == "eclat" or klass.is_tidlist:
                    ua, vb = ia, ib
                else:
                    ua, vb = ib, ia
                ua_l.append(klass.row_ids[ua])
                vb_l.append(klass.row_ids[vb])
                rho_l.append(klass.supports[ia])
                meta.extend((ci, int(a), int(b)) for a, b in zip(ia, ib))
            ua_g = np.concatenate(ua_l).astype(np.int32)
            vb_g = np.concatenate(vb_l).astype(np.int32)
            rho_g = np.concatenate(rho_l).astype(np.int32)

            # -- chunked device evaluation: ONE dispatch per chunk ---------
            pend: List[Tuple[int, int, int, int, Tuple]] = []
            groups: Dict[Tuple[int, int], List[int]] = {}
            for lo in range(0, ua_g.size, self.pair_chunk):
                sl = slice(lo, lo + self.pair_chunk)
                slots_f, sup_f, kept = self._eval_pairs(
                    store, ua_g[sl], vb_g[sl], rho_g[sl], stats)
                for slot, s, ki in zip(slots_f, sup_f, kept):
                    ci, a, b = meta[lo + ki]
                    klass = drained[ci]
                    cs = klass.itemsets[a] + (klass.itemsets[b][-1],)
                    out[frozenset(cs)] = s
                    stats.nodes += 1
                    groups.setdefault((ci, a), []).append(len(pend))
                    pend.append((ci, a, slot, s, cs))

            # -- form child classes and push --------------------------------
            for _key, idxs in groups.items():
                stack.append(_Class(
                    itemsets=[pend[i][4] for i in idxs],
                    row_ids=np.asarray([pend[i][2] for i in idxs], np.int32),
                    supports=np.asarray([pend[i][3] for i in idxs],
                                        np.int32),
                    is_tidlist=False))

            # -- parent rows are spent operands: recycle their slots --------
            for klass in drained:
                store.free(klass.row_ids)

    def _eval_pairs(self, store: DeviceRowStore, ua: np.ndarray,
                    vb: np.ndarray, rho: np.ndarray,
                    stats: DeviceMiningStats,
                    ) -> Tuple[np.ndarray, List[int], List[int]]:
        """Evaluate one pair chunk in a single fused device dispatch.

        Returns (slots, supports, kept): store slots and supports of the
        frequent children, plus their chunk-local pair indices."""
        n = int(ua.size)
        stats.candidates += n
        stats.word_ops_full += n * self._n_blocks * self.block_words
        mode = "and" if self.scheme == "eclat" else "andnot"

        slots = store.alloc(n)
        cnt, alive = self._dispatch(store, ua, vb, slots, rho, mode, stats)

        support = cnt if self.scheme == "eclat" else rho - cnt
        # Dead pairs carry frozen (partial) counts; in "andnot" mode a frozen
        # count *overestimates* the support, so aliveness is load-bearing.
        freq = support >= self._minsup
        if self.early_stop:
            freq = np.logical_and(freq, alive)

        kept_idx = np.nonzero(freq)[0]
        store.free(slots[~freq])                  # dead children: recycle
        return (slots[kept_idx],
                [int(s) for s in support[kept_idx]],
                [int(i) for i in kept_idx])

    def _dispatch(self, store: DeviceRowStore, ua: np.ndarray,
                  vb: np.ndarray, slots: np.ndarray, rho: np.ndarray,
                  mode: str, stats: DeviceMiningStats,
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """One fused device dispatch; updates work/attribution stats.

        Returns ``(cnt, alive)`` trimmed to the chunk length, where
        ``cnt`` is the raw kernel count (support for "and", diffset size
        for "andnot") and ``alive`` marks pairs that survived ES.  The
        distributed miner overrides this with the shard_map dispatch."""
        n = int(ua.size)
        kernel_minsup = self._minsup if self.early_stop else 0
        cap = store.capacity
        store.rows, store.suffix, cnt, blocks, alive = \
            ops.screen_and_intersect(
                store.rows, store.suffix,
                _bucket_pad(ua, n), _bucket_pad(vb, n),
                _bucket_pad(slots, n, fill=cap),   # OOB pad -> dropped
                _bucket_pad(rho, n), jnp.int32(kernel_minsup),
                mode=mode, backend=self.backend)
        stats.device_calls += 1
        cnt = np.asarray(cnt[:n])
        blocks = np.asarray(blocks[:n])
        alive = np.asarray(alive[:n])
        stats.word_ops += int(blocks.sum()) * self.block_words
        if self.early_stop:
            # Attribution: a dead pair that did exactly one block was
            # killed by the fused one-block screen — including on
            # single-block datasets (nb == 1) and pairs that died on the
            # final block (blocks == nb), which the pre-ISSUE-2 code
            # dropped from both buckets.
            dead = ~alive
            stats.screened_out += int((dead & (blocks == 1)).sum())
            stats.kernel_aborts += int((dead & (blocks > 1)).sum())
        return cnt, alive


def mine_bitmap(db: Sequence[Sequence[Hashable]], minsup: int,
                scheme: str = "eclat", early_stop: bool = True,
                **kw) -> Tuple[ItemsetSupports, DeviceMiningStats]:
    """Convenience front-end mirroring ``oracle.mine``."""
    return BitmapMiner(scheme=scheme, early_stop=early_stop, **kw).mine(
        db, minsup)
