"""Device bitmap miners: Eclat and dEclat with block-level early stopping.

Host/DFS split (DESIGN.md §2): the equivalence-class depth-first search
stays on the host (Python), but candidate evaluation is batched at the
*class* level — every sibling pair (a, b), a<b, of one equivalence class
goes to the device in a handful of chunked calls.  Early stopping appears
at two levels:

  * inter-call screening: a one-block bound kills most infrequent pairs
    before the full intersection is materialised (pairs are compacted on
    the host, so screened-out pairs cost zero further device work);
  * intra-call blocking: the kernel walks TID blocks and aborts a pair the
    moment its suffix bound drops below minsup.

The two together are the batched TPU translation of the paper's
INTERSECT_ES / DIFFERENCE_ES.

Work metric: ``word_ops`` — uint32 word operations actually performed
(blocks_done x block_words per pair; one block per pair for the screen).
This is the device analogue of the paper's #comparisons and is what
benchmarks/bench_comparisons.py reports next to the oracle's exact
counter.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, FrozenSet, Hashable, List, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.bitmap import (BitmapDB, DEFAULT_BLOCK_WORDS,
                               suffix_popcounts_np)
from repro.kernels import ops

ItemsetSupports = Dict[FrozenSet[Hashable], int]

_PAIR_BUCKETS = (64, 256, 1024, 4096, 16384, 65536, 262144)


@dataclass
class DeviceMiningStats:
    """Work accounting for the bitmap engine (device analogue of
    ``oracle.MiningStats``)."""

    candidates: int = 0
    nodes: int = 0
    screened_out: int = 0        # pairs killed by the one-block screen
    kernel_aborts: int = 0       # pairs killed inside the blocked kernel
    word_ops: int = 0            # uint32 ops actually performed
    word_ops_full: int = 0       # what a non-ES engine would have performed
    device_calls: int = 0
    runtime_s: float = 0.0

    @property
    def ratio(self) -> float:
        return self.candidates / max(self.nodes, 1)

    @property
    def word_ops_saved_frac(self) -> float:
        if self.word_ops_full == 0:
            return 0.0
        return 1.0 - self.word_ops / self.word_ops_full

    def as_dict(self) -> Dict[str, float]:
        return {
            "candidates": self.candidates,
            "nodes": self.nodes,
            "ratio": round(self.ratio, 4),
            "screened_out": self.screened_out,
            "kernel_aborts": self.kernel_aborts,
            "word_ops": self.word_ops,
            "word_ops_full": self.word_ops_full,
            "word_ops_saved_frac": round(self.word_ops_saved_frac, 4),
            "device_calls": self.device_calls,
            "runtime_s": round(self.runtime_s, 6),
        }


def _bucket_pad(arr: np.ndarray, n: int) -> np.ndarray:
    for b in _PAIR_BUCKETS:
        if n <= b:
            if n == b:
                return arr
            pad_shape = (b - n,) + arr.shape[1:]
            return np.concatenate([arr, np.zeros(pad_shape, arr.dtype)])
    raise ValueError(f"batch of {n} exceeds largest bucket")


@dataclass
class _Class:
    """One equivalence class: members share a prefix (Eclat) and are kept
    in search order.  Rows are TID bitmaps (Eclat, dEclat level 1) or
    diffsets (dEclat level >= 2)."""

    itemsets: List[Tuple[Hashable, ...]]
    rows: np.ndarray          # uint32 (m, n_blocks, bw)
    suffix: np.ndarray        # int32  (m, n_blocks + 1)
    supports: np.ndarray      # int32  (m,)
    is_tidlist: bool


class BitmapMiner:
    """Eclat / dEclat over packed bitmaps with two-level early stopping."""

    def __init__(self, scheme: str = "eclat", early_stop: bool = True,
                 block_words: int = DEFAULT_BLOCK_WORDS,
                 pair_chunk: int = 65536, backend: str = "auto",
                 metrics: bool = True):
        if scheme not in ("eclat", "declat"):
            raise ValueError(f"bad scheme {scheme!r}")
        self.scheme = scheme
        self.early_stop = early_stop
        self.block_words = block_words
        self.pair_chunk = min(pair_chunk, _PAIR_BUCKETS[-1])
        self.backend = backend
        # metrics=True runs the blocked ES kernel so blocks_done/word_ops are
        # exact; metrics=False takes the fused fast path (ES savings come
        # from the screen alone — the production CPU configuration).
        self.metrics = metrics

    def mine(self, db: Sequence[Sequence[Hashable]], minsup: int,
             ) -> Tuple[ItemsetSupports, DeviceMiningStats]:
        if minsup < 1:
            raise ValueError("minsup must be an absolute count >= 1")
        stats = DeviceMiningStats()
        t0 = time.perf_counter()

        bdb = BitmapDB.from_db(db, minsup, self.block_words)
        out: ItemsetSupports = {}
        for r, item in enumerate(bdb.items):
            out[frozenset((item,))] = int(bdb.supports[r])
            stats.nodes += 1

        root = _Class(
            itemsets=[(it,) for it in bdb.items],
            rows=bdb.bitmaps,
            suffix=suffix_popcounts_np(bdb.bitmaps),
            supports=bdb.supports.astype(np.int32),
            is_tidlist=True)
        self._minsup = minsup
        self._n_blocks = bdb.n_blocks
        self._traverse(root, out, stats)
        stats.runtime_s = time.perf_counter() - t0
        return out, stats

    # -- frontier-batched expansion -----------------------------------------
    #
    # A work stack of pending classes is drained in groups: pairs from as
    # many classes as fit in one ``pair_chunk`` are concatenated into a
    # single device call.  This keeps batches large even deep in the DFS
    # where individual classes are tiny — on a real TPU this is what
    # amortises launch latency; on CPU it is the difference between
    # dispatch-bound and compute-bound mining.  Result sets are order-
    # independent, so draining order does not affect correctness.

    def _traverse(self, root: _Class, out: ItemsetSupports,
                  stats: DeviceMiningStats) -> None:
        stack: List[_Class] = [root]
        while stack:
            # -- drain classes until one pair_chunk is filled --------------
            drained: List[_Class] = []
            total = 0
            while stack and total < self.pair_chunk:
                klass = stack.pop()
                m = len(klass.itemsets)
                if m < 2:
                    continue
                drained.append(klass)
                total += m * (m - 1) // 2
            if not drained:
                continue

            # -- merge all pairs into global index arrays -------------------
            offs = np.cumsum([0] + [len(k.itemsets) for k in drained])
            rows_cat = np.concatenate([k.rows for k in drained])
            suf_cat = np.concatenate([k.suffix for k in drained])
            sup_cat = np.concatenate([k.supports for k in drained])
            ua_l, vb_l, rho_l, meta = [], [], [], []
            for ci, klass in enumerate(drained):
                m = len(klass.itemsets)
                ia, ib = np.triu_indices(m, 1)
                # Operand orientation (paper Alg. 1/2):
                #   eclat:             Z = T(Px) & T(Py)
                #   declat level 2:    D(xy)  = T(x)  & ~T(y)  (U=x,  V=y)
                #   declat level >=3:  D(Pxy) = D(Py) & ~D(Px) (U=Py, V=Px)
                if self.scheme == "eclat" or klass.is_tidlist:
                    ua, vb = ia, ib
                else:
                    ua, vb = ib, ia
                ua_l.append(ua + offs[ci])
                vb_l.append(vb + offs[ci])
                rho_l.append(klass.supports[ia])
                meta.extend((ci, int(a), int(b)) for a, b in zip(ia, ib))
            ua_g = np.concatenate(ua_l)
            vb_g = np.concatenate(vb_l)
            rho_g = np.concatenate(rho_l).astype(np.int32)

            # -- chunked device evaluation ---------------------------------
            pend: List[Tuple[int, int, np.ndarray, int, Tuple]] = []
            groups: Dict[Tuple[int, int], List[int]] = {}
            for lo in range(0, ua_g.size, self.pair_chunk):
                sl = slice(lo, lo + self.pair_chunk)
                rows_f, sup_f, kept = self._eval_pairs(
                    rows_cat, suf_cat, ua_g[sl], vb_g[sl], rho_g[sl], stats)
                for r, s, ki in zip(rows_f, sup_f, kept):
                    ci, a, b = meta[lo + ki]
                    klass = drained[ci]
                    cs = klass.itemsets[a] + (klass.itemsets[b][-1],)
                    out[frozenset(cs)] = s
                    stats.nodes += 1
                    groups.setdefault((ci, a), []).append(len(pend))
                    pend.append((ci, a, r, s, cs))
            del rows_cat, suf_cat, sup_cat

            # -- form child classes and push --------------------------------
            for _key, idxs in groups.items():
                rows = np.stack([pend[i][2] for i in idxs])
                stack.append(_Class(
                    itemsets=[pend[i][4] for i in idxs],
                    rows=rows,
                    suffix=suffix_popcounts_np(rows),
                    supports=np.asarray([pend[i][3] for i in idxs],
                                        np.int32),
                    is_tidlist=False))

    def _eval_pairs(self, rows_cat: np.ndarray, suf_cat: np.ndarray,
                    ua: np.ndarray, vb: np.ndarray, rho: np.ndarray,
                    stats: DeviceMiningStats,
                    ) -> Tuple[List[np.ndarray], List[int], List[int]]:
        n = ua.size
        stats.candidates += n
        nb, bw = self._n_blocks, self.block_words
        stats.word_ops_full += n * nb * bw

        U = rows_cat[ua]
        V = rows_cat[vb]
        suf_u = suf_cat[ua]
        suf_v = suf_cat[vb]
        mode = "and" if self.scheme == "eclat" else "andnot"

        keep = np.arange(n)
        if self.early_stop and nb > 1:
            _, alive = ops.screen_pairs(
                jnp.asarray(U[:, 0]), jnp.asarray(V[:, 0]),
                jnp.asarray(suf_u[:, 1]), jnp.asarray(suf_v[:, 1]),
                jnp.asarray(rho), jnp.int32(self._minsup), mode=mode)
            stats.device_calls += 1
            stats.word_ops += n * bw
            alive = np.asarray(alive)
            stats.screened_out += int((~alive).sum())
            keep = np.nonzero(alive)[0]
            if keep.size == 0:
                return [], [], []
            U, V, suf_u, suf_v, rho = (U[keep], V[keep], suf_u[keep],
                                       suf_v[keep], rho[keep])
        k = keep.size

        if self.metrics:
            kernel_minsup = self._minsup if self.early_stop else 0
            Z, cnt, blocks, alive = ops.bitmap_intersect_es(
                jnp.asarray(_bucket_pad(np.ascontiguousarray(U), k)),
                jnp.asarray(_bucket_pad(np.ascontiguousarray(V), k)),
                jnp.asarray(_bucket_pad(np.ascontiguousarray(suf_u), k)),
                jnp.asarray(_bucket_pad(np.ascontiguousarray(suf_v), k)),
                jnp.asarray(_bucket_pad(rho, k)),
                jnp.int32(kernel_minsup), mode=mode, backend=self.backend)
            stats.device_calls += 1
            Z = np.asarray(Z[:k])
            cnt = np.asarray(cnt[:k])
            blocks = np.asarray(blocks[:k])
            alive = np.asarray(alive[:k])
            stats.word_ops += int(blocks.sum()) * bw
            stats.kernel_aborts += int((blocks < nb).sum())
        else:
            Z, cnt = ops.bitmap_intersect_full(
                jnp.asarray(_bucket_pad(np.ascontiguousarray(U), k)),
                jnp.asarray(_bucket_pad(np.ascontiguousarray(V), k)),
                mode=mode, backend=self.backend)
            stats.device_calls += 1
            Z = np.asarray(Z[:k])
            cnt = np.asarray(cnt[:k])
            alive = np.ones((k,), bool)
            stats.word_ops += k * nb * bw

        support = cnt if self.scheme == "eclat" else rho - cnt
        # Dead pairs carry frozen (partial) counts; in "andnot" mode a frozen
        # count *overestimates* the support, so aliveness is load-bearing.
        freq = support >= self._minsup
        if self.early_stop and self.metrics:
            freq = np.logical_and(freq, alive)

        rows_f: List[np.ndarray] = []
        sup_f: List[int] = []
        kept: List[int] = []
        for bi in np.nonzero(freq)[0]:
            rows_f.append(Z[bi])
            sup_f.append(int(support[bi]))
            kept.append(int(keep[bi]))   # local index within this chunk
        return rows_f, sup_f, kept


def mine_bitmap(db: Sequence[Sequence[Hashable]], minsup: int,
                scheme: str = "eclat", early_stop: bool = True,
                **kw) -> Tuple[ItemsetSupports, DeviceMiningStats]:
    """Convenience front-end mirroring ``oracle.mine``."""
    return BitmapMiner(scheme=scheme, early_stop=early_stop, **kw).mine(
        db, minsup)
