"""Device bitmap miners: Eclat and dEclat with block-level early stopping.

Host/DFS split (DESIGN.md §2): the equivalence-class depth-first search
stays on the host (Python), but the host only ever handles row *indices*
and small int vectors — every bitmap row lives in a device-resident
``DeviceRowStore`` slab (core/rowstore.py) from the moment the level-1
TID bitmaps are uploaded until the slot is free-listed.  Candidate
evaluation is batched at the *class* level: every sibling pair (a, b),
a<b, of one equivalence class goes to the device in chunked calls, and
each chunk is exactly **one** device dispatch
(``kernels.ops.screen_and_intersect``):

  * gather: operand rows + suffix tables are picked out of the slab by
    index (no host U/V materialisation, no re-upload);
  * screen: the kernel evaluates the one-block bound first — a pair whose
    block-0 bound misses minsup dies with ``blocks_done == 1`` and costs
    no further blocks;
  * blocked ES: surviving pairs walk TID blocks and abort the moment the
    suffix bound drops below minsup (the paper's INTERSECT_ES /
    DIFFERENCE_ES quantised to blocks);
  * scatter: child rows *and* their suffix-popcount tables are computed
    on device and written into preallocated slots of the same slab —
    **survivor-only** (ISSUE 5): the count phase completes before the
    scatter phase and gates it, so dead candidates cost zero scatter
    words (``stats.child_scatters`` counts frequent children exactly).

Slots are still *reserved* pessimistically (one per candidate pair —
the scatter destinations must exist before the dispatch) and the dead
ones are returned to the free list right after, but nothing was ever
written to them: free-list traffic is pure host bookkeeping, so
infrequent candidates cost zero extra device work.  When occupancy
drops far enough the scheduler compacts the slab at a drain-group
boundary (``DeviceRowStore.compact_if_sparse``) and remaps the
frontier's slot handles through the returned mapping.

Work metric: ``word_ops`` — uint32 word operations actually performed
(blocks_done x block_words per pair; the fused screen is block 0 of the
same scan).  This is the device analogue of the paper's #comparisons and
is what benchmarks/bench_paper.py reports next to the oracle's exact
counter.

The traversal policy (work stack, cross-class drain-group batching,
chunk slicing, operand free-listing, compaction scheduling) lives in
``core.frontier.FrontierScheduler`` — this module only implements the
scheduler's client protocol on top of the fused bitmap dispatch.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, FrozenSet, Hashable, List, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.bitmap import (BitmapDB, DEFAULT_BLOCK_WORDS,
                               PAIR_CHUNK_BUCKETS, bucket_pad)
from repro.core.frontier import (Child, ClassNode, EngineAccounting,
                                 FrontierScheduler)
from repro.core.rowstore import DeviceRowStore
from repro.kernels import ops

ItemsetSupports = Dict[FrozenSet[Hashable], int]

# Canonical table lives in core.bitmap next to bucket_pad (ISSUE 5
# consolidation) so the pair-chunk clamp and the pad logic cannot drift.
_PAIR_BUCKETS = PAIR_CHUNK_BUCKETS


@dataclass
class DeviceMiningStats(EngineAccounting):
    """Work accounting for the bitmap engine (device analogue of
    ``oracle.MiningStats``; the shared device/allocator counters come
    from ``frontier.EngineAccounting``)."""

    screened_out: int = 0        # pairs killed by the one-block screen
    kernel_aborts: int = 0       # pairs killed past block 0
    word_ops: int = 0            # uint32 ops actually performed
    word_ops_full: int = 0       # what a non-ES engine would have performed

    # Legacy names kept as read-only views of the shared accounting.
    @property
    def store_grows(self) -> int:
        return self.grows

    @property
    def peak_rows(self) -> int:
        return self.peak_live

    @property
    def deaths(self) -> int:
        return self.screened_out + self.kernel_aborts

    @property
    def ratio(self) -> float:
        return self.candidates / max(self.nodes, 1)

    @property
    def word_ops_saved_frac(self) -> float:
        if self.word_ops_full == 0:
            return 0.0
        return 1.0 - self.word_ops / self.word_ops_full

    def as_dict(self) -> Dict[str, float]:
        return {
            "candidates": self.candidates,
            "nodes": self.nodes,
            "ratio": round(self.ratio, 4),
            "screened_out": self.screened_out,
            "kernel_aborts": self.kernel_aborts,
            "word_ops": self.word_ops,
            "word_ops_full": self.word_ops_full,
            "word_ops_saved_frac": round(self.word_ops_saved_frac, 4),
            "store_grows": self.store_grows,
            "peak_rows": self.peak_rows,
            "runtime_s": round(self.runtime_s, 6),
            **self.accounting_dict(),
        }


def _bucket_pad(arr: np.ndarray, n: int, fill=0) -> np.ndarray:
    return bucket_pad(arr, n, _PAIR_BUCKETS, fill)


class BitmapMiner:
    """Eclat / dEclat over a device-resident row store with fused
    screen+intersect early stopping.

    The DFS itself is ``core.frontier.FrontierScheduler`` — this class is
    its client: it turns one class's sibling-pair triangle into store
    slot columns, evaluates a pair-chunk slice as ONE fused device
    dispatch, and recycles spent slots.  ``compact_occupancy`` is the
    allocator memory-tuning knob: when live rows fall below that
    fraction of the slab (and the slab would at least halve), the
    scheduler compacts it between drain groups; 0 disables compaction.
    """

    def __init__(self, scheme: str = "eclat", early_stop: bool = True,
                 block_words: int = DEFAULT_BLOCK_WORDS,
                 pair_chunk: int = 65536, backend: str = "auto",
                 metrics: bool = True, compact_occupancy: float = 0.25):
        if scheme not in ("eclat", "declat"):
            raise ValueError(f"bad scheme {scheme!r}")
        self.scheme = scheme
        self.early_stop = early_stop
        self.block_words = block_words
        self.pair_chunk = min(pair_chunk, _PAIR_BUCKETS[-1])
        self.backend = backend
        self.compact_occupancy = compact_occupancy
        # The fused dispatch returns exact blocks_done/word_ops for free;
        # ``metrics`` is kept for API compatibility and no longer selects
        # a separate (two-dispatch) fast path.
        self.metrics = metrics

    def mine(self, db: Sequence[Sequence[Hashable]], minsup: int,
             ) -> Tuple[ItemsetSupports, DeviceMiningStats]:
        if minsup < 1:
            raise ValueError("minsup must be an absolute count >= 1")
        stats = DeviceMiningStats()
        t0 = time.perf_counter()

        bdb = BitmapDB.from_db(db, minsup, self.block_words)
        out: ItemsetSupports = {}
        for r, item in enumerate(bdb.items):
            out[frozenset((item,))] = int(bdb.supports[r])
            stats.nodes += 1

        store = self._make_store(bdb)
        root = ClassNode(
            itemsets=[(it,) for it in bdb.items],
            rows=np.arange(bdb.n_items, dtype=np.int32),
            supports=bdb.supports.astype(np.int32),
            payload=True)                  # payload: is_tidlist
        self._minsup = minsup
        # Work metrics use the REAL block count: a sharded store pads
        # its block axis up to the shard count, and charging those
        # all-zero pad blocks to ``word_ops_full`` inflated every
        # DistributedMiner run's saved-fraction (ISSUE 5 bugfix).
        self._n_blocks = bdb.n_blocks
        self._store = store
        self._out = out
        self._stats = stats
        FrontierScheduler(self, self.pair_chunk).run(root)
        stats.note_allocator(store)
        stats.runtime_s = time.perf_counter() - t0
        return out, stats

    def _make_store(self, bdb: BitmapDB) -> DeviceRowStore:
        """Allocate the device slab.  Subclasses (the distributed miner)
        override this to place it under a sharded layout."""
        return DeviceRowStore(
            bdb.bitmaps,
            capacity=bdb.n_items + min(self.pair_chunk, 4096))

    # -- FrontierScheduler client protocol ----------------------------------

    def pair_columns(self, klass: ClassNode, ia: np.ndarray,
                     ib: np.ndarray) -> Dict[str, np.ndarray]:
        # Operand orientation (paper Alg. 1/2):
        #   eclat:             Z = T(Px) & T(Py)
        #   declat level 2:    D(xy)  = T(x)  & ~T(y)  (U=x,  V=y)
        #   declat level >=3:  D(Pxy) = D(Py) & ~D(Px) (U=Py, V=Px)
        if self.scheme == "eclat" or klass.payload:
            ua, vb = ia, ib
        else:
            ua, vb = ib, ia
        return {"ua": klass.rows[ua].astype(np.int32),
                "vb": klass.rows[vb].astype(np.int32),
                "rho": klass.supports[ia].astype(np.int32)}

    def evaluate_pairs(self, cols: Dict[str, np.ndarray],
                       ) -> List[Tuple[int, int, int, Any]]:
        """One pair-chunk slice -> ONE fused device dispatch.

        Returns the frequent children as ``(ki, slot, support, None)``
        tuples (``ki`` = chunk-local pair index)."""
        store, stats = self._store, self._stats
        ua, vb, rho = cols["ua"], cols["vb"], cols["rho"]
        n = int(ua.size)
        stats.candidates += n
        stats.word_ops_full += n * self._n_blocks * self.block_words
        mode = "and" if self.scheme == "eclat" else "andnot"

        slots = store.alloc(n)
        cnt, alive = self._dispatch(store, ua, vb, slots, rho, mode, stats)

        support = cnt if self.scheme == "eclat" else rho - cnt
        # Dead pairs carry frozen (partial) counts; in "andnot" mode a frozen
        # count *overestimates* the support, so aliveness is load-bearing.
        # This mask is exactly the dispatch's in-kernel scatter gate
        # (ref._survivor_mask): only these children were materialised.
        freq = np.logical_and(support >= self._minsup, alive)

        kept_idx = np.nonzero(freq)[0]
        stats.child_scatters += int(kept_idx.size)
        # Real (unpadded) blocks, like word_ops/word_ops_full: the
        # telemetry stays shard-count invariant even though a sharded
        # store physically pads each child row's block axis with zeros.
        stats.scatter_words += (int(kept_idx.size) * self._n_blocks
                                * self.block_words)
        store.free(slots[~freq])                  # dead children: recycle
        return [(int(ki), int(slots[ki]), int(support[ki]), None)
                for ki in kept_idx]

    def make_class(self, parent: ClassNode,
                   children: List[Child]) -> ClassNode:
        del parent
        return ClassNode(
            itemsets=[c.itemset for c in children],
            rows=np.asarray([c.row for c in children], np.int32),
            supports=np.asarray([c.support for c in children], np.int32),
            payload=False)                 # children are never tidlists

    def emit(self, itemset: Tuple[Hashable, ...], support: int) -> None:
        self._out[frozenset(itemset)] = support
        self._stats.nodes += 1

    def release(self, klass: ClassNode) -> None:
        self._store.free(klass.rows)

    def maybe_compact(self, reserve: int) -> "np.ndarray | None":
        """Drain-group boundary hook: compact the slab when occupancy
        warrants it.  Returns the slot mapping for the scheduler to
        remap every live frontier handle (or None)."""
        return self._store.compact_if_sparse(
            self.compact_occupancy, reserve=reserve, backend=self.backend)

    def _dispatch(self, store: DeviceRowStore, ua: np.ndarray,
                  vb: np.ndarray, slots: np.ndarray, rho: np.ndarray,
                  mode: str, stats: DeviceMiningStats,
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """One fused device dispatch; updates work/attribution stats.

        Returns ``(cnt, alive)`` trimmed to the chunk length, where
        ``cnt`` is the raw kernel count (support for "and", diffset size
        for "andnot") and ``alive`` marks pairs that survived ES.  The
        distributed miner overrides this with the shard_map dispatch."""
        n = int(ua.size)
        cap = store.capacity
        # minsup is always the real threshold: the dispatch's
        # survivor-only scatter gate needs it even with ES disabled
        # (the ``early_stop`` flag alone controls the in-scan abort).
        store.rows, store.suffix, cnt, blocks, alive = \
            ops.screen_and_intersect(
                store.rows, store.suffix,
                _bucket_pad(ua, n), _bucket_pad(vb, n),
                _bucket_pad(slots, n, fill=cap),   # OOB pad -> dropped
                _bucket_pad(rho, n), jnp.int32(self._minsup),
                mode=mode, early_stop=self.early_stop,
                backend=self.backend)
        stats.device_calls += 1
        cnt = np.asarray(cnt[:n])
        blocks = np.asarray(blocks[:n])
        alive = np.asarray(alive[:n])
        stats.word_ops += int(blocks.sum()) * self.block_words
        if self.early_stop:
            # Attribution: a dead pair that did exactly one block was
            # killed by the fused one-block screen — including on
            # single-block datasets (nb == 1) and pairs that died on the
            # final block (blocks == nb), which the pre-ISSUE-2 code
            # dropped from both buckets.
            dead = ~alive
            stats.screened_out += int((dead & (blocks == 1)).sum())
            stats.kernel_aborts += int((dead & (blocks > 1)).sum())
        return cnt, alive


def mine_bitmap(db: Sequence[Sequence[Hashable]], minsup: int,
                scheme: str = "eclat", early_stop: bool = True,
                **kw) -> Tuple[ItemsetSupports, DeviceMiningStats]:
    """Convenience front-end mirroring ``oracle.mine``."""
    return BitmapMiner(scheme=scheme, early_stop=early_stop, **kw).mine(
        db, minsup)
