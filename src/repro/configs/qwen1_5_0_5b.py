"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B; hf-verified].

24L, d_model=1024, 16 heads (kv=16 — plain MHA), d_ff=2816 SwiGLU,
vocab 151936, QKV bias, tied embeddings.
"""

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

_FULL = LMConfig(
    name="qwen1.5-0.5b",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=2816, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6, norm_eps=1e-6,
    attn_chunk=1024, dtype="bfloat16", remat="dots",
)

_SMOKE = LMConfig(
    name="qwen1.5-smoke",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=6, d_head=16,
    d_ff=256, vocab_size=512, qkv_bias=True, tie_embeddings=True,
    attn_chunk=64, dtype="float32", remat="none",
)

SPEC = ArchSpec(
    arch_id="qwen1.5-0.5b",
    family="lm",
    source="hf:Qwen/Qwen1.5-0.5B",
    config_fn=lambda shape_id=None: _FULL,
    smoke_config_fn=lambda: _SMOKE,
    shape_ids=tuple(LM_SHAPES),
    rules_override={},           # kv=16 divides model=16
    notes="QKV bias; long_500k skipped (full attention).",
)
