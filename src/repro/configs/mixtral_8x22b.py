"""mixtral-8x22b [arXiv:2401.04088; hf-verified].

56L, d_model=6144, 48 heads (GQA kv=8, head_dim=128), MoE 8 experts
top-2 with d_ff=16384, vocab 32768, sliding-window attention
(window 4096 per the assignment's SWA tag; the ring KV cache is what
makes the long_500k decode cell sub-quadratic).
"""

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

_FULL = LMConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384,
    vocab_size=32768, tie_embeddings=False,
    moe=True, n_experts=8, top_k=2, moe_d_ff=16384, n_shared_experts=0,
    first_k_dense=0, capacity_factor=1.25,
    sliding_window=4096,
    rope_theta=1e6,
    attn_chunk=1024, dtype="bfloat16", remat="full",
)

_SMOKE = LMConfig(
    name="mixtral-smoke",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_head=16,
    d_ff=256, vocab_size=512, tie_embeddings=False,
    moe=True, n_experts=4, top_k=2, moe_d_ff=256,
    sliding_window=32, attn_chunk=64, dtype="float32", remat="none",
)

SPEC = ArchSpec(
    arch_id="mixtral-8x22b",
    family="lm",
    source="arXiv:2401.04088 (Mixtral of Experts)",
    config_fn=lambda shape_id=None: _FULL,
    smoke_config_fn=lambda: _SMOKE,
    shape_ids=tuple(LM_SHAPES),
    # 8 experts < model=16: tensor-parallel INSIDE each expert instead of
    # expert parallelism (d_ff 16384 / 16 = 1024), kv heads replicated;
    # "embed" -> data adds the FSDP axis (280GB bf16 -> 1.1GB/chip).
    rules_override={"experts": None, "experts_act": None,
                    "expert_ff": "model", "kv_heads": None,
                    "embed": "data"},
    notes="SWA ring cache => long_500k runs with a 4096-slot cache.",
)
