"""granite-3-8b [hf:ibm-granite/granite-3.0-2b-base family; hf-verified].

40L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), d_ff=12800 SwiGLU,
vocab 49155 (padded to 49280 for TP), tied embeddings.
"""

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

_FULL = LMConfig(
    name="granite-3-8b",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12800, vocab_size=49155, qkv_bias=False, tie_embeddings=True,
    rope_theta=1e4,
    attn_chunk=1024, dtype="bfloat16", remat="dots",
)

_SMOKE = LMConfig(
    name="granite-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=384, vocab_size=515, qkv_bias=False, tie_embeddings=True,
    attn_chunk=64, dtype="float32", remat="none",
)

SPEC = ArchSpec(
    arch_id="granite-3-8b",
    family="lm",
    source="hf:ibm-granite/granite-3.0-2b-base (8b sibling dims)",
    config_fn=lambda shape_id=None: _FULL,
    smoke_config_fn=lambda: _SMOKE,
    shape_ids=tuple(LM_SHAPES),
    rules_override={"kv_heads": None},   # kv=8 < model=16
    notes="GQA; vocab 49155 padded to 49280; long_500k skipped.",
)
