"""two-tower-retrieval [Yi et al., RecSys'19 (YouTube); unverified tier].

embed_dim=256, tower MLP 1024-512-256, dot-product scoring, in-batch
sampled softmax with logQ correction.  5M users / 2M items.

This is the arch closest to the paper's technique: ``retrieval_cand``
is a 1M-candidate top-k scan, and the blocked screened scorer
(benchmarks/bench_retrieval.py) transfers the early-stopping upper-bound
idea to it (DESIGN.md §4).
"""

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import TwoTowerConfig

_FULL = TwoTowerConfig(
    name="two-tower-retrieval", n_users=5_000_000, n_items=2_000_000,
    n_user_hist=50, embed_dim=256, tower_mlp=(1024, 512, 256),
    temperature=0.05, dtype="float32",
)

_SMOKE = TwoTowerConfig(
    name="two-tower-smoke", n_users=1000, n_items=500, n_user_hist=10,
    embed_dim=32, tower_mlp=(64, 32), dtype="float32",
)

SPEC = ArchSpec(
    arch_id="two-tower-retrieval",
    family="recsys",
    source="Yi et al., RecSys'19 (sampled-softmax two-tower)",
    config_fn=lambda shape_id=None: _FULL,
    smoke_config_fn=lambda: _SMOKE,
    shape_ids=tuple(RECSYS_SHAPES),
    rules_override={},
    notes="ES-transfer hillclimb target (blocked screened retrieval).",
)
