"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-v01; unverified].

Dense decoder: 64L, d_model=12288, 96 heads (GQA kv=8, head_dim=128),
d_ff=33792 SwiGLU, vocab 256000, no biases, tied embeddings.
"""

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

_FULL = LMConfig(
    name="command-r-plus-104b",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_head=128,
    d_ff=33792, vocab_size=256000, qkv_bias=False, tie_embeddings=True,
    rope_theta=75_000_000.0,   # command-r family long-context base
    attn_chunk=1024, dtype="bfloat16", remat="full",
)

_SMOKE = LMConfig(
    name="command-r-plus-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=352, vocab_size=512, qkv_bias=False, tie_embeddings=True,
    attn_chunk=64, dtype="float32", remat="none",
)

SPEC = ArchSpec(
    arch_id="command-r-plus-104b",
    family="lm",
    source="hf:CohereForAI/c4ai-command-r-v01 (scaled; unverified tier)",
    config_fn=lambda shape_id=None: _FULL,
    smoke_config_fn=lambda: _SMOKE,
    shape_ids=tuple(LM_SHAPES),
    # kv=8 does not divide model=16: replicate kv projections, shard q
    # heads.  "embed" -> data gives 2D (FSDP x TP) weight sharding: 208GB
    # of bf16 weights land at 0.8GB/chip instead of 13GB/chip.
    rules_override={"kv_heads": None, "embed": "data"},
    notes="GQA no-bias; long_500k skipped (full attention).",
)
