"""din [arXiv:1706.06978; paper-verified].

embed_dim=18, seq_len=100, attention MLP 80-40, main MLP 200-80,
target-attention CTR ranker; item vocab at production scale (1M).
"""

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import DINConfig

_FULL = DINConfig(
    name="din", n_items=1_000_000, n_context=100_000, n_context_fields=4,
    embed_dim=18, seq_len=100, attn_mlp=(80, 40), mlp=(200, 80),
    dtype="float32",
)

_SMOKE = DINConfig(
    name="din-smoke", n_items=2000, n_context=100, n_context_fields=4,
    embed_dim=8, seq_len=20, attn_mlp=(16, 8), mlp=(32, 16),
    dtype="float32",
)

SPEC = ArchSpec(
    arch_id="din",
    family="recsys",
    source="arXiv:1706.06978 (Deep Interest Network)",
    config_fn=lambda shape_id=None: _FULL,
    smoke_config_fn=lambda: _SMOKE,
    shape_ids=tuple(RECSYS_SHAPES),
    rules_override={},
    notes=("retrieval_cand ranks 1M candidates through full target "
           "attention (B=1 user, candidate axis batched)."),
)
