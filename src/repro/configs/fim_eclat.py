"""The paper's own workload as a dry-run architecture: one distributed
Eclat mining round (screen + count, count-distribution over TID blocks).

Not one of the 40 assigned cells — an EXTRA pair of cells proving the
paper's technique itself lowers and shards on the production meshes.
"""

import dataclasses

from repro.configs.base import ArchSpec, FIM_SHAPES


@dataclasses.dataclass(frozen=True)
class FIMConfig:
    name: str = "fim-eclat"
    scheme: str = "eclat"
    early_stop: bool = True
    block_words: int = 128


SPEC = ArchSpec(
    arch_id="fim-eclat",
    family="fim",
    source="this paper (Nguyen 2019) + Zaki KDD'97 (Eclat)",
    config_fn=lambda shape_id=None: FIMConfig(),
    smoke_config_fn=lambda: FIMConfig(name="fim-smoke", block_words=2),
    shape_ids=tuple(FIM_SHAPES),
    rules_override={},
    notes="mine_1g: 1.07B transactions, 1TB bitmap store on one pod.",
)
