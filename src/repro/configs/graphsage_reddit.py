"""graphsage-reddit [arXiv:1706.02216; paper-verified].

2 layers, d_hidden=128, mean aggregator, sample sizes 25-10.  The model's
input/output dims follow the dataset of each shape cell (cora-like /
reddit / ogbn-products / molecules), as in the paper's per-dataset runs.
"""

from typing import Optional

from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import SAGEConfig


def _config(shape_id: Optional[str] = None) -> SAGEConfig:
    dims = GNN_SHAPES[shape_id or "minibatch_lg"].dims
    return SAGEConfig(
        name="graphsage-reddit",
        n_layers=2, d_hidden=128, aggregator="mean",
        fanouts=tuple(dims.get("fanouts", (25, 10))),
        d_feat=dims["d_feat"], n_classes=dims["n_classes"],
        dtype="float32",
    )


def _smoke() -> SAGEConfig:
    return SAGEConfig(name="graphsage-smoke", n_layers=2, d_hidden=16,
                      d_feat=24, n_classes=5, fanouts=(5, 3),
                      dtype="float32")


SPEC = ArchSpec(
    arch_id="graphsage-reddit",
    family="gnn",
    source="arXiv:1706.02216 (GraphSAGE)",
    config_fn=_config,
    smoke_config_fn=_smoke,
    shape_ids=tuple(GNN_SHAPES),
    rules_override={},
    notes=("Message passing via segment_sum (no CSR SpMM in JAX); "
           "minibatch_lg uses the real uniform fanout sampler."),
)
