"""xdeepfm [arXiv:1803.05170; paper-verified].

39 sparse fields, embed_dim=10, CIN 200-200-200, deep MLP 400-400.
Criteo-scale per-field vocab (100k -> 3.9M total rows).
"""

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import XDeepFMConfig

_FULL = XDeepFMConfig(
    name="xdeepfm", n_fields=39, vocab_per_field=100_000, embed_dim=10,
    cin_layers=(200, 200, 200), mlp=(400, 400), dtype="float32",
)

_SMOKE = XDeepFMConfig(
    name="xdeepfm-smoke", n_fields=8, vocab_per_field=200, embed_dim=6,
    cin_layers=(16, 16), mlp=(32, 16), dtype="float32",
)

SPEC = ArchSpec(
    arch_id="xdeepfm",
    family="recsys",
    source="arXiv:1803.05170 (xDeepFM)",
    config_fn=lambda shape_id=None: _FULL,
    smoke_config_fn=lambda: _SMOKE,
    shape_ids=tuple(RECSYS_SHAPES),
    rules_override={},
    notes="retrieval_cand = offline scoring of 1M candidate rows.",
)
