"""Config schema: architectures x input shapes -> dry-run cells.

Every assigned architecture contributes one ``ArchSpec``; its family
decides which shape set applies (LM / GNN / RecSys / FIM).  A *cell* is
one (arch, shape) pair — the unit the multi-pod dry-run, roofline table
and perf hillclimb all operate on.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    shape_id: str
    kind: str                 # train | prefill | decode | serve | retrieval
                              # | train_full | train_sampled | mine
    dims: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str               # lm | gnn | recsys | fim
    source: str               # public citation from the assignment
    # config_fn(shape_id) -> model config (GNN models vary d_feat by shape)
    config_fn: Callable[[Optional[str]], Any]
    smoke_config_fn: Callable[[], Any]
    shape_ids: Tuple[str, ...]
    rules_override: Dict[str, Any] = dataclasses.field(default_factory=dict)
    notes: str = ""

    def skip_reason(self, shape_id: str) -> Optional[str]:
        """Brief rule: long_500k needs sub-quadratic attention; pure
        full-attention archs skip it (documented in DESIGN.md §4)."""
        if self.family == "lm" and shape_id == "long_500k":
            cfg = self.config_fn(shape_id)
            if getattr(cfg, "sliding_window", 0) == 0:
                return ("full-attention arch: 500k-token decode requires "
                        "sub-quadratic attention (DESIGN.md §4)")
        return None


# ---------------------------------------------------------------------------
# Family shape sets (dims already padded to divide the 2x16x16 mesh; the
# unpadded source numbers are kept alongside for the record).
# ---------------------------------------------------------------------------

LM_SHAPES: Dict[str, ShapeDef] = {
    "train_4k": ShapeDef("train_4k", "train",
                         dict(seq=4096, global_batch=256, n_microbatches=8)),
    "prefill_32k": ShapeDef("prefill_32k", "prefill",
                            dict(seq=32768, batch=32)),
    "decode_32k": ShapeDef("decode_32k", "decode",
                           dict(kv_len=32768, batch=128)),
    "long_500k": ShapeDef("long_500k", "decode",
                          dict(kv_len=524288, batch=1)),
}

GNN_SHAPES: Dict[str, ShapeDef] = {
    # cora-like full batch (2708 nodes / 10556 edges padded to /32)
    "full_graph_sm": ShapeDef("full_graph_sm", "train_full",
                              dict(n_nodes=2816, n_edges=10752, d_feat=1433,
                                   n_classes=7, raw_nodes=2708,
                                   raw_edges=10556)),
    # reddit sampled training; assigned cell fanout is 15-10
    "minibatch_lg": ShapeDef("minibatch_lg", "train_sampled",
                             dict(batch_nodes=1024, fanouts=(15, 10),
                                  d_feat=602, n_classes=41,
                                  raw_nodes=232965, raw_edges=114615892)),
    "ogb_products": ShapeDef("ogb_products", "train_full",
                             dict(n_nodes=2449408, n_edges=61859840,
                                  d_feat=100, n_classes=47,
                                  raw_nodes=2449029, raw_edges=61859140)),
    # 128 small graphs as one disjoint union
    "molecule": ShapeDef("molecule", "train_full",
                         dict(n_nodes=3840, n_edges=8192, d_feat=32,
                              n_classes=16, batch_graphs=128,
                              nodes_per_graph=30, edges_per_graph=64)),
}

RECSYS_SHAPES: Dict[str, ShapeDef] = {
    "train_batch": ShapeDef("train_batch", "train",
                            dict(batch=65536, n_microbatches=1)),
    "serve_p99": ShapeDef("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeDef("serve_bulk", "serve", dict(batch=262144)),
    "retrieval_cand": ShapeDef("retrieval_cand", "retrieval",
                               dict(batch=1, n_candidates=1_000_000)),
}

# The paper's own workload as first-class dry-run cells: one distributed
# mining round (screen + count) over a production-scale bitmap store.
FIM_SHAPES: Dict[str, ShapeDef] = {
    # 2^27 transactions (134M), 8192 frequent-itemset rows, 64k pairs/round
    "mine_128m": ShapeDef("mine_128m", "mine",
                          dict(store_rows=8192, n_blocks=32768,
                               block_words=128, pairs=65536,
                               n_trans=2 ** 27)),
    # 2^30 transactions (1.07B): 1TB bitmap store, 4.3GB/chip on one pod
    "mine_1g": ShapeDef("mine_1g", "mine",
                        dict(store_rows=8192, n_blocks=262144,
                             block_words=128, pairs=65536,
                             n_trans=2 ** 30)),
}

FAMILY_SHAPES: Dict[str, Dict[str, ShapeDef]] = {
    "lm": LM_SHAPES,
    "gnn": GNN_SHAPES,
    "recsys": RECSYS_SHAPES,
    "fim": FIM_SHAPES,
}
