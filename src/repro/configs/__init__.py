"""Architecture registry: ``--arch <id>`` resolution for every driver."""

from typing import Dict, List, Tuple

from repro.configs.base import (  # noqa: F401
    ArchSpec, ShapeDef, FAMILY_SHAPES, LM_SHAPES, GNN_SHAPES,
    RECSYS_SHAPES, FIM_SHAPES,
)

from repro.configs import (
    command_r_plus_104b, qwen1_5_0_5b, granite_3_8b, deepseek_v2_236b,
    mixtral_8x22b, graphsage_reddit, sasrec, din, xdeepfm,
    two_tower_retrieval, fim_eclat,
)

# The 10 assigned architectures + the paper's own workload.
REGISTRY: Dict[str, ArchSpec] = {
    spec.arch_id: spec for spec in (
        command_r_plus_104b.SPEC,
        qwen1_5_0_5b.SPEC,
        granite_3_8b.SPEC,
        deepseek_v2_236b.SPEC,
        mixtral_8x22b.SPEC,
        graphsage_reddit.SPEC,
        sasrec.SPEC,
        din.SPEC,
        xdeepfm.SPEC,
        two_tower_retrieval.SPEC,
        fim_eclat.SPEC,
    )
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(
    a for a in REGISTRY if REGISTRY[a].family != "fim")


def get_arch(arch_id: str) -> ArchSpec:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}") from None


def get_shape(spec: ArchSpec, shape_id: str) -> ShapeDef:
    return FAMILY_SHAPES[spec.family][shape_id]


def all_cells(include_fim: bool = True) -> List[Tuple[str, str]]:
    """Every (arch_id, shape_id) pair — 40 assigned + optional FIM extras."""
    cells = []
    for arch_id, spec in REGISTRY.items():
        if spec.family == "fim" and not include_fim:
            continue
        for shape_id in spec.shape_ids:
            cells.append((arch_id, shape_id))
    return cells
