"""sasrec [arXiv:1808.09781; paper-verified].

embed_dim=50, 2 blocks, 1 head, seq_len=50, self-attentive sequential
recommendation.  Catalog scaled to production (1M items) so the embedding
table is the memory object the shapes exercise.
"""

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import SASRecConfig

# n_negatives=1: the paper trains with one sampled negative per position.
_FULL = SASRecConfig(
    name="sasrec", n_items=1_000_000, embed_dim=50, n_blocks=2,
    n_heads=1, seq_len=50, n_negatives=1, dtype="float32",
)

_SMOKE = SASRecConfig(
    name="sasrec-smoke", n_items=1000, embed_dim=16, n_blocks=2,
    n_heads=1, seq_len=20, n_negatives=5, dtype="float32",
)

SPEC = ArchSpec(
    arch_id="sasrec",
    family="recsys",
    source="arXiv:1808.09781 (SASRec)",
    config_fn=lambda shape_id=None: _FULL,
    smoke_config_fn=lambda: _SMOKE,
    shape_ids=tuple(RECSYS_SHAPES),
    rules_override={},
    notes="retrieval_cand scores the last state against 1M candidates.",
)
