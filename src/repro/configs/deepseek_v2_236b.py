"""deepseek-v2-236b [arXiv:2405.04434; hf-verified].

60L, d_model=5120, 128 heads with MLA (kv_lora=512, q_lora=1536,
qk_nope=128, qk_rope=64, v=128), MoE: 160 routed experts top-6 +
2 shared, expert d_ff=1536, first layer dense (d_ff=12288),
vocab 102400.
"""

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

_FULL = LMConfig(
    name="deepseek-v2-236b",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_head=128,
    d_ff=12288,                      # dense (first_k_dense) layers
    vocab_size=102400, tie_embeddings=False,
    moe=True, n_experts=160, top_k=6, moe_d_ff=1536, n_shared_experts=2,
    first_k_dense=1, capacity_factor=1.25,
    mla=True, q_lora=1536, kv_lora=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=1e4, norm_eps=1e-6,
    attn_chunk=1024, dtype="bfloat16", remat="full",
)

_SMOKE = LMConfig(
    name="deepseek-v2-smoke",
    n_layers=3, d_model=96, n_heads=4, n_kv_heads=4, d_head=24,
    d_ff=256, vocab_size=512, tie_embeddings=False,
    moe=True, n_experts=8, top_k=2, moe_d_ff=48, n_shared_experts=1,
    first_k_dense=1, mla=True, q_lora=48, kv_lora=32, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16,
    attn_chunk=64, dtype="float32", remat="none",
)

SPEC = ArchSpec(
    arch_id="deepseek-v2-236b",
    family="lm",
    source="arXiv:2405.04434 (DeepSeek-V2)",
    config_fn=lambda shape_id=None: _FULL,
    smoke_config_fn=lambda: _SMOKE,
    shape_ids=tuple(LM_SHAPES),
    # 160 experts / 16 = 10 per chip: expert parallelism over "model";
    # "embed" -> data adds the FSDP axis (472GB bf16 -> 1.8GB/chip);
    # MLA latent dims stay replicated.
    rules_override={"embed": "data"},
    notes=("MLA absorbed decode caches (c_kv 512 + rope 64) only. "
           "long_500k skipped: MLA compresses the cache ~9x but attention "
           "is still O(S) per step / O(S^2) prefill."),
)
