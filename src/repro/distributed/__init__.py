from repro.distributed.sharding import (  # noqa: F401
    AxisRules, use_rules, current_rules, logical_spec, constrain,
    make_param_shardings, DEFAULT_RULES, MULTI_POD_RULES,
)
from repro.distributed.compression import (  # noqa: F401
    quantize_int8, dequantize_int8, compressed_psum_int8, ErrorFeedback,
)
