"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Model code never names mesh axes directly.  It tags tensors and params
with *logical* axis names ("batch", "heads", "embed", ...), and a rules
table maps logical names to mesh axes.  Swapping a rules table re-shards
the entire model — that is the knob the perf hillclimb turns.

Rules resolve lazily against the mesh that is current at trace time, so
the same model code lowers for the single-pod (data, model) mesh and the
multi-pod (pod, data, model) mesh without edits.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import get_abstract_mesh

AxisName = Optional[Union[str, Tuple[str, ...]]]
AxisRules = Dict[str, AxisName]

# The baseline rules table.  "batch" resolves to every data-parallel axis
# present on the mesh; tensor-parallel dimensions resolve to "model".
DEFAULT_RULES: AxisRules = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,          # activations' hidden dim stays replicated
    "act_heads": "model",
    "act_kv_heads": None,       # kv heads often < model size; replicate
    "act_ff": "model",
    "experts_act": "model",     # (E, C, D) expert buffers: E over model
    "vocab_act": "model",       # logits (B, S, V): V over model
    "kv_seq": None,
    # params — transformer
    "vocab": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",              # MLP hidden (column parallel in, row out)
    "experts": "model",         # expert parallelism
    "expert_ff": None,
    "lora": None,               # MLA latent dims stay replicated
    # gnn
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
    "feat": None,
    "hidden": "model",
    # recsys
    "table_rows": "model",      # huge embedding tables: row sharded
    "table_dim": None,
    "candidates": ("pod", "data"),
    "fields": None,
    # mining
    "tid_blocks": ("pod", "data"),
    "pairs": "model",
    # optimizer state (ZeRO): shard the largest param axis over data
    "zero": ("data",),
}

# Multi-pod override example: keep TP within a pod, push batch across pods.
MULTI_POD_RULES: AxisRules = dict(DEFAULT_RULES)

_STATE = threading.local()


def current_rules() -> AxisRules:
    return getattr(_STATE, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_rules(rules: AxisRules):
    """Temporarily install a rules table (hillclimb / per-arch overrides).

    ``rules`` entries update a copy of the current table, so callers only
    specify the names they want to change."""
    prev = current_rules()
    merged = dict(prev)
    merged.update(rules)
    _STATE.rules = merged
    try:
        yield merged
    finally:
        _STATE.rules = prev


def _mesh_axes(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    if mesh is not None:
        return tuple(mesh.axis_names)
    env = get_abstract_mesh()   # None on JAX < 0.5 (repro.compat)
    if env is not None and env.axis_names:
        return tuple(env.axis_names)
    return ()


def logical_spec(logical: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None,
                 rules: Optional[AxisRules] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for ``mesh``.

    Logical names missing from the rules table resolve to None
    (replicated).  Mesh axes that do not exist on the current mesh are
    silently dropped (e.g. "pod" on the single-pod mesh), and a mesh axis
    may be used by at most one tensor dimension (first wins)."""
    rules = rules or current_rules()
    avail = set(_mesh_axes(mesh))
    used: set = set()
    out = []
    for name in logical:
        axis = rules.get(name) if name is not None else None
        if axis is None:
            out.append(None)
            continue
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        axes = tuple(a for a in axes if a in avail and a not in used)
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


def constrain(x: jax.Array, logical: Sequence[Optional[str]],
              mesh: Optional[Mesh] = None) -> jax.Array:
    """with_sharding_constraint by logical names (no-op without a mesh)."""
    mesh = mesh or _current_concrete_mesh()
    if mesh is None:
        return x
    spec = logical_spec(logical, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


_ACTIVE_MESH: threading.local = threading.local()


def _current_concrete_mesh() -> Optional[Mesh]:
    return getattr(_ACTIVE_MESH, "mesh", None)


@contextlib.contextmanager
def active_mesh(mesh: Optional[Mesh]):
    """Install the mesh used by ``constrain`` inside model code."""
    prev = _current_concrete_mesh()
    _ACTIVE_MESH.mesh = mesh
    try:
        yield mesh
    finally:
        _ACTIVE_MESH.mesh = prev


def make_param_shardings(mesh: Mesh, logical_tree,
                         rules: Optional[AxisRules] = None):
    """Map a pytree of logical-axis tuples to NamedShardings on ``mesh``."""
    return jax.tree.map(
        lambda names: NamedSharding(mesh, logical_spec(names, mesh, rules)),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            n is None or isinstance(n, str) for n in x),
    )


def shard_like(tree, shardings):
    """device_put a pytree according to a parallel tree of shardings."""
    return jax.tree.map(jax.device_put, tree, shardings)


def divisibility_report(shape: Tuple[int, ...], spec: P, mesh: Mesh):
    """Human-readable check that a shape divides its spec on the mesh."""
    problems = []
    # A PartitionSpec may omit trailing (unsharded) dims, so the spec is
    # allowed to be shorter than the shape.
    for dim, axis in zip(shape, spec, strict=False):
        if axis is None:
            continue
        axes = (axis,) if isinstance(axis, str) else axis
        total = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % total:
            problems.append(f"dim {dim} % mesh{axes}={total} != 0")
    return problems
