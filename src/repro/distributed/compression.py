"""Gradient compression for the slow cross-pod hop.

int8 quantization with per-tensor scales and error feedback (1-bit Adam /
EF-SGD family).  Applied only to the reduction over the ``pod`` axis —
within a pod the ICI is fast enough that full-precision reduce-scatter is
the right call; across pods (DCN) an 8x shrink of the gradient payload is
worth the quantization noise, and the error-feedback buffer makes the
compression unbiased over time.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum_int8(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """psum with int8 payload: quantize -> psum(int32) -> dequant(mean scale).

    Usable inside shard_map over the pod axis.  The int32 accumulation of
    int8 payloads is exact; only the shared scale introduces error (each
    shard's scale is psum-averaged, standard practice)."""
    q, scale = quantize_int8(x)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return acc.astype(jnp.float32) * (scale_sum / n)


def compressed_crosspod_allreduce(tree, mesh, pod_axis: str = "pod"):
    """Mean-reduce a pytree across pods with int8 payloads.

    The within-pod reduction is assumed done (fast ICI, full precision);
    this is the slow DCN hop.  Per-leaf int8 quantization with psum'd
    scales — 4x (fp32) / 2x (bf16) payload shrink.  Pair with
    ``ErrorFeedback`` across steps to de-bias.

    Usage in a train step (multi-pod mesh): grads computed with batch
    sharded over ("pod","data") come out of value_and_grad already
    globally reduced by SPMD; to take ownership of the pod hop instead,
    constrain the loss's batch to "data" only and call this on the grads.
    """
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if pod_axis not in mesh.axis_names:
        return tree  # single-pod mesh: nothing to do

    def leaf(x):
        spec = P(*([None] * x.ndim))

        @partial(shard_map, mesh=mesh, in_specs=spec, out_specs=spec,
                 check_rep=False)
        def red(v):
            n = jax.lax.psum(jnp.ones((), jnp.float32), pod_axis)
            return compressed_psum_int8(v, pod_axis) / n

        return red(x)

    return jax.tree.map(leaf, tree)


@dataclass
class ErrorFeedback:
    """Error-feedback state: residual = x - dequant(quant(x)) carried into
    the next step so quantization error does not bias the optimizer."""

    @staticmethod
    def init(params) -> dict:
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads, residuals):
        """Returns (compressed_grads, new_residuals)."""
        def one(g, r):
            x = g.astype(jnp.float32) + r
            q, s = quantize_int8(x)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), x - deq

        flat = jax.tree.map(one, grads, residuals)
        comp = jax.tree.map(lambda t: t[0], flat,
                            is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
        return comp, res
