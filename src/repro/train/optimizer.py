"""Optimizers from scratch (no optax): AdamW and Adafactor.

Functional style: ``init`` builds the state pytree (same structure as
params, so the sharding rules that place params also place optimizer
state — moments inherit the param's logical axes, ZeRO-style sharding is
a rules-table change), ``update`` is pure.

Adafactor matters at 104B scale: AdamW moments for command-r-plus would
add 2 x 104B fp32 = 832GB of state; Adafactor's factored second moment
cuts that to ~param size.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to ``min_lr_frac * lr``."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params: Params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params: Params, grads: Params, state, cfg: OptConfig,
                 ) -> Tuple[Params, Dict[str, Any], Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mu": mu, "nu": nu, "step": step}, {
        "lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------

def adafactor_init(params: Params) -> Dict[str, Any]:
    def fac(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(fac, params), "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params: Params, grads: Params, state, cfg: OptConfig,
                     ) -> Tuple[Params, Dict[str, Any], Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(p, g, v):
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = decay * v["vr"] + (1 - decay) * g2.mean(-1)
            vc = decay * v["vc"] + (1 - decay) * g2.mean(-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(-1, keepdims=True)[..., None],
                                   1e-30))
            delta = g / jnp.sqrt(denom + cfg.eps)
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": decay * v["v"] + (1 - decay) * g2}
            delta = g / jnp.sqrt(nv["v"] + cfg.eps)
        # update clipping (RMS <= 1) per the paper
        rms = jnp.sqrt(jnp.mean(delta * delta) + 1e-30)
        delta = delta / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), nv

    leaves_is = lambda t: isinstance(t, dict) and (  # noqa: E731
        "vr" in t or "v" in t)
    out = jax.tree.map(upd, params, grads, state["v"], is_leaf=None)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    del leaves_is
    return new_params, {"v": v, "step": step}, {"lr": lr, "grad_norm": gnorm}


OPTIMIZERS: Dict[str, Tuple[Callable, Callable]] = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
}


def opt_init(params, cfg: OptConfig):
    return OPTIMIZERS[cfg.kind][0](params)


def opt_update(params, grads, state, cfg: OptConfig):
    return OPTIMIZERS[cfg.kind][1](params, grads, state, cfg)


def opt_state_logical(logical, cfg: OptConfig):
    """Logical axes for the optimizer state, mirroring param axes."""
    if cfg.kind == "adamw":
        return {"mu": logical, "nu": logical,
                "step": ()}
    def fac(names):
        names = tuple(names)
        if len(names) >= 2:
            return {"vr": names[:-1], "vc": names[:-2] + names[-1:]}
        return {"v": names}
    is_tuple = lambda x: isinstance(x, tuple) and all(  # noqa: E731
        n is None or isinstance(n, str) for n in x)
    return {"v": jax.tree.map(fac, logical, is_leaf=is_tuple), "step": ()}
