# Training substrate: optimizers, step builders, checkpointing.
