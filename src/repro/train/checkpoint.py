"""Sharded numpy checkpointing with atomic writes and elastic restore.

Design (DESIGN.md §6):
  * one ``.npy`` file per pytree leaf (path-encoded filename) + a JSON
    manifest (step, tree structure, dtypes, logical axes, mesh shape);
  * writes go to ``<dir>/tmp-<step>`` then ``os.replace`` to
    ``<dir>/step-<step>`` — a crash mid-write never corrupts the latest
    checkpoint (restart reads the newest complete manifest);
  * restore is *elastic*: leaves are stored unsharded (fetched to host),
    and are re-placed onto whatever mesh/sharding the restoring run uses,
    so pod count may change across restarts;
  * ``AsyncCheckpointer`` hands the (host-fetched) state to a worker
    thread so a slow filesystem never blocks the training step
    (straggler mitigation for the I/O path).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import ml_dtypes

import jax

# numpy cannot natively (de)serialise bf16/fp8: store them as same-width
# uints and reinterpret on load, with the true dtype in the manifest.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8, "float16": None}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC and _EXOTIC[name] is not None:
        return arr.view(_EXOTIC[name]), name
    return arr, name


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC and _EXOTIC[dtype_name] is not None:
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save_checkpoint(directory: str, step: int, state: Dict[str, Any],
                    extra: Optional[Dict[str, Any]] = None,
                    keep: int = 3) -> str:
    """state: pytree of arrays (device or host). Returns final path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp-{step}-{os.getpid()}")
    final = os.path.join(directory, f"step-{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "time": time.time(), "leaves": [],
                "extra": extra or {}}
    for name, leaf in _flatten_with_paths(state):
        arr = np.asarray(jax.device_get(leaf))
        saved, dtype_name = _encode(arr)
        fname = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), saved)
        manifest["leaves"].append({
            "path": name, "file": fname,
            "shape": list(arr.shape), "dtype": dtype_name})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _rotate(directory, keep)
    return final


def _rotate(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step-"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step-") and os.path.exists(
                os.path.join(directory, d, "manifest.json")):
            steps.append(int(d.split("-")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into ``template``'s structure.  ``shardings`` (optional,
    same structure) re-places each leaf on the current mesh — this is the
    elastic-resharding path."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step-{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    paths = _flatten_with_paths(template)
    leaves = []
    for name, _leaf in paths:
        entry = by_path.get(name)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = _decode(np.load(os.path.join(d, entry["file"])),
                      entry["dtype"])
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(jax.device_put, state, shardings)
    return state, step, manifest.get("extra", {})


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writes on a worker thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, state, extra=None) -> None:
        self.wait()  # one in flight at a time; device_get happens here
        host_state = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save_checkpoint(self.directory, step, host_state,
                                extra=extra, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
