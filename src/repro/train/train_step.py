"""Step builders: train (grad + optimizer, microbatched) and serve.

``make_train_step(loss_fn, opt_cfg, n_microbatches)`` returns a pure
``step(params, opt_state, batch) -> (params, opt_state, metrics)``
suitable for jit/pjit.  Microbatching reshapes every batch leaf
``(B, ...) -> (n_mb, B/n_mb, ...)`` and accumulates grads with
``lax.scan`` — under SPMD this is also what lets XLA overlap each
microbatch's gradient reduce-scatter with the next microbatch's backward
(the standard pjit accumulation overlap).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import OptConfig, opt_update

LossFn = Callable[[Any, Any], Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]]


def _split_microbatches(batch, n_mb: int):
    def r(x):
        assert x.shape[0] % n_mb == 0, (x.shape, n_mb)
        return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])

    return jax.tree.map(r, batch)


def make_train_step(loss_fn: LossFn, opt_cfg: OptConfig,
                    n_microbatches: int = 1) -> Callable:
    """loss_fn(params, microbatch) -> (loss, metrics dict of scalars)."""

    def step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if n_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = _split_microbatches(batch, n_microbatches)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (loss_mb, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss_mb), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(accum, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            loss = loss / n_microbatches
            metrics = jax.tree.map(lambda x: x[-1], ms)

        new_params, new_state, opt_metrics = opt_update(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return step


def make_eval_step(loss_fn: LossFn) -> Callable:
    def step(params, batch):
        loss, metrics = loss_fn(params, batch)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return metrics

    return step
