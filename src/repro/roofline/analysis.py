"""Three-term roofline from dry-run artifacts (TPU v5e constants).

    compute    = HLO_FLOPs_per_chip / peak_FLOPs            [s]
    memory     = HLO_bytes_per_chip / HBM_bw                [s]
    collective = per-chip link bytes (ring model) / link_bw [s]

cost_analysis() on the partitioned module reports per-chip numbers, so
the "/(chips x ...)" in the brief's formulas is already applied.
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per the brief; the
ratio MODEL_FLOPS / (HLO_FLOPs x chips) flags remat/redundancy waste.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

# TPU v5e, per chip
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link (brief's constant)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    link_bytes_per_chip: float
    model_flops: float            # 6*N*D or 6*N_active*D (train cells)
    peak_memory_per_chip: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.link_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs (remat/redundancy waste)."""
        total = self.flops_per_chip * self.chips
        if total <= 0 or self.model_flops <= 0:
            return 0.0
        return self.model_flops / total

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful FLOPs / (chips*peak*step_time_lb)."""
        if self.model_flops <= 0:
            # non-train cells: report compute-term share of the bound
            lb = self.step_time_lower_bound
            return self.t_compute / lb if lb > 0 else 0.0
        denom = self.chips * PEAK_FLOPS * self.step_time_lower_bound
        return self.model_flops / denom if denom > 0 else 0.0

    def as_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "link_bytes_per_chip": self.link_bytes_per_chip,
            "peak_memory_per_chip": self.peak_memory_per_chip,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_lb_s": self.step_time_lower_bound,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def terms_from_record(rec: Dict) -> Optional[RooflineTerms]:
    """Build terms from one dry-run JSON record (see launch/dryrun.py)."""
    if rec.get("skip_reason"):
        return None
    cost = rec.get("cost_analysis") or {}
    coll = rec.get("collectives") or {}
    # MODEL_FLOPS: 6*N_active*D for training (fwd+bwd), 2*N_active*D for
    # forward-only cells (prefill/decode).  Records store the raw token
    # count; the factor is applied here so it stays auditable.
    tokens = float(rec.get("tokens_per_step", 0.0))
    n_active = float(rec.get("active_params", 0.0))
    factor = 6.0 if rec["shape"].startswith("train") else 2.0
    model_flops = factor * n_active * tokens if tokens and n_active else 0.0
    return RooflineTerms(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=rec["chips"],
        flops_per_chip=float(cost.get("flops", 0.0)),
        bytes_per_chip=float(cost.get("bytes accessed", 0.0)),
        link_bytes_per_chip=float(
            coll.get("total", {}).get("link_bytes", 0.0)),
        model_flops=model_flops,
        peak_memory_per_chip=float(rec.get("peak_memory_per_chip", 0.0)),
    )


def load_records(outdir: str) -> Dict[str, Dict]:
    recs = {}
    if not os.path.isdir(outdir):
        return recs
    for f in sorted(os.listdir(outdir)):
        if f.endswith(".json"):
            with open(os.path.join(outdir, f)) as fh:
                recs[f[:-5]] = json.load(fh)
    return recs


def format_table(records: Dict[str, Dict]) -> str:
    """Markdown roofline table for EXPERIMENTS.md."""
    header = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | "
              "t_coll (ms) | bottleneck | useful-FLOPs | roofline frac |\n"
              "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for _key, rec in sorted(records.items()):
        if rec.get("skip_reason"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                        f"| — | — | — | SKIP: {rec['skip_reason'][:40]}… "
                        f"| — | — |")
            continue
        t = terms_from_record(rec)
        rows.append(
            f"| {t.arch} | {t.shape} | {t.mesh} "
            f"| {t.t_compute*1e3:.3f} | {t.t_memory*1e3:.3f} "
            f"| {t.t_collective*1e3:.3f} | {t.bottleneck} "
            f"| {t.useful_flops_ratio:.3f} | {t.roofline_fraction:.3f} |")
    return header + "\n".join(rows)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(format_table(recs))


if __name__ == "__main__":
    main()
