"""HLO text parsing: per-device collective traffic from a compiled module.

``cost_analysis()`` does not report collective bytes, so we parse the
post-SPMD (per-device) HLO: build a symbol table of op result sizes, then
for every ``all-reduce`` / ``all-gather`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` sum the byte sizes of its
*operands* (per the brief).  Shapes in the partitioned module are
per-device shapes, so the sums are per-chip traffic; the roofline model
applies a ring-algorithm factor per collective kind.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# ring-algorithm per-link byte multiplier (relative to operand bytes)
RING_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# one HLO op definition: %name = type[shape]{layout} opcode(...operands...)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*\)|[\w\[\],{}\s/#:*]+?)\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuples sum their components."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_WHILE_TUPLE_RE = re.compile(r"=\s*\(([^)]*)\)\s+while\(")
_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def estimate_bf16_shadow_bytes(hlo_text: str) -> int:
    """XLA-CPU float normalisation artifact: the CPU backend has no native
    bf16, so loop-carried bf16 buffers acquire f32 shadow copies (verified
    on a minimal pure-bf16 matmul scan — the f32 twin stack appears with
    no remat and no fp32 ops anywhere in the program).  On the real TPU
    target these shadows do not exist.  This estimates their total: for
    every ``while`` carry tuple, sum the sizes of f32 elements whose dims
    exactly match a bf16 element of the same tuple."""
    total = 0
    for m in _WHILE_TUPLE_RE.finditer(hlo_text):
        elems = _TUPLE_ELEM_RE.findall(m.group(1))
        bf16_dims = {dims for dt, dims in elems if dt == "bf16"}
        for dt, dims in elems:
            if dt == "f32" and dims in bf16_dims and dims:
                n = 1
                for d in dims.split(","):
                    n *= int(d)
                total += 4 * n
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns {kind: {"count": n, "operand_bytes": b, "link_bytes": b*f}}.

    Also aggregates "total" with summed link bytes."""
    sizes: Dict[str, int] = {}
    pending: list = []

    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        sizes[name] = _shape_bytes(type_str)
        base = opcode.rstrip("-started").rstrip(".")
        kind = None
        for ck in COLLECTIVE_KINDS:
            if opcode in (ck, ck + "-start"):
                kind = ck
                break
        if kind is not None:
            # operand list: up to the matching close paren; names only
            args = rest.split(")", 1)[0]
            ops = [o for o in _OPERAND_RE.findall(args) if not o.isdigit()]
            pending.append((kind, ops))
        del base

    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "operand_bytes": 0.0, "link_bytes": 0.0})
    for kind, ops in pending:
        b = sum(sizes.get(o, 0) for o in ops)
        out[kind]["count"] += 1
        out[kind]["operand_bytes"] += b
        out[kind]["link_bytes"] += b * RING_FACTOR[kind]
    total = {"count": sum(v["count"] for v in out.values()),
             "operand_bytes": sum(v["operand_bytes"] for v in out.values()),
             "link_bytes": sum(v["link_bytes"] for v in out.values())}
    result = dict(out)
    result["total"] = total
    return result
