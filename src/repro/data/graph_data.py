"""Graph data: synthetic power-law graphs + a real uniform neighbor sampler.

``minibatch_lg`` (Reddit-scale sampled training) needs an actual neighbor
sampler, not a stub: ``NeighborSampler`` builds a CSR adjacency once and
draws uniform fanout samples per minibatch (GraphSAGE's training regime),
padding with self-loops where degree < fanout and emitting validity masks.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass
class SynthGraph:
    x: np.ndarray          # (N, F) float32
    edge_src: np.ndarray   # (E,) int32
    edge_dst: np.ndarray   # (E,) int32
    labels: np.ndarray     # (N,) int32


def gen_powerlaw_graph(n_nodes: int, avg_degree: float, d_feat: int,
                       n_classes: int, seed: int = 0,
                       alpha: float = 1.5) -> SynthGraph:
    """Degree-skewed random graph with label-correlated features."""
    rng = np.random.default_rng(seed)
    w = (rng.pareto(alpha, n_nodes) + 0.1)
    p = w / w.sum()
    n_edges = int(n_nodes * avg_degree)
    src = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    x = (centers[labels] + rng.normal(scale=2.0, size=(n_nodes, d_feat))
         ).astype(np.float32)
    return SynthGraph(x=x, edge_src=src, edge_dst=dst, labels=labels)


def gen_batched_molecules(n_graphs: int, n_nodes: int, n_edges: int,
                          d_feat: int, n_classes: int, seed: int = 0,
                          ) -> SynthGraph:
    """Disjoint union of ``n_graphs`` small graphs (the ``molecule`` shape)."""
    rng = np.random.default_rng(seed)
    srcs: List[np.ndarray] = []
    dsts: List[np.ndarray] = []
    for g in range(n_graphs):
        base = g * n_nodes
        srcs.append(rng.integers(0, n_nodes, n_edges).astype(np.int32) + base)
        dsts.append(rng.integers(0, n_nodes, n_edges).astype(np.int32) + base)
    N = n_graphs * n_nodes
    labels = rng.integers(0, n_classes, N).astype(np.int32)
    x = rng.normal(size=(N, d_feat)).astype(np.float32)
    return SynthGraph(x=x, edge_src=np.concatenate(srcs),
                      edge_dst=np.concatenate(dsts), labels=labels)


class NeighborSampler:
    """Uniform fanout sampling over a CSR adjacency (GraphSAGE §3.1).

    For each seed node: f1 neighbors; for each of those: f2 neighbors.
    Nodes with degree < fanout are padded by repeating sampled neighbors
    (standard GraphSAGE practice: sample WITH replacement); isolated nodes
    fall back to self-loops with mask=0."""

    def __init__(self, edge_src: np.ndarray, edge_dst: np.ndarray,
                 n_nodes: int, seed: int = 0):
        order = np.argsort(edge_dst, kind="stable")
        self.nbr = edge_src[order]
        counts = np.bincount(edge_dst, minlength=n_nodes)
        self.offsets = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=self.offsets[1:])
        self.n_nodes = n_nodes
        self.rng = np.random.default_rng(seed)

    def sample_hop(self, nodes: np.ndarray, fanout: int,
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """nodes (...,) -> (neighbors (..., fanout), mask (..., fanout))."""
        flat = nodes.reshape(-1)
        deg = (self.offsets[flat + 1] - self.offsets[flat])
        has = deg > 0
        # uniform with replacement
        r = self.rng.integers(0, np.maximum(deg, 1),
                              size=(fanout, flat.size))
        idx = self.offsets[flat][None, :] + r
        nbrs = np.where(has[None, :], self.nbr[idx % len(self.nbr)],
                        flat[None, :])
        mask = np.broadcast_to(has[None, :], nbrs.shape)
        nbrs = nbrs.T.reshape(nodes.shape + (fanout,)).astype(np.int32)
        mask = mask.T.reshape(nodes.shape + (fanout,))
        return nbrs, mask

    def sample_batch(self, seeds: np.ndarray, fanouts: Tuple[int, int],
                     x: np.ndarray,
                     ) -> Tuple[Tuple[np.ndarray, ...],
                                Tuple[np.ndarray, ...]]:
        """Returns (feats, masks) matching models.gnn.forward_sampled."""
        f1, f2 = fanouts
        h1, m1 = self.sample_hop(seeds, f1)              # (B, f1)
        h2, m2 = self.sample_hop(h1, f2)                 # (B, f1, f2)
        feats = (x[seeds], x[h1], x[h2])
        return feats, (m1, m2)
