"""Synthetic transaction databases replicating the paper's nine datasets.

The paper evaluates on FIMI/KONECT datasets (T40I10D100K, MovieLens-1M,
Github, Retail, Kosarak, Accidents, Chess, Connect, Pumsb).  This
container is offline, so we generate *statistical replicas*: same
generative family, matched #items / avg transaction length / density
regime, scaled so benchmarks run in minutes on one CPU core.  What the
paper's experiments depend on is the **candidate/node ratio** regime
(Table IV) — sparse high-ratio data (big ES wins) vs dense low-ratio data
(neutral) — which these generators reproduce by construction.

Generators
----------
``gen_quest``          IBM Quest-style market baskets (T40I10D100K family)
``gen_powerlaw_baskets`` power-law item popularity (Retail/Kosarak family)
``gen_bipartite``      user x item memberships (MovieLens/Github family)
``gen_dense_tabular``  categorical-attribute rows (Chess/Connect/Pumsb
                       family: every transaction has one item per column,
                       few columns, heavy co-occurrence => dense, ratio~1)
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

import numpy as np

Database = List[List[int]]
BatchStream = Iterator[Tuple[np.ndarray, np.ndarray]]


def gen_quest(n_trans: int = 2000, n_items: int = 200,
              avg_trans_len: int = 12, avg_pat_len: int = 4,
              n_patterns: int = 60, corruption: float = 0.3,
              seed: int = 0) -> Database:
    """Simplified IBM Quest generator (Agrawal & Srikant, VLDB'94).

    Potentially-frequent patterns are drawn with exponentially distributed
    sizes and power-law weights; each transaction is assembled from
    patterns (with per-item corruption) until its target length is met.
    """
    rng = np.random.default_rng(seed)
    pat_sizes = np.maximum(1, rng.poisson(avg_pat_len, n_patterns))
    patterns = [rng.choice(n_items, size=min(s, n_items), replace=False)
                for s in pat_sizes]
    weights = rng.pareto(1.5, n_patterns) + 1e-3
    weights /= weights.sum()
    trans_lens = np.maximum(1, rng.poisson(avg_trans_len, n_trans))

    db: Database = []
    for t in range(n_trans):
        target = trans_lens[t]
        items: set = set()
        guard = 0
        while len(items) < target and guard < 40:
            guard += 1
            p = patterns[rng.choice(n_patterns, p=weights)]
            kept = p[rng.random(len(p)) >= corruption]
            items.update(int(i) for i in kept)
        if not items:
            items = {int(rng.integers(n_items))}
        db.append(sorted(items))
    return db


def gen_powerlaw_baskets(n_trans: int = 3000, n_items: int = 800,
                         avg_trans_len: float = 10.0, alpha: float = 1.3,
                         seed: int = 0) -> Database:
    """Retail/Kosarak-style baskets: Zipfian item popularity, variable
    lengths, weak correlation => very high candidate/node ratio."""
    rng = np.random.default_rng(seed)
    pop = 1.0 / np.arange(1, n_items + 1) ** alpha
    pop /= pop.sum()
    lens = np.maximum(1, rng.poisson(avg_trans_len, n_trans))
    db: Database = []
    for t in range(n_trans):
        k = min(int(lens[t]), n_items)
        items = rng.choice(n_items, size=k, replace=False, p=pop)
        db.append(sorted(int(i) for i in items))
    return db


def gen_bipartite(n_users: int = 1500, n_items: int = 600,
                  avg_degree: float = 20.0, user_skew: float = 1.1,
                  item_skew: float = 1.2, seed: int = 0) -> Database:
    """MovieLens/Github-style bipartite memberships: transactions are
    users, items are movies/projects; both sides heavy-tailed."""
    rng = np.random.default_rng(seed)
    u_w = rng.pareto(user_skew, n_users) + 0.1
    deg = np.maximum(1, (u_w / u_w.mean() * avg_degree)).astype(int)
    deg = np.minimum(deg, n_items)
    pop = 1.0 / np.arange(1, n_items + 1) ** item_skew
    pop /= pop.sum()
    db: Database = []
    for u in range(n_users):
        items = rng.choice(n_items, size=deg[u], replace=False, p=pop)
        db.append(sorted(int(i) for i in items))
    return db


def gen_dense_tabular(n_trans: int = 1000, n_cols: int = 12,
                      vals_per_col: int = 4, skew: float = 2.0,
                      correlation: float = 0.9, n_classes: int = 3,
                      seed: int = 0) -> Database:
    """Chess/Connect/Pumsb-style data: one item per categorical column.

    Columns are CORRELATED through a latent class (board positions /
    census fields are strongly dependent): each row draws a class and
    each column takes the class's value w.p. ``correlation``, else a
    skewed random value.  That co-occurrence structure is what drives the
    paper's dense regime — candidate/node ratio ~ 1 (nearly every
    proposed candidate is frequent, leaving ES nothing to abort)."""
    rng = np.random.default_rng(seed)
    db: Database = []
    col_dists = []
    for _c in range(n_cols):
        w = rng.pareto(skew, vals_per_col) + 0.2
        col_dists.append(w / w.sum())
    class_vals = rng.integers(0, vals_per_col, size=(n_classes, n_cols))
    class_p = rng.dirichlet(np.full(n_classes, 2.0))
    for _t in range(n_trans):
        k = rng.choice(n_classes, p=class_p)
        row = []
        for c in range(n_cols):
            if rng.random() < correlation:
                v = int(class_vals[k, c])
            else:
                v = int(rng.choice(vals_per_col, p=col_dists[c]))
            row.append(c * vals_per_col + v)
        db.append(row)
    return db


# Paper Table III analogues (scaled ~20-100x down; relative minsups kept in
# the same regime so the candidate/node ratio matches each dataset family).
DATASET_REPLICAS: Dict[str, Tuple[str, dict, List[float]]] = {
    # name: (generator, kwargs, relative minsup ladder — 4 values like the
    # paper's minSup_1..minSup_4, smallest first)
    "t40-like":      ("quest", dict(n_trans=4000, n_items=300,
                                    avg_trans_len=16, avg_pat_len=6,
                                    n_patterns=80), [0.005, 0.01, 0.02, 0.04]),
    "movielens-like": ("bipartite", dict(n_users=1200, n_items=400,
                                         avg_degree=40), [0.07, 0.08, 0.09, 0.10]),
    # NOTE: the ladder sits above the clique blow-up knee of this replica
    # (F explodes >10^7 below minsup~20 — popular-project co-membership);
    # the paper's absolute-runtime regime maps to these relative levels.
    "github-like":   ("bipartite", dict(n_users=4000, n_items=1500,
                                        avg_degree=4, item_skew=1.05),
                      [0.006, 0.007, 0.009, 0.012]),
    "retail-like":   ("powerlaw", dict(n_trans=4000, n_items=1200,
                                       avg_trans_len=10), [0.001, 0.0015, 0.002, 0.003]),
    "kosarak-like":  ("powerlaw", dict(n_trans=6000, n_items=1600,
                                       avg_trans_len=8, alpha=1.6),
                      [0.002, 0.004, 0.008, 0.012]),
    "accidents-like": ("dense", dict(n_trans=2500, n_cols=11,
                                     vals_per_col=5, skew=1.6),
                       [0.28, 0.32, 0.38, 0.44]),
    "chess-like":    ("dense", dict(n_trans=1000, n_cols=12,
                                    vals_per_col=3, skew=2.5),
                      [0.45, 0.5, 0.55, 0.6]),
    "connect-like":  ("dense", dict(n_trans=2000, n_cols=14,
                                    vals_per_col=3, skew=3.0),
                      [0.5, 0.55, 0.6, 0.65]),
    "pumsb-like":    ("dense", dict(n_trans=1500, n_cols=15,
                                    vals_per_col=6, skew=1.8),
                      [0.28, 0.32, 0.38, 0.44]),
}

_GENS = {
    "quest": gen_quest,
    "powerlaw": gen_powerlaw_baskets,
    "bipartite": gen_bipartite,
    "dense": gen_dense_tabular,
}


def make_dataset(name: str, seed: int = 0) -> Tuple[Database, List[int]]:
    """Returns (db, minsup ladder as absolute counts, smallest first)."""
    gen_name, kwargs, rels = DATASET_REPLICAS[name]
    db = _GENS[gen_name](seed=seed, **kwargs)
    n = len(db)
    minsups = [max(1, int(round(r * n))) for r in rels]
    return db, minsups


# ---------------------------------------------------------------------------
# Paper-scale replicas (ISSUE 9): streamed batch generators + two-pass
# bitmap packing.  The smoke-scale generators above build a Python
# list-of-lists; at paper size (10^5..10^6 transactions) that detour —
# and BitmapDB.from_db's per-transaction Python loop over it — dominates
# end-to-end time and RAM.  Here the same generative families are
# re-expressed as *vectorized batch streams* yielding
# ``(items uint/int (b, L), mask bool (b, L))`` arrays, and
# :func:`stream_paper_dataset` packs them straight into the frequent-row
# bitmap: pass 1 counts supports (per-row dedup via sort+first-occurrence
# — the powerlaw stream draws WITH replacement), pass 2 regenerates the
# identical stream from the seed and ORs bits into the packed slab.
# Peak host memory is one batch plus the final bitmap; no dense
# (n_trans x n_items) matrix and no list-of-lists ever exist.
# ---------------------------------------------------------------------------

def _powerlaw_stream(*, n_trans: int, n_items: int, avg_trans_len: float,
                     alpha: float, seed: int, batch: int) -> BatchStream:
    """Vectorized Kosarak-family stream.  Items are drawn WITH
    replacement (a (b, L) ``rng.choice`` is the vectorizable form);
    duplicates within a row collapse when packing/counting, so the
    marginal popularity regime matches ``gen_powerlaw_baskets`` with the
    effective length landing slightly under ``avg_trans_len``."""
    rng = np.random.default_rng(seed)
    pop = 1.0 / np.arange(1, n_items + 1) ** alpha
    pop /= pop.sum()
    cap = max(4, int(avg_trans_len * 3) + 8)   # Poisson tail clip
    for lo in range(0, n_trans, batch):
        b = min(batch, n_trans - lo)
        lens = np.minimum(np.maximum(1, rng.poisson(avg_trans_len, b)), cap)
        items = rng.choice(n_items, size=(b, cap), p=pop)
        mask = np.arange(cap)[None, :] < lens[:, None]
        yield items, mask


def _dense_stream(*, n_trans: int, n_cols: int, vals_per_col: int,
                  skew: float, correlation: float = 0.9, n_classes: int = 3,
                  seed: int, batch: int) -> BatchStream:
    """Vectorized Accidents/Pumsb-family stream: same latent-class model
    as ``gen_dense_tabular``, one item per column, drawn a batch of rows
    at a time."""
    rng = np.random.default_rng(seed)
    col_p = []
    for _c in range(n_cols):
        w = rng.pareto(skew, vals_per_col) + 0.2
        col_p.append(w / w.sum())
    class_vals = rng.integers(0, vals_per_col, size=(n_classes, n_cols))
    class_p = rng.dirichlet(np.full(n_classes, 2.0))
    for lo in range(0, n_trans, batch):
        b = min(batch, n_trans - lo)
        k = rng.choice(n_classes, size=b, p=class_p)
        use_class = rng.random((b, n_cols)) < correlation
        noise = np.stack([rng.choice(vals_per_col, size=b, p=col_p[c])
                          for c in range(n_cols)], axis=1)
        vals = np.where(use_class, class_vals[k], noise)
        items = np.arange(n_cols)[None, :] * vals_per_col + vals
        yield items, np.ones((b, n_cols), bool)


_STREAMS = {"powerlaw": _powerlaw_stream, "dense": _dense_stream}

# Paper-size regimes (Table III): kosarak at its real row/item counts;
# accidents/pumsb keep the paper's TRANSACTION counts (the axis the mesh
# shards and the axis that makes them "paper scale") but a modest column
# count — the latent-class model at correlation 0.9 makes nearly every
# column subset frequent, so paper-width rows would put |F| ~ 2^74 out
# of reach of ANY miner; the dense low-ratio regime the paper's Table IV
# attributes to these datasets is preserved at this width.
PAPER_REPLICAS: Dict[str, Tuple[str, dict, List[float]]] = {
    "kosarak-paper":   ("powerlaw", dict(n_trans=990_000, n_items=41_270,
                                         avg_trans_len=8.0, alpha=1.6),
                        [0.0025, 0.005, 0.01, 0.02]),
    "accidents-paper": ("dense", dict(n_trans=340_183, n_cols=15,
                                      vals_per_col=5, skew=1.6),
                        [0.28, 0.32, 0.38, 0.44]),
    "pumsb-paper":     ("dense", dict(n_trans=49_046, n_cols=18,
                                      vals_per_col=6, skew=1.8),
                        [0.28, 0.32, 0.38, 0.44]),
}


def _item_universe(gen_name: str, kwargs: dict) -> int:
    if gen_name == "powerlaw":
        return int(kwargs["n_items"])
    return int(kwargs["n_cols"]) * int(kwargs["vals_per_col"])


def _masked_unique_bincount(items: np.ndarray, mask: np.ndarray,
                            n_universe: int) -> np.ndarray:
    """Per-row-deduplicated item counts for one batch: sort each row,
    keep first occurrences, bincount the survivors."""
    x = np.where(mask, items, -1)
    x = np.sort(x, axis=1)
    first = np.ones(x.shape, bool)
    first[:, 1:] = x[:, 1:] != x[:, :-1]
    sel = first & (x >= 0)
    return np.bincount(x[sel].ravel(), minlength=n_universe)


def stream_paper_dataset(name: str, *, scale: float = 1.0, seed: int = 0,
                         block_words: int = 128, batch: int = 8192):
    """Pack a paper-scale replica into a :class:`BitmapDB` by streaming.

    Two passes over the SAME seeded stream (regeneration is the
    multi-host determinism story too — every host can rebuild any batch
    from (seed, batch index)): pass 1 accumulates per-item supports,
    pass 2 ORs each frequent item's TID bits into its packed bitmap row
    with ``np.bitwise_or.at``.  Rows come out in the engine's Eclat
    order (support ascending, ``repr`` tie-break — matching
    ``BitmapDB.from_db``).  ``scale`` multiplies the transaction count
    (CI runs ``--full --scale 0.1``); minsups stay *relative*, so the
    mined regime is scale-invariant.

    Returns ``(BitmapDB, minsup ladder as absolute counts, smallest
    first)``; the BitmapDB is packed at the smallest ladder rung, so one
    packing serves the whole trajectory.
    """
    from repro.core.bitmap import WORD_BITS, BitmapDB

    gen_name, base_kwargs, rels = PAPER_REPLICAS[name]
    kwargs = dict(base_kwargs)
    kwargs["n_trans"] = n_trans = max(1, int(round(kwargs["n_trans"]
                                                   * scale)))
    minsups = [max(1, int(round(r * n_trans))) for r in rels]
    minsup = minsups[0]
    n_universe = _item_universe(gen_name, kwargs)
    make_stream = lambda: _STREAMS[gen_name](seed=seed, batch=batch,  # noqa: E731
                                             **kwargs)

    supports = np.zeros(n_universe, np.int64)
    for items, mask in make_stream():
        supports += _masked_unique_bincount(items, mask, n_universe)

    freq = np.flatnonzero(supports >= minsup)
    order = sorted(freq.tolist(), key=lambda i: (supports[i], repr(int(i))))
    row_of = np.full(n_universe, -1, np.int64)
    row_of[order] = np.arange(len(order))

    block_tids = block_words * WORD_BITS
    n_blocks = max(1, -(-n_trans // block_tids))
    # Flat word axis during packing: global word index is just tid>>5.
    bitmaps = np.zeros((len(order), n_blocks * block_words), np.uint32)
    tid0 = 0
    for items, mask in make_stream():
        b, width = items.shape
        r = row_of[items]
        valid = mask & (r >= 0)
        tids = tid0 + np.broadcast_to(np.arange(b)[:, None], (b, width))
        rr, tt = r[valid], tids[valid]
        np.bitwise_or.at(bitmaps, (rr, tt >> 5),
                         (1 << (tt & 31)).astype(np.uint32))
        tid0 += b
    bdb = BitmapDB(items=[int(i) for i in order],
                   bitmaps=bitmaps.reshape(len(order), n_blocks,
                                           block_words),
                   supports=supports[order].astype(np.int32),
                   n_trans=n_trans, minsup=minsup, block_words=block_words)
    return bdb, minsups
