"""Synthetic LM token pipeline.

Deterministic Zipfian token stream with Markov bigram structure so the
loss actually decreases during the example training runs (pure uniform
noise would pin the loss at log V).  Sharding-aware: every host can
regenerate any global batch from (seed, step) alone — that is the
straggler/elasticity story for the data layer (no data server to fail
over; restarts are pure recomputation).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass
class LMDataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_alpha: float = 1.1
    bigram_weight: float = 0.7   # probability mass following the bigram map


class SyntheticLM:
    """token[t+1] ~ bigram(token[t]) w.p. ``bigram_weight`` else Zipf."""

    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._zipf = (ranks ** -cfg.zipf_alpha)
        self._zipf /= self._zipf.sum()
        # a fixed random permutation as the bigram successor map
        self._succ = rng.permutation(v).astype(np.int64)

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens, labels) of shape (batch, seq_len), labels are
        next-token ids (last label wraps; masked value -1 never emitted)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.batch, cfg.seq_len, cfg.vocab_size
        out = np.empty((B, S + 1), np.int64)
        out[:, 0] = rng.choice(V, size=B, p=self._zipf)
        noise = rng.random((B, S))
        fresh = rng.choice(V, size=(B, S), p=self._zipf)
        for t in range(S):
            follow = self._succ[out[:, t]]
            out[:, t + 1] = np.where(noise[:, t] < cfg.bigram_weight,
                                     follow, fresh[:, t])
        tokens = out[:, :-1].astype(np.int32)
        labels = out[:, 1:].astype(np.int32)
        return tokens, labels

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
