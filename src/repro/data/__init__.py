from repro.data.transactions import (  # noqa: F401
    gen_quest, gen_dense_tabular, gen_powerlaw_baskets, gen_bipartite,
    DATASET_REPLICAS, make_dataset,
)
