"""Synthetic recsys interaction data (Zipfian popularity, sessionised)."""

from __future__ import annotations

from typing import Dict

import numpy as np


def _zipf(rng, n: int, size, alpha: float = 1.2) -> np.ndarray:
    # inverse-CDF Zipf over [0, n): cheap and vectorised
    u = rng.random(size)
    return np.minimum((u ** (-1.0 / (alpha - 1.0)) - 1.0).astype(np.int64),
                      n - 1) % n


def sasrec_batch(rng_seed: int, batch: int, seq_len: int, n_items: int,
                 n_neg: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(rng_seed)
    seq = (_zipf(rng, n_items - 1, (batch, seq_len)) + 1).astype(np.int32)
    # next-item targets: shifted sequence; negatives uniform
    pos = np.roll(seq, -1, axis=1)
    pos[:, -1] = (_zipf(rng, n_items - 1, (batch,)) + 1)
    neg = rng.integers(1, n_items, (batch, seq_len, n_neg)).astype(np.int32)
    return {"seq_ids": seq, "pos_ids": pos.astype(np.int32), "neg_ids": neg}


def din_batch(rng_seed: int, batch: int, seq_len: int, n_items: int,
              n_context: int, n_ctx_fields: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(rng_seed)
    hist = (_zipf(rng, n_items - 1, (batch, seq_len)) + 1).astype(np.int32)
    target = (_zipf(rng, n_items - 1, (batch,)) + 1).astype(np.int32)
    ctx = rng.integers(0, n_context, (batch, n_ctx_fields)).astype(np.int32)
    # clicks correlate with target popularity (low id = popular)
    p = 1.0 / (1.0 + target / (0.05 * n_items))
    labels = (rng.random(batch) < p).astype(np.float32)
    return {"hist_ids": hist, "target_id": target, "ctx_ids": ctx,
            "labels": labels}


def xdeepfm_batch(rng_seed: int, batch: int, n_fields: int,
                  vocab_per_field: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(rng_seed)
    ids = _zipf(rng, vocab_per_field, (batch, n_fields))
    offsets = np.arange(n_fields, dtype=np.int64) * vocab_per_field
    field_ids = (ids + offsets[None, :]).astype(np.int32)
    labels = (rng.random(batch) < 0.25).astype(np.float32)
    return {"field_ids": field_ids, "labels": labels}


def twotower_batch(rng_seed: int, batch: int, n_users: int, n_items: int,
                   hist_len: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(rng_seed)
    user = rng.integers(0, n_users, (batch,)).astype(np.int32)
    hist = _zipf(rng, n_items, (batch, hist_len)).astype(np.int32)
    hlen = rng.integers(1, hist_len + 1, (batch,))
    mask = (np.arange(hist_len)[None, :] < hlen[:, None])
    pos = _zipf(rng, n_items, (batch,)).astype(np.int32)
    # logQ correction: Zipf sampling probability of each positive
    ranks = pos.astype(np.float64) + 1
    q = ranks ** -1.2
    logq = np.log(q / q.sum() * batch).astype(np.float32)
    return {"user_id": user, "hist_ids": hist, "hist_mask": mask,
            "pos_item": pos, "item_logq": logq}
